#!/usr/bin/env python3
"""Generate the *committed* golden fixtures under rust/tests/fixtures/.

This is an exact, independent port of the deterministic pieces of the
Rust crate (util::rng::Pcg64, channel::ChannelGenerator,
trace::generate, delay::BatchDelayModel, quality::PowerLawQuality).
All arithmetic is IEEE-754 double / wrapping u64, identical op-for-op
to the Rust side, so the fixtures pin the Rust implementation without
needing a Rust toolchain to produce them.

Run from the repo root:  python tools/gen_golden_fixtures.py
"""

import json
import os

MASK64 = (1 << 64) - 1
MASK32 = (1 << 32) - 1
PCG_MULT = 6364136223846793005


class Pcg64:
    """Port of rust/src/util/rng.rs (PCG-XSH-RR 64/32)."""

    def __init__(self, seed, stream):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + seed) & MASK64
        self.next_u32()

    @classmethod
    def seeded(cls, seed):
        return cls(seed, 0xDA3E39CB94B95BDB)

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & MASK32
        rot = (old >> 59) & 31
        return ((xorshifted >> rot) | (xorshifted << (32 - rot) & MASK32)) & MASK32

    def next_u64(self):
        hi = self.next_u32()
        lo = self.next_u32()
        return ((hi << 32) | lo) & MASK64

    def uniform(self):
        # (next_u64 >> 11) * 2^-53 — both factors exact in binary64
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()


def generate_workload(seed, num_services=20, deadline_lo=7.0, deadline_hi=20.0,
                      eta_lo=5.0, eta_hi=10.0):
    """Port of trace::generate with the paper scenario."""
    rng = Pcg64(seed, 0x7ACE)
    channel_seed = rng.next_u64()
    channels = Pcg64(channel_seed, 0xC4A17)
    devices = []
    for dev_id in range(num_services):
        deadline = rng.uniform_in(deadline_lo, deadline_hi)
        eta = channels.uniform_in(eta_lo, eta_hi)
        devices.append({"id": dev_id, "deadline": deadline, "eta": eta})
    return devices


def delay_g(x, a=0.0240, b=0.3543):
    return 0.0 if x == 0 else a * x + b


def quality_q(t, c=293.0, d=1.1, e=13.0, outage_factor=1.5):
    if t == 0:
        return outage_factor * (c + e)
    return c * t ** (-d) + e


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures")
    os.makedirs(out_dir, exist_ok=True)

    fixtures = {
        "workload_seed7.json": {
            "description": "trace::generate(paper scenario, seed 7) — pins the PCG64 "
                           "stream and the Section-IV distributions",
            "seed": 7,
            "devices": generate_workload(7),
        },
        "models_paper.json": {
            "description": "BatchDelayModel::paper().g(X) and PowerLawQuality::paper()"
                           ".quality(T) at reference points",
            "delay_g": {str(x): delay_g(x) for x in [1, 2, 4, 8, 16, 20, 32]},
            "quality": {str(t): quality_q(t) for t in [0, 1, 2, 4, 8, 16, 32, 50, 100]},
        },
    }
    for name, payload in fixtures.items():
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
