//! Quickstart: load the AOT artifacts, run one real batched denoising
//! step, and solve a small scheduling problem — the 60-second tour of
//! the public API.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use aigc_edge::config::{default_artifacts_dir, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::{PowerLawQuality, QualityModel};
use aigc_edge::runtime::{ArtifactStore, BatchInput, DenoiseExecutor};
use aigc_edge::scheduler::{BatchScheduler, Service, Stacking};
use aigc_edge::util::Pcg64;

fn main() -> anyhow::Result<()> {
    // ---- 1. the compute layer: one real batched DDIM step ----
    let store = ArtifactStore::load(&default_artifacts_dir())?;
    println!("PJRT platform: {}; buckets {:?}", store.platform(), store.buckets());

    let mut exec = DenoiseExecutor::new(&store);
    let dim = exec.data_dim();
    let mut rng = Pcg64::seeded(0);
    let latents: Vec<Vec<f32>> =
        (0..4).map(|_| (0..dim).map(|_| rng.normal() as f32).collect()).collect();
    // four tasks at *different* timesteps in ONE batch — the heterogeneity
    // that batch denoising schedules
    let ts = [(1000, 800), (750, 500), (500, 250), (250, 0)];
    let batch: Vec<BatchInput> = latents
        .iter()
        .zip(&ts)
        .map(|(l, &(c, p))| BatchInput { latent: l, t_cur: c, t_prev: p })
        .collect();
    let out = exec.step(&batch)?;
    println!(
        "executed a {}-task batch in bucket {} in {:.2} ms",
        batch.len(),
        out.bucket,
        out.exec_seconds * 1e3
    );

    // ---- 2. the scheduling layer: STACKING on a toy instance ----
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let services: Vec<Service> =
        [3.0, 5.0, 8.0, 12.0].iter().enumerate().map(|(i, &b)| Service::new(i, b)).collect();
    let schedule = Stacking::default().schedule(&services, &delay, &quality);
    println!("\nSTACKING on generation budgets [3, 5, 8, 12] s:");
    for (k, (&steps, &done)) in schedule.steps.iter().zip(&schedule.completion).enumerate() {
        println!(
            "  service {k}: {steps} denoising steps, finishes at {done:.2} s, FID {:.1}",
            quality.quality(steps)
        );
    }
    println!(
        "mean FID {:.2} across {} batches (amortization {:.0}%)",
        schedule.mean_quality(&quality),
        schedule.batches.len(),
        100.0 * schedule.amortization_ratio(&delay)
    );

    // ---- 3. the full config surface ----
    let cfg = ExperimentConfig::paper();
    println!(
        "\npaper preset: K={}, B={} kHz, deadlines U[{}, {}] s",
        cfg.scenario.num_services,
        cfg.scenario.total_bandwidth_hz / 1e3,
        cfg.scenario.deadline_lo,
        cfg.scenario.deadline_hi
    );
    Ok(())
}
