//! End-to-end serving driver — the repository's headline validation.
//!
//! Serves the paper's Section-IV workload (K = 20 devices, deadlines
//! U[7, 20] s, B = 40 kHz, η ∈ U[5, 10]) through the ENTIRE stack:
//! PSO bandwidth allocation → STACKING batch plan → real PJRT
//! executions of the AOT-compiled DDIM step → simulated transmission.
//! Reports per-request latency, throughput, and — via the Fréchet
//! distance between the actually-generated latents and the target
//! distribution — the delivered content quality.
//!
//! Run: `cargo run --release --example serve_edge [epochs] [k]`
//! Results are recorded in EXPERIMENTS.md.

use aigc_edge::config::{default_artifacts_dir, ExperimentConfig};
use aigc_edge::coordinator::{Engine, EngineConfig};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::runtime::ArtifactStore;
use aigc_edge::trace::generate;
use aigc_edge::util::linalg::{frechet_distance, sample_moments, SymMat};
use aigc_edge::util::stats;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let k: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);

    let dir = default_artifacts_dir();
    let store = ArtifactStore::load(&dir)?;
    println!(
        "platform {} | buckets {:?} | serving {epochs} epochs of K={k}",
        store.platform(),
        store.buckets()
    );

    let mut cfg = ExperimentConfig::paper();
    cfg.scenario.num_services = k;
    let quality = PowerLawQuality::paper();
    let mut engine = Engine::new(&store, EngineConfig::default());

    let mut all_latents: Vec<f64> = Vec::new();
    let mut gen_latencies: Vec<f64> = Vec::new();
    let mut planned: Vec<f64> = Vec::new();
    let mut steps_served: Vec<f64> = Vec::new();
    let mut total_tasks = 0u64;
    let mut total_wall = 0.0;
    let mut outages = 0usize;

    for epoch in 0..epochs {
        let workload = generate(&cfg.scenario, cfg.seed + epoch as u64);
        let t0 = std::time::Instant::now();
        let report = engine.serve_epoch_default(&workload, &quality)?;
        let wall = t0.elapsed().as_secs_f64();
        total_wall += report.exec_wall_s;
        println!(
            "epoch {epoch}: planned mean FID {:.2}, {} batches, exec {:.2}s (epoch wall {:.2}s incl. PSO)",
            report.mean_quality, report.batches, report.exec_wall_s, wall
        );
        for r in &report.requests {
            if r.steps == 0 {
                outages += 1;
                continue;
            }
            gen_latencies.push(r.actual_gen_s);
            planned.push(r.planned_gen_s);
            steps_served.push(r.steps as f64);
            total_tasks += r.steps as u64;
        }
        for latent in report.latents.iter().filter(|l| !l.is_empty()) {
            all_latents.extend(latent.iter().map(|&v| v as f64));
        }
    }

    let dim = store.manifest().data_dim;
    let served = all_latents.len() / dim;
    println!("\n== serving summary ==");
    println!("requests served: {served}  outages: {outages}");
    println!("denoising tasks executed: {total_tasks}");
    println!(
        "generation wall-clock: mean {:.2}s  p95 {:.2}s (planned-model mean {:.2}s)",
        stats::mean(&gen_latencies),
        stats::percentile(&gen_latencies, 95.0),
        stats::mean(&planned),
    );
    println!("mean steps/request: {:.1}", stats::mean(&steps_served));
    println!(
        "throughput: {:.1} denoising tasks/s of GPU time",
        total_tasks as f64 / total_wall.max(1e-9)
    );

    // ---- delivered quality: Fréchet distance on the REAL outputs ----
    if let Some(moments_file) = &store.manifest().moments_file {
        let raw = std::fs::read(dir.join(moments_file))?;
        let floats: Vec<f64> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
            .collect();
        let mu_t = floats[..dim].to_vec();
        let cov_t = SymMat { n: dim, data: floats[dim..].to_vec() };
        let (mu_g, cov_g) = sample_moments(&all_latents, dim);
        let fd = frechet_distance(&mu_g, &cov_g, &mu_t, &cov_t);
        let mean_steps = stats::mean(&steps_served);
        println!(
            "delivered quality: FD {:.2} over {served} generations (calibration curve predicts ≈{:.2} at {:.0} steps{})",
            fd,
            calibrated_prediction(&dir, mean_steps),
            mean_steps,
            if served < 4 * dim { "; small-sample FD is inflated" } else { "" }
        );
    }
    println!("\n{}", engine.metrics.render());
    Ok(())
}

/// What the calibration curve (artifacts/quality.json) predicts for a
/// given step budget.
fn calibrated_prediction(dir: &std::path::Path, steps: f64) -> f64 {
    use aigc_edge::quality::{PowerLawQuality, QualityModel};
    match PowerLawQuality::from_quality_json(&dir.join("quality.json")) {
        Ok(q) => q.quality(steps.round() as u32),
        Err(_) => f64::NAN,
    }
}
