//! Online serving demo: start the TCP server, spawn a fleet of
//! simulated mobile devices, and print the per-device outcomes — the
//! deployment shape of the paper's system.
//!
//! Run: `cargo run --release --example online_tcp [devices]`

use aigc_edge::config::{default_artifacts_dir, ExperimentConfig};
use aigc_edge::server::{serve, Client, Response, ServerConfig};
use aigc_edge::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let devices: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let dir = default_artifacts_dir();
    let mut cfg = ExperimentConfig::paper();
    cfg.pso.particles = 8;
    cfg.pso.iterations = 10;
    let server = serve(dir, cfg, ServerConfig { epoch_ms: 300, max_batch: 32 }, "127.0.0.1:0")?;
    let addr = server.addr;
    println!("server on {addr}; spawning {devices} devices");

    let handles: Vec<_> = (0..devices)
        .map(|i| {
            std::thread::spawn(move || {
                let mut rng = Pcg64::seeded(900 + i as u64);
                // paper distributions, scaled down so the demo runs fast
                let deadline = rng.uniform_in(2.5, 6.0);
                let eta = rng.uniform_in(5.0, 10.0);
                let mut client = Client::connect(addr).expect("connect");
                let t0 = std::time::Instant::now();
                let resp = client.generate(deadline, eta).expect("generate");
                (i, deadline, eta, resp, t0.elapsed().as_secs_f64())
            })
        })
        .collect();

    println!("{:>3}  {:>8}  {:>6}  {:>22}  {:>8}", "dev", "deadline", "eta", "response", "rtt_s");
    for h in handles {
        let (i, deadline, eta, resp, rtt) = h.join().unwrap();
        let shown = match &resp {
            Response::Done { steps, gen_ms, quality, .. } => {
                format!("{steps} steps, {gen_ms:.0}ms, FID {quality:.1}")
            }
            Response::Outage => "OUTAGE".to_string(),
            Response::Error(e) => format!("ERR {e}"),
        };
        println!("{i:>3}  {deadline:>8.2}  {eta:>6.2}  {shown:>22}  {rtt:>8.2}");
    }

    let mut client = Client::connect(addr)?;
    let _ = client.generate(3.0, 7.0)?;
    println!("\nserver metrics:\n{}", client.stats()?);
    Ok(())
}
