//! Fig. 1a on this machine: measure the real batch denoising delay per
//! bucket on the PJRT runtime and fit g(X) = aX + b.
//!
//! Run: `cargo run --release --example profile_batch [reps]`

use aigc_edge::bench;
use aigc_edge::config::default_artifacts_dir;
use aigc_edge::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    aigc_edge::coordinator::pin_xla_single_threaded();
    let reps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let store = ArtifactStore::load(&default_artifacts_dir())?;
    println!(
        "platform: {} (paper measured on an RTX 3050; shapes, not absolutes, transfer)",
        store.platform()
    );
    bench::fig1a(&store, reps);
    Ok(())
}
