//! Minimal offline-compatible subset of the `anyhow` error-handling
//! crate, matching upstream semantics for the surface this workspace
//! uses:
//!
//! * [`Error`] — an opaque error carrying a chain of context messages.
//!   `{}` displays the outermost message, `{:#}` the whole chain joined
//!   with `": "` (upstream's alternate format), and `{:?}` a
//!   `Caused by:` listing.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (any error convertible into [`Error`], including `Error` itself)
//!   and on `Option`.
//! * [`anyhow!`] and [`bail!`] macros.
//!
//! `From<E: std::error::Error + Send + Sync + 'static>` powers `?`
//! conversions; as in upstream, `Error` itself deliberately does not
//! implement `std::error::Error` so that blanket impl stays coherent.

use std::fmt;

/// An error wrapping a chain of messages, outermost first.
pub struct Error {
    /// `chain[0]` is the most recent context; the root cause is last.
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a single message.
    pub fn new(msg: String) -> Self {
        Self { chain: vec![msg] }
    }

    /// Construct from anything displayable (upstream's `Error::msg`).
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self::new(msg.to_string())
    }

    /// Prepend a context message (what `.context(..)` does).
    pub fn wrap(mut self, msg: String) -> Self {
        self.chain.insert(0, msg);
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// All messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain joined with ": " (upstream format).
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::new(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Build an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::new(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::new(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err()
            .wrap("loading artifacts".into());
        assert_eq!(e.to_string(), "loading artifacts");
        let full = format!("{e:#}");
        assert!(full.contains("loading artifacts: reading manifest: file missing"), "{full}");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("file missing"));
    }

    #[test]
    fn context_on_option_and_anyhow_result() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let nested: Result<u32> = Err(anyhow!("root"));
        let e = nested.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: root");
    }

    #[test]
    fn macros() {
        fn fails(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(fails(-2).unwrap_err().to_string(), "negative input -2");
        let from_string = anyhow!(String::from("boom"));
        assert_eq!(from_string.to_string(), "boom");
        let formatted = anyhow!("x = {}, y = {}", 1, 2);
        assert_eq!(formatted.to_string(), "x = 1, y = 2");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::new("root".into()).wrap("mid".into()).wrap("outer".into());
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}
