//! Failure traces and migration policy — the fault model for the
//! shared-clock cluster engine (`sim::event`).
//!
//! The paper's joint optimization assumes servers that stay up; a
//! production edge fleet does not. Collaborative distributed diffusion
//! (arXiv:2304.03446) and 6G MEC offloading (arXiv:2312.06203) both
//! treat dynamic server availability and task re-offloading as
//! first-class, so this module makes them first-class here:
//!
//! * [`FaultScript`] — a deterministic failure trace: per-server down
//!   intervals, either **scheduled** explicitly or drawn from a
//!   **seeded** alternating-renewal process (exponential up-times with
//!   mean `mtbf_s`, exponential down-times with mean `mttr_s`).
//!   Identical seeds replay bit-identically, like every other
//!   stochastic component in the system.
//! * [`MigrationPolicy`] — what happens to a dead (or overloaded)
//!   server's queued requests: lose them with the server
//!   ([`NoMigration`]), hand them back through the
//!   [`Router`](crate::routing::Router) with their elapsed deadline
//!   budget preserved ([`RequeueOnDeath`]), additionally let solve
//!   carry-overs re-enter the router whenever an idle sibling exists
//!   ([`StealWhenIdle`]), or checkpoint the executing batch at the
//!   last completed step boundary so partial denoising progress
//!   resumes on a live sibling ([`CheckpointOnDeath`]).
//!
//! Every name parser here returns an error listing the valid names, so
//! a CLI/TOML typo is diagnosable without reading the source.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::Pcg64;

/// One contiguous outage of one server: down at `from_s`, recovered at
/// `until_s` (which may exceed the trace horizon — the server then
/// simply never comes back).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DownInterval {
    pub server: usize,
    pub from_s: f64,
    pub until_s: f64,
}

impl DownInterval {
    pub fn new(server: usize, from_s: f64, until_s: f64) -> Result<Self> {
        let d = Self { server, from_s, until_s };
        d.validate()?;
        Ok(d)
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.from_s >= 0.0 && self.from_s.is_finite()) {
            bail!(
                "down interval for server {}: from_s must be finite and >= 0, got {}",
                self.server,
                self.from_s
            );
        }
        if !(self.until_s > self.from_s && self.until_s.is_finite()) {
            bail!(
                "down interval for server {}: until_s ({}) must be finite and > from_s ({})",
                self.server,
                self.until_s,
                self.from_s
            );
        }
        Ok(())
    }

    pub fn duration_s(&self) -> f64 {
        self.until_s - self.from_s
    }
}

/// Whether a fault event takes a server down or brings it back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Down,
    Up,
}

/// One scheduled availability transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t_s: f64,
    pub server: usize,
    pub kind: FaultKind,
}

/// A deterministic per-server failure trace: the complete set of down
/// intervals a cluster run injects. Intervals never overlap per server
/// (validated on construction), so the induced event sequence is a
/// well-formed alternation of Down/Up per server.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultScript {
    /// Sorted by `(from_s, server)`.
    downs: Vec<DownInterval>,
}

/// The shared all-alive script — what borrowing callers point at when
/// they inject no faults (e.g. `EventClusterConfig::fault_free`, the
/// pipeline sweep). Identical to [`FaultScript::empty`], but `'static`.
pub static NO_FAULTS: FaultScript = FaultScript { downs: Vec::new() };

impl FaultScript {
    /// No failures: the event engine degenerates to an all-alive fleet.
    pub fn empty() -> Self {
        Self { downs: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.downs.is_empty()
    }

    pub fn downs(&self) -> &[DownInterval] {
        &self.downs
    }

    /// Build from explicit intervals; rejects malformed or per-server
    /// overlapping intervals.
    pub fn scheduled(mut downs: Vec<DownInterval>) -> Result<Self> {
        for d in &downs {
            d.validate()?;
        }
        let key = |d: &DownInterval| (d.from_s, d.server);
        downs.sort_by(|a, b| key(a).partial_cmp(&key(b)).unwrap());
        let mut last_until: BTreeMap<usize, f64> = BTreeMap::new();
        for d in &downs {
            if let Some(&until) = last_until.get(&d.server) {
                if d.from_s < until {
                    bail!(
                        "server {} has overlapping down intervals (down at {} before recovery at {until})",
                        d.server,
                        d.from_s
                    );
                }
            }
            last_until.insert(d.server, d.until_s);
        }
        Ok(Self { downs })
    }

    /// Seeded alternating-renewal failures for every server: up-times
    /// are Exp(mean `mtbf_s`), down-times Exp(mean `mttr_s`), drawn on
    /// an independent PCG stream per server. Failures starting past
    /// `horizon_s` are not generated (a recovery may land past it).
    pub fn random(servers: usize, horizon_s: f64, mtbf_s: f64, mttr_s: f64, seed: u64) -> Self {
        assert!(mtbf_s > 0.0 && mtbf_s.is_finite(), "mtbf_s must be positive and finite");
        assert!(mttr_s > 0.0 && mttr_s.is_finite(), "mttr_s must be positive and finite");
        assert!(horizon_s >= 0.0 && horizon_s.is_finite(), "horizon_s must be finite");
        let mut downs = Vec::new();
        for server in 0..servers {
            let mut rng = Pcg64::new(seed, 0xFA17_0000 + server as u64);
            downs.extend(renewal_downs(server, horizon_s, mtbf_s, mttr_s, |mean| {
                rng.exponential(1.0 / mean)
            }));
        }
        Self::scheduled(downs).expect("renewal intervals are disjoint by construction")
    }

    /// Parse the CLI/TOML interval spec:
    /// `server:from_s:until_s[,server:from_s:until_s...]`.
    pub fn parse_spec(spec: &str) -> Result<Vec<DownInterval>> {
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() != 3 {
                bail!("down interval '{part}': expected server:from_s:until_s");
            }
            let ctx = |what: &str| format!("down interval '{part}': bad {what}");
            let server: usize = fields[0].parse().with_context(|| ctx("server index"))?;
            let from_s: f64 = fields[1].parse().with_context(|| ctx("from_s"))?;
            let until_s: f64 = fields[2].parse().with_context(|| ctx("until_s"))?;
            out.push(DownInterval::new(server, from_s, until_s)?);
        }
        Ok(out)
    }

    /// Check every interval names a server inside an `n`-server fleet.
    pub fn validate_servers(&self, n: usize) -> Result<()> {
        for d in &self.downs {
            if d.server >= n {
                bail!(
                    "fault script names server {} but the fleet has {n} servers (0..={})",
                    d.server,
                    n - 1
                );
            }
        }
        Ok(())
    }

    /// The induced availability transitions, time-sorted. At equal
    /// instants recoveries sort before failures (so back-to-back
    /// intervals on one server never yield a spuriously all-dead
    /// ordering), then lower server ids first.
    pub fn events(&self) -> Vec<FaultEvent> {
        let mut ev = Vec::with_capacity(self.downs.len() * 2);
        for d in &self.downs {
            ev.push(FaultEvent { t_s: d.from_s, server: d.server, kind: FaultKind::Down });
            ev.push(FaultEvent { t_s: d.until_s, server: d.server, kind: FaultKind::Up });
        }
        ev.sort_by(|a, b| {
            a.t_s
                .partial_cmp(&b.t_s)
                .unwrap()
                .then((a.kind == FaultKind::Down).cmp(&(b.kind == FaultKind::Down)))
                .then(a.server.cmp(&b.server))
        });
        ev
    }

    /// Total scheduled downtime summed over servers.
    pub fn total_downtime_s(&self) -> f64 {
        self.downs.iter().map(DownInterval::duration_s).sum()
    }
}

/// Outage floor for renewal draws. `Pcg64::uniform` can return exactly
/// 0.0, which makes `exponential` return exactly 0.0 — and a
/// zero-length `DownInterval` fails `until_s > from_s` validation, so
/// the unclamped construction could panic inside its own
/// "disjoint by construction" expect.
const MIN_OUTAGE_S: f64 = 1e-9;

/// One server's alternating-renewal down intervals: `draw(mean)` is
/// called for alternating up-gaps (mean `mtbf_s`) and outages (mean
/// `mttr_s`). Split from [`FaultScript::random`] so the degenerate
/// zero-length outage draw can be forced in tests. Zero up-gaps are
/// legal (back-to-back intervals touch); zero outages are clamped to
/// [`MIN_OUTAGE_S`].
fn renewal_downs(
    server: usize,
    horizon_s: f64,
    mtbf_s: f64,
    mttr_s: f64,
    mut draw: impl FnMut(f64) -> f64,
) -> Vec<DownInterval> {
    let mut downs = Vec::new();
    let mut t = draw(mtbf_s);
    while t < horizon_s {
        let outage = draw(mttr_s).max(MIN_OUTAGE_S);
        let until_s = t + outage;
        // At extreme `t` even the clamped outage can round away to a
        // zero-width interval; skip it rather than emit an invalid one.
        if until_s > t {
            downs.push(DownInterval { server, from_s: t, until_s });
        }
        t += outage + draw(mtbf_s);
    }
    downs
}

/// How the fault script is produced. Lives here (not in `config`) so
/// the mode set and its names stay next to the implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultModeKind {
    /// No failures injected.
    None,
    /// Seeded alternating-renewal failures ([`FaultScript::random`]).
    Random,
    /// Explicit down intervals ([`FaultScript::scheduled`]).
    Scheduled,
}

impl FaultModeKind {
    /// Parse the CLI/TOML name; the error lists the valid names.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "none" | "off" => Ok(Self::None),
            "random" => Ok(Self::Random),
            "scheduled" => Ok(Self::Scheduled),
            other => bail!("unknown fault mode '{other}' (valid: none|off, random, scheduled)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Random => "random",
            Self::Scheduled => "scheduled",
        }
    }

    pub fn all() -> [Self; 3] {
        [Self::None, Self::Random, Self::Scheduled]
    }
}

/// What the cluster engine does with requests stranded on a dead (or
/// overloaded) server. Implementations are deliberately tiny decision
/// predicates: the mechanics (hand-off through the router with the
/// elapsed deadline budget preserved) live in `sim::event`, so every
/// policy shares one audited migration path.
pub trait MigrationPolicy {
    fn name(&self) -> &'static str;

    /// Re-route a dead server's queued requests through the router
    /// (`false`: they are lost with the server).
    fn requeue_on_death(&self) -> bool;

    /// Hand a solve's carry-overs back to the router whenever an idle
    /// alive sibling exists (`false`: carry-overs stay local).
    fn steal_when_idle(&self) -> bool;

    /// Checkpoint the executing batch at the last completed step
    /// boundary when its server dies: undelivered requests keep their
    /// finished denoising steps and re-enter the router as partials
    /// after a latent-transfer delay (`false`: a death loses the
    /// undelivered part of the executing batch).
    fn checkpoint_in_flight(&self) -> bool {
        false
    }
}

/// Queued requests die with their server (the ablation baseline).
#[derive(Debug, Clone, Copy, Default)]
pub struct NoMigration;

impl MigrationPolicy for NoMigration {
    fn name(&self) -> &'static str {
        "none"
    }

    fn requeue_on_death(&self) -> bool {
        false
    }

    fn steal_when_idle(&self) -> bool {
        false
    }
}

/// A dead server's queue is handed back to the router at the failure
/// instant; deferred work otherwise stays put.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequeueOnDeath;

impl MigrationPolicy for RequeueOnDeath {
    fn name(&self) -> &'static str {
        "requeue-on-death"
    }

    fn requeue_on_death(&self) -> bool {
        true
    }

    fn steal_when_idle(&self) -> bool {
        false
    }
}

/// Requeue-on-death plus work stealing: carry-overs re-enter the
/// router whenever a sibling's queue has drained, so an overloaded
/// server sheds deferred work to idle capacity.
#[derive(Debug, Clone, Copy, Default)]
pub struct StealWhenIdle;

impl MigrationPolicy for StealWhenIdle {
    fn name(&self) -> &'static str {
        "steal-when-idle"
    }

    fn requeue_on_death(&self) -> bool {
        true
    }

    fn steal_when_idle(&self) -> bool {
        true
    }
}

/// Requeue-on-death plus step checkpointing: a dying server's executing
/// batch is cut at the last completed step boundary, and every
/// undelivered request resumes on another server with its finished
/// steps credited (after a latent-transfer delay). Work-conserving
/// under failures: partial denoising progress survives the crash.
#[derive(Debug, Clone, Copy, Default)]
pub struct CheckpointOnDeath;

impl MigrationPolicy for CheckpointOnDeath {
    fn name(&self) -> &'static str {
        "checkpoint-on-death"
    }

    fn requeue_on_death(&self) -> bool {
        true
    }

    fn steal_when_idle(&self) -> bool {
        false
    }

    fn checkpoint_in_flight(&self) -> bool {
        true
    }
}

/// Which migration policy a cluster runs (config/CLI surface for the
/// [`MigrationPolicy`] implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationPolicyKind {
    None,
    RequeueOnDeath,
    StealWhenIdle,
    Checkpoint,
}

impl MigrationPolicyKind {
    /// Parse the CLI/TOML name; the error lists the valid names.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "none" | "off" => Ok(Self::None),
            "requeue" | "requeue-on-death" => Ok(Self::RequeueOnDeath),
            "steal" | "steal-when-idle" => Ok(Self::StealWhenIdle),
            "checkpoint" | "checkpoint-on-death" => Ok(Self::Checkpoint),
            other => {
                bail!("unknown migration policy '{other}' (valid: none|off, requeue|requeue-on-death, steal|steal-when-idle, checkpoint|checkpoint-on-death)")
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::RequeueOnDeath => "requeue-on-death",
            Self::StealWhenIdle => "steal-when-idle",
            Self::Checkpoint => "checkpoint-on-death",
        }
    }

    /// All policies, in the order the fault sweeps compare them.
    pub fn all() -> [Self; 4] {
        [Self::None, Self::RequeueOnDeath, Self::StealWhenIdle, Self::Checkpoint]
    }

    pub fn build(&self) -> Box<dyn MigrationPolicy> {
        match self {
            Self::None => Box::new(NoMigration),
            Self::RequeueOnDeath => Box::new(RequeueOnDeath),
            Self::StealWhenIdle => Box::new(StealWhenIdle),
            Self::Checkpoint => Box::new(CheckpointOnDeath),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn down(server: usize, from: f64, until: f64) -> DownInterval {
        DownInterval::new(server, from, until).unwrap()
    }

    #[test]
    fn scheduled_sorts_and_rejects_overlap() {
        let script =
            FaultScript::scheduled(vec![down(1, 30.0, 40.0), down(0, 10.0, 20.0)]).unwrap();
        assert_eq!(script.downs()[0].server, 0);
        assert_eq!(script.downs()[1].server, 1);
        assert!((script.total_downtime_s() - 20.0).abs() < 1e-12);
        let overlap = FaultScript::scheduled(vec![down(2, 5.0, 15.0), down(2, 10.0, 20.0)]);
        assert!(overlap.unwrap_err().to_string().contains("overlapping"));
        // back-to-back intervals on one server are fine
        assert!(FaultScript::scheduled(vec![down(2, 5.0, 15.0), down(2, 15.0, 20.0)]).is_ok());
    }

    #[test]
    fn interval_validation_rejects_nonsense() {
        assert!(DownInterval::new(0, -1.0, 5.0).is_err());
        assert!(DownInterval::new(0, 5.0, 5.0).is_err());
        assert!(DownInterval::new(0, 5.0, 1.0).is_err());
        assert!(DownInterval::new(0, 0.0, f64::INFINITY).is_err());
        assert!(DownInterval::new(0, f64::NAN, 1.0).is_err());
    }

    #[test]
    fn events_are_time_sorted_with_up_before_down_on_ties() {
        let script =
            FaultScript::scheduled(vec![down(0, 10.0, 20.0), down(1, 20.0, 30.0)]).unwrap();
        let ev = script.events();
        assert_eq!(ev.len(), 4);
        assert!(ev.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        // at t = 20 the recovery of server 0 precedes the failure of 1
        assert_eq!(ev[1], FaultEvent { t_s: 20.0, server: 0, kind: FaultKind::Up });
        assert_eq!(ev[2], FaultEvent { t_s: 20.0, server: 1, kind: FaultKind::Down });
    }

    #[test]
    fn random_is_seeded_disjoint_and_roughly_calibrated() {
        let a = FaultScript::random(4, 2000.0, 60.0, 10.0, 7);
        let b = FaultScript::random(4, 2000.0, 60.0, 10.0, 7);
        assert_eq!(a, b, "identical seeds must replay bit-identically");
        assert_ne!(a, FaultScript::random(4, 2000.0, 60.0, 10.0, 8));
        assert!(!a.is_empty());
        // disjoint per server by construction (scheduled() re-validates)
        FaultScript::scheduled(a.downs().to_vec()).unwrap();
        // ~2000/70 ≈ 28.6 failures per server expected; loose 3σ bounds
        let per_server = a.downs().len() as f64 / 4.0;
        assert!((10.0..60.0).contains(&per_server), "failures/server = {per_server}");
        let mean_outage = a.total_downtime_s() / a.downs().len() as f64;
        assert!((4.0..25.0).contains(&mean_outage), "mean outage = {mean_outage}");
    }

    #[test]
    fn spec_parses_and_rejects_malformed() {
        let downs = FaultScript::parse_spec("1:10:25, 0:40:60").unwrap();
        assert_eq!(downs.len(), 2);
        assert_eq!(downs[0], down(1, 10.0, 25.0));
        assert_eq!(downs[1], down(0, 40.0, 60.0));
        assert!(FaultScript::parse_spec("").unwrap().is_empty());
        assert!(FaultScript::parse_spec("1:10").is_err());
        assert!(FaultScript::parse_spec("x:1:2").is_err());
        assert!(FaultScript::parse_spec("1:abc:2").is_err());
        assert!(FaultScript::parse_spec("1:5:2").is_err());
    }

    #[test]
    fn validate_servers_bounds_indices() {
        let script = FaultScript::scheduled(vec![down(3, 1.0, 2.0)]).unwrap();
        assert!(script.validate_servers(4).is_ok());
        let err = script.validate_servers(3).unwrap_err().to_string();
        assert!(err.contains("server 3") && err.contains("3 servers"), "{err}");
    }

    #[test]
    fn kind_parsers_round_trip_and_list_valid_names() {
        for kind in MigrationPolicyKind::all() {
            assert_eq!(MigrationPolicyKind::from_name(kind.name()).unwrap(), kind);
            assert_eq!(kind.build().name(), kind.name());
        }
        for mode in FaultModeKind::all() {
            assert_eq!(FaultModeKind::from_name(mode.name()).unwrap(), mode);
        }
        let err = MigrationPolicyKind::from_name("bogus").unwrap_err().to_string();
        assert!(err.contains("requeue-on-death") && err.contains("steal-when-idle"), "{err}");
        let err = FaultModeKind::from_name("bogus").unwrap_err().to_string();
        assert!(err.contains("random") && err.contains("scheduled"), "{err}");
    }

    #[test]
    fn policy_predicates_match_the_documented_matrix() {
        assert!(!NoMigration.requeue_on_death() && !NoMigration.steal_when_idle());
        assert!(RequeueOnDeath.requeue_on_death() && !RequeueOnDeath.steal_when_idle());
        assert!(StealWhenIdle.requeue_on_death() && StealWhenIdle.steal_when_idle());
        assert!(!NoMigration.checkpoint_in_flight());
        assert!(!RequeueOnDeath.checkpoint_in_flight());
        assert!(!StealWhenIdle.checkpoint_in_flight());
        assert!(
            CheckpointOnDeath.requeue_on_death()
                && !CheckpointOnDeath.steal_when_idle()
                && CheckpointOnDeath.checkpoint_in_flight()
        );
    }

    /// Regression: `Pcg64::uniform` can return exactly 0.0, making an
    /// exponential outage draw exactly 0.0 — the resulting zero-length
    /// interval failed validation inside `FaultScript::random`'s
    /// "disjoint by construction" expect. Force the degenerate draw.
    #[test]
    fn renewal_clamps_zero_length_outage_draws() {
        let mut draws = [5.0, 0.0, 3.0, 1.0, 100.0].into_iter();
        let downs = renewal_downs(0, 50.0, 60.0, 10.0, |_mean| draws.next().unwrap());
        assert_eq!(downs.len(), 2);
        let degenerate = downs[0];
        assert!(degenerate.duration_s() > 0.0, "zero draw must be clamped");
        assert!(degenerate.duration_s() <= MIN_OUTAGE_S);
        // the clamped interval still composes into a valid script
        FaultScript::scheduled(downs).unwrap();
        // zero up-gaps are legal: back-to-back intervals touch
        let mut draws = [1.0, 2.0, 0.0, 2.0, 100.0].into_iter();
        let touching = renewal_downs(0, 10.0, 60.0, 10.0, |_mean| draws.next().unwrap());
        assert_eq!(touching.len(), 2);
        assert_eq!(touching[0].until_s, touching[1].from_s);
        FaultScript::scheduled(touching).unwrap();
    }

    #[test]
    fn random_never_panics_across_seeds() {
        for seed in 0..200 {
            let script = FaultScript::random(3, 400.0, 15.0, 4.0, seed);
            script.validate_servers(3).unwrap();
        }
    }
}
