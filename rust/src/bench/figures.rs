//! One function per paper figure. Each prints the paper-shaped table
//! and returns the rows for assertions in tests/benches.

use crate::bandwidth::{Allocator, EqualAllocator, PsoAllocator, PsoConfig};
use crate::cache::CacheSettings;
use crate::config::ExperimentConfig;
use crate::coordinator::{profile_batch_delay, ProfileConfig, SolveMode};
use crate::delay::BatchDelayModel;
use crate::faults::{DownInterval, FaultScript, MigrationPolicyKind, NO_FAULTS};
use crate::quality::{PowerLawQuality, QualityModel, TableQuality};
use crate::routing::RouterKind;
use crate::runtime::ArtifactStore;
use crate::scheduler::{
    BatchScheduler, FixedSizeBatching, GreedyBatching, SingleInstance, Stacking,
};
use crate::sim::{
    server_speeds, simulate_cluster, simulate_dynamic, simulate_event_cluster, solve_joint,
    ClusterConfig, DynamicConfig, EventClusterConfig,
};
use crate::trace::{generate, sweeps, ArrivalTrace};
use crate::util::exec::par_map;
use crate::util::fit_power_law;

use super::TableWriter;

/// The five schemes of Fig. 2 (proposed + four baselines).
pub struct Scheme {
    pub name: &'static str,
    pub scheduler: Box<dyn BatchScheduler>,
    pub use_pso: bool,
}

/// Build the paper's comparison set. PSO settings are scaled down via
/// `pso_cfg` for quick runs.
pub fn schemes() -> Vec<Scheme> {
    vec![
        Scheme { name: "proposed", scheduler: Box::new(Stacking::default()), use_pso: true },
        Scheme {
            name: "single-instance",
            scheduler: Box::new(SingleInstance::default()),
            use_pso: true,
        },
        Scheme { name: "greedy", scheduler: Box::new(GreedyBatching), use_pso: true },
        Scheme {
            name: "fixed-size",
            scheduler: Box::new(FixedSizeBatching::default()),
            use_pso: true,
        },
        Scheme {
            name: "equal-bandwidth",
            scheduler: Box::new(Stacking::default()),
            use_pso: false,
        },
    ]
}

fn make_allocator(use_pso: bool, pso: PsoConfig) -> Box<dyn Allocator> {
    if use_pso {
        Box::new(PsoAllocator::new(pso))
    } else {
        Box::new(EqualAllocator)
    }
}

fn pso_config(cfg: &ExperimentConfig) -> PsoConfig {
    PsoConfig {
        particles: cfg.pso.particles,
        iterations: cfg.pso.iterations,
        patience: cfg.pso.patience,
        ..Default::default()
    }
}

/// Mean quality of one scheme on one scenario, averaged over seeds.
fn scheme_mean_quality(
    scheme: &Scheme,
    cfg: &ExperimentConfig,
    scenario: &crate::config::ScenarioConfig,
    quality: &dyn QualityModel,
    delay: &BatchDelayModel,
    reps: usize,
) -> f64 {
    let allocator = make_allocator(scheme.use_pso, pso_config(cfg));
    let mut acc = 0.0;
    for rep in 0..reps {
        let workload = generate(scenario, cfg.seed + rep as u64);
        let sol =
            solve_joint(&workload, scheme.scheduler.as_ref(), allocator.as_ref(), delay, quality);
        acc += sol.outcome.mean_quality();
    }
    acc / reps as f64
}

// ---------------------------------------------------------------------------
// Fig. 1a — denoising delay vs batch size (measured on this machine)
// ---------------------------------------------------------------------------

/// Rows: (batch size, measured seconds, fitted seconds). Also prints the
/// fitted constants next to the paper's.
pub fn fig1a(store: &ArtifactStore, reps: usize) -> Vec<(u32, f64, f64)> {
    let fit = profile_batch_delay(store, ProfileConfig { reps, ..Default::default() })
        .expect("profiling failed");
    let model = fit.model();
    let mut table = TableWriter::new(
        "Fig. 1a — denoising delay vs batch size (PJRT CPU, this machine)",
        &["batch X", "measured s", "fit aX+b s"],
    )
    .with_csv("fig1a_batch_delay");
    let mut rows = Vec::new();
    for &(x, measured) in &fit.samples {
        let fitted = model.g(x);
        table.row(&[x.to_string(), format!("{measured:.5}"), format!("{fitted:.5}")]);
        rows.push((x, measured, fitted));
    }
    table.finish();
    println!(
        "fit: a = {:.5} s/task, b = {:.5} s/batch (R² = {:.4});  paper (RTX 3050): a = 0.0240, b = 0.3543",
        model.a, model.b, fit.fit.r2
    );
    println!(
        "amortization: per-task cost {:.4}s at X=1 -> {:.4}s at X={}",
        model.per_task(1),
        model.per_task(store.max_bucket()),
        store.max_bucket()
    );
    rows
}

// ---------------------------------------------------------------------------
// Fig. 1b — quality vs denoising steps (measured at `make artifacts`)
// ---------------------------------------------------------------------------

/// Rows: (steps, measured FD, rust power-law fit). Prints the rust-side
/// re-fit against the python fit stored in quality.json.
pub fn fig1b(cfg: &ExperimentConfig) -> Vec<(u32, f64, f64)> {
    let table_quality = TableQuality::from_quality_json(&cfg.quality_json_path())
        .expect("quality.json missing — run `make artifacts`");
    let python_fit = PowerLawQuality::from_quality_json(&cfg.quality_json_path()).unwrap();
    let pts = table_quality.points();
    let xs: Vec<f64> = pts.iter().map(|p| p.0 as f64).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let rust_fit = fit_power_law(&xs, &ys);

    let mut table = TableWriter::new(
        "Fig. 1b — quality (Fréchet distance) vs denoising steps",
        &["steps T", "measured FD", "fit c*T^-d+e"],
    )
    .with_csv("fig1b_quality");
    let mut rows = Vec::new();
    for &(t, fd) in pts {
        let fitted = rust_fit.eval(t as f64);
        table.row(&[t.to_string(), format!("{fd:.4}"), format!("{fitted:.4}")]);
        rows.push((t, fd, fitted));
    }
    table.finish();
    println!(
        "rust re-fit: c = {:.3}, d = {:.3}, e = {:.3} (R² = {:.4}); python fit: c = {:.3}, d = {:.3}, e = {:.3}",
        rust_fit.c, rust_fit.d, rust_fit.e, rust_fit.r2, python_fit.c, python_fit.d, python_fit.e
    );
    rows
}

// ---------------------------------------------------------------------------
// Fig. 2a — end-to-end delay illustration (K = 10, proposed algorithm)
// ---------------------------------------------------------------------------

/// Rows: (service, deadline, gen done, tx delay, e2e, steps).
pub fn fig2a(cfg: &ExperimentConfig) -> Vec<(usize, f64, f64, f64, f64, u32)> {
    let scenario = sweeps::with_num_services(&cfg.scenario, 10);
    let workload = generate(&scenario, cfg.seed);
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let sol = solve_joint(
        &workload,
        &Stacking::default(),
        &PsoAllocator::new(pso_config(cfg)),
        &delay,
        &quality,
    );
    let mut table = TableWriter::new(
        "Fig. 2a — end-to-end delay, K = 10, proposed algorithm",
        &["svc", "deadline s", "gen s", "tx s", "e2e s", "steps", "slack s"],
    )
    .with_csv("fig2a_schedule");
    let mut rows = Vec::new();
    let mut sorted: Vec<_> = sol.outcome.services.iter().collect();
    sorted.sort_by(|a, b| a.deadline.partial_cmp(&b.deadline).unwrap());
    for s in sorted {
        table.row(&[
            s.id.to_string(),
            format!("{:.2}", s.deadline),
            format!("{:.2}", s.gen_delay),
            format!("{:.2}", s.tx_delay),
            format!("{:.2}", s.e2e_delay),
            s.steps.to_string(),
            format!("{:.2}", s.deadline - s.e2e_delay),
        ]);
        rows.push((s.id, s.deadline, s.gen_delay, s.tx_delay, s.e2e_delay, s.steps));
    }
    table.finish();
    println!(
        "mean FID {:.2}; outages {}; makespan {:.2}s; batches {}",
        sol.outcome.mean_quality(),
        sol.outcome.outages(),
        sol.outcome.schedule.makespan(),
        sol.outcome.schedule.batches.len()
    );
    rows
}

// ---------------------------------------------------------------------------
// Fig. 2b — mean FID vs number of services
// ---------------------------------------------------------------------------

/// Rows: (K, [per-scheme mean FID in `schemes()` order]). The K ×
/// scheme cells are independent (each builds its own allocator), so
/// they fan out across `cfg.perf.threads` — rows are assembled in cell
/// order, bit-identical to the serial sweep.
pub fn fig2b(cfg: &ExperimentConfig, ks: &[usize], reps: usize) -> Vec<(usize, Vec<f64>)> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let schemes = schemes();
    let cells: Vec<(usize, usize)> =
        ks.iter().flat_map(|&k| (0..schemes.len()).map(move |si| (k, si))).collect();
    let vals = par_map(cfg.perf.threads, &cells, |_, &(k, si)| {
        let scenario = sweeps::with_num_services(&cfg.scenario, k);
        scheme_mean_quality(&schemes[si], cfg, &scenario, &quality, &delay, reps)
    });
    let mut headers: Vec<&str> = vec!["K"];
    headers.extend(schemes.iter().map(|s| s.name));
    let mut table = TableWriter::new("Fig. 2b — mean FID vs number of services", &headers)
        .with_csv("fig2b_service_sweep");
    let mut rows = Vec::new();
    for (ki, &k) in ks.iter().enumerate() {
        let row_vals: Vec<f64> =
            (0..schemes.len()).map(|si| vals[ki * schemes.len() + si]).collect();
        let mut cells = vec![k.to_string()];
        cells.extend(row_vals.iter().map(|q| format!("{q:.2}")));
        table.row(&cells);
        rows.push((k, row_vals));
    }
    table.finish();
    rows
}

// ---------------------------------------------------------------------------
// Fig. 2c — mean FID vs minimum delay requirement (τmax = 20 s, K = 20)
// ---------------------------------------------------------------------------

/// Rows: (τmin, [per-scheme mean FID]). Cells fan out like `fig2b`.
pub fn fig2c(cfg: &ExperimentConfig, taus: &[f64], reps: usize) -> Vec<(f64, Vec<f64>)> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let schemes = schemes();
    let cells: Vec<(f64, usize)> =
        taus.iter().flat_map(|&tau| (0..schemes.len()).map(move |si| (tau, si))).collect();
    let vals = par_map(cfg.perf.threads, &cells, |_, &(tau, si)| {
        let scenario = sweeps::with_min_deadline(&cfg.scenario, tau);
        scheme_mean_quality(&schemes[si], cfg, &scenario, &quality, &delay, reps)
    });
    let mut headers: Vec<&str> = vec!["tau_min"];
    headers.extend(schemes.iter().map(|s| s.name));
    let mut table = TableWriter::new(
        "Fig. 2c — mean FID vs minimum delay requirement (tau_max = 20 s)",
        &headers,
    )
    .with_csv("fig2c_min_delay");
    let mut rows = Vec::new();
    for (ti, &tau) in taus.iter().enumerate() {
        let row_vals: Vec<f64> =
            (0..schemes.len()).map(|si| vals[ti * schemes.len() + si]).collect();
        let mut cells = vec![format!("{tau:.0}")];
        cells.extend(row_vals.iter().map(|q| format!("{q:.2}")));
        table.row(&cells);
        rows.push((tau, row_vals));
    }
    table.finish();
    rows
}

// ---------------------------------------------------------------------------
// Fig. 3 (new, not in the paper) — dynamic arrivals: λ-sweep
// ---------------------------------------------------------------------------

/// One λ-sweep row of the dynamic-arrival figure.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig3Row {
    pub lambda_hz: f64,
    pub requests: usize,
    pub served: usize,
    pub mean_quality: f64,
    pub outage_rate: f64,
    pub p50_e2e_s: f64,
    pub p99_e2e_s: f64,
    pub mean_wait_s: f64,
    pub epochs: usize,
}

/// Sweep the Poisson arrival rate λ against delivered quality, outage
/// rate and tail latency under the dynamic (multi-epoch) simulator.
/// Fully seeded: identical inputs produce bit-identical rows (asserted
/// by `benches/fig3_dynamic.rs`).
pub fn fig3_dynamic(cfg: &ExperimentConfig, lambdas: &[f64], horizon_s: f64) -> Vec<Fig3Row> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let dyn_cfg = DynamicConfig::from(&cfg.dynamic);
    let mut table = TableWriter::new(
        "Fig. 3 — dynamic Poisson arrivals: quality/outage/latency vs rate",
        &[
            "lambda", "requests", "served", "mean FID", "outage", "p50 e2e s", "p99 e2e s",
            "wait s", "epochs",
        ],
    )
    .with_csv("fig3_dynamic");
    // Each λ is an independent seeded run — the sweep fans out across
    // `cfg.perf.threads`, rows assembled in λ order.
    let rows: Vec<Fig3Row> = par_map(cfg.perf.threads, lambdas, |_, &lambda| {
        let mut arrival = cfg.arrival;
        arrival.process = crate::config::ArrivalProcessKind::Poisson;
        arrival.rate_hz = lambda;
        arrival.horizon_s = horizon_s;
        let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
        let report = simulate_dynamic(&trace, &scheduler, &allocator, &delay, &quality, &dyn_cfg);
        Fig3Row {
            lambda_hz: lambda,
            requests: trace.len(),
            served: report.served(),
            mean_quality: report.mean_quality(),
            outage_rate: report.outage_rate(),
            p50_e2e_s: report.e2e_percentile(50.0),
            p99_e2e_s: report.e2e_percentile(99.0),
            mean_wait_s: report.mean_wait_s(),
            epochs: report.epochs.len(),
        }
    });
    for row in &rows {
        table.row(&[
            format!("{:.2}", row.lambda_hz),
            row.requests.to_string(),
            row.served.to_string(),
            format!("{:.2}", row.mean_quality),
            format!("{:.3}", row.outage_rate),
            format!("{:.2}", row.p50_e2e_s),
            format!("{:.2}", row.p99_e2e_s),
            format!("{:.2}", row.mean_wait_s),
            row.epochs.to_string(),
        ]);
    }
    table.finish();
    rows
}

// ---------------------------------------------------------------------------
// Cluster figure (new) — router λ-sweep over a heterogeneous fleet
// ---------------------------------------------------------------------------

/// One (λ, router) cell of the cluster routing sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FigClusterRow {
    pub lambda_hz: f64,
    pub router: RouterKind,
    pub requests: usize,
    pub served: usize,
    pub mean_quality: f64,
    pub outage_rate: f64,
    pub p50_e2e_s: f64,
    pub p99_e2e_s: f64,
    /// Largest per-server share of the traffic (1/N = perfectly even).
    pub max_share: f64,
}

/// Sweep the Poisson arrival rate λ across every routing policy on the
/// configured fleet (`cfg.cluster`: server count + GPU speed spread).
/// Each λ reuses one seeded trace, so router columns are directly
/// comparable and the whole sweep replays bit-identically (asserted by
/// `benches/fig_cluster.rs` and pinned by `golden_fig_cluster.json`).
pub fn fig_cluster(cfg: &ExperimentConfig, lambdas: &[f64], horizon_s: f64) -> Vec<FigClusterRow> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let mut table = TableWriter::new(
        "Cluster — router λ-sweep: fleet quality/outage/latency per policy",
        &[
            "lambda", "router", "requests", "served", "mean FID", "outage", "p50 e2e", "p99 e2e",
            "max share",
        ],
    )
    .with_csv("fig_cluster");
    // One seeded trace per λ (so router columns stay directly
    // comparable), then the λ × router cells fan out across
    // `cfg.perf.threads` and borrow it — no per-cell cloning.
    let traces: Vec<ArrivalTrace> = lambdas
        .iter()
        .map(|&lambda| {
            let mut arrival = cfg.arrival;
            arrival.process = crate::config::ArrivalProcessKind::Poisson;
            arrival.rate_hz = lambda;
            arrival.horizon_s = horizon_s;
            ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed)
        })
        .collect();
    let cells: Vec<(usize, RouterKind)> = (0..lambdas.len())
        .flat_map(|li| RouterKind::all().into_iter().map(move |r| (li, r)))
        .collect();
    let rows: Vec<FigClusterRow> = par_map(cfg.perf.threads, &cells, |_, &(li, router)| {
        let trace = &traces[li];
        let mut settings = cfg.cluster;
        settings.router = router;
        let cluster_cfg = ClusterConfig::from_settings(&settings, &cfg.dynamic);
        let report =
            simulate_cluster(trace, &scheduler, &allocator, &delay, &quality, &cluster_cfg);
        let stats = report.fleet_stats();
        let max_share = report
            .servers
            .iter()
            .map(|s| s.assigned() as f64 / trace.len().max(1) as f64)
            .fold(0.0, f64::max);
        FigClusterRow {
            lambda_hz: lambdas[li],
            router,
            requests: trace.len(),
            served: report.served(),
            mean_quality: stats.mean_quality,
            outage_rate: stats.outage_rate,
            p50_e2e_s: stats.p50_e2e_s,
            p99_e2e_s: stats.p99_e2e_s,
            max_share,
        }
    });
    for row in &rows {
        table.row(&[
            format!("{:.2}", row.lambda_hz),
            row.router.name().to_string(),
            row.requests.to_string(),
            row.served.to_string(),
            format!("{:.2}", row.mean_quality),
            format!("{:.3}", row.outage_rate),
            format!("{:.2}", row.p50_e2e_s),
            format!("{:.2}", row.p99_e2e_s),
            format!("{:.3}", row.max_share),
        ]);
    }
    table.finish();
    rows
}

// ---------------------------------------------------------------------------
// Faults figure (new) — failure rate × migration policy on the event engine
// ---------------------------------------------------------------------------

/// One (failure-rate, migration-policy) cell of the fault sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FigFaultsRow {
    /// Injected failure rate, failures per server per minute (0 = no
    /// faults).
    pub fault_rate_per_min: f64,
    pub policy: MigrationPolicyKind,
    pub requests: usize,
    pub served: usize,
    pub dropped: usize,
    pub lost_to_failure: usize,
    pub migrated: usize,
    pub failures: usize,
    pub mean_quality: f64,
    pub outage_rate: f64,
    pub p99_e2e_s: f64,
    /// Deadline-censored post-failure p99 (`metrics::RecoveryStats`).
    pub post_failure_p99_s: f64,
    pub mean_time_to_drain_s: f64,
}

/// Sweep the injected failure rate across every migration policy on the
/// configured fleet (`cfg.cluster`), at the configured arrival rate,
/// through the shared-clock event engine. Each failure rate draws its
/// own seeded trace and fault script, reused across the policy columns
/// so cells are directly comparable; the whole sweep replays
/// bit-identically (asserted by `benches/fig_faults.rs` and pinned by
/// `golden_fig_faults.json`).
pub fn fig_faults(
    cfg: &ExperimentConfig,
    fault_rates_per_min: &[f64],
    horizon_s: f64,
) -> Vec<FigFaultsRow> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let speeds = server_speeds(cfg.cluster.servers, cfg.cluster.speed_min, cfg.cluster.speed_max);
    let mut table = TableWriter::new(
        "Faults — failure rate × migration policy: drops/tail/recovery per cell",
        &[
            "fail/min", "policy", "requests", "served", "lost", "migrated", "fails", "mean FID",
            "outage", "p99 e2e", "post p99", "drain s",
        ],
    )
    .with_csv("fig_faults");
    // A distinct seeded trace and script per failure rate: the sweep
    // covers distinct requests, while the policy columns inside a rate
    // share both (directly comparable). The rate × policy cells fan
    // out across `cfg.perf.threads` and *borrow* the shared trace,
    // speeds and script — no per-cell cloning.
    let inputs: Vec<(ArrivalTrace, FaultScript)> = fault_rates_per_min
        .iter()
        .enumerate()
        .map(|(i, &rate)| {
            let mut arrival = cfg.arrival;
            arrival.process = crate::config::ArrivalProcessKind::Poisson;
            arrival.horizon_s = horizon_s;
            let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed + i as u64);
            let faults = if rate <= 0.0 {
                FaultScript::empty()
            } else {
                let mtbf_s = 60.0 / rate;
                let servers = cfg.cluster.servers;
                FaultScript::random(
                    servers,
                    horizon_s,
                    mtbf_s,
                    cfg.faults.mttr_s,
                    cfg.seed + i as u64,
                )
            };
            (trace, faults)
        })
        .collect();
    let cells: Vec<(usize, MigrationPolicyKind)> = (0..fault_rates_per_min.len())
        .flat_map(|i| MigrationPolicyKind::all().into_iter().map(move |p| (i, p)))
        .collect();
    let rows: Vec<FigFaultsRow> = par_map(cfg.perf.threads, &cells, |_, &(i, policy)| {
        let (trace, faults) = &inputs[i];
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router: cfg.cluster.router,
            dynamic: DynamicConfig::from(&cfg.dynamic),
            faults,
            migration: policy,
            resume_transfer_s: cfg.migration.transfer_s,
        };
        let report =
            simulate_event_cluster(trace, &scheduler, &allocator, &delay, &quality, &event_cfg);
        let stats = report.fleet_stats();
        let rs = report.recovery_stats(cfg.dynamic.window_s);
        FigFaultsRow {
            fault_rate_per_min: fault_rates_per_min[i],
            policy,
            requests: trace.len(),
            served: report.served(),
            dropped: report.dropped(),
            lost_to_failure: report.lost_to_failure(),
            migrated: report.migrated(),
            failures: report.failures(),
            mean_quality: stats.mean_quality,
            outage_rate: stats.outage_rate,
            p99_e2e_s: stats.p99_e2e_s,
            post_failure_p99_s: rs.post_failure_p99_s,
            mean_time_to_drain_s: rs.mean_time_to_drain_s,
        }
    });
    for row in &rows {
        table.row(&[
            format!("{:.2}", row.fault_rate_per_min),
            row.policy.name().to_string(),
            row.requests.to_string(),
            row.served.to_string(),
            row.lost_to_failure.to_string(),
            row.migrated.to_string(),
            row.failures.to_string(),
            format!("{:.2}", row.mean_quality),
            format!("{:.3}", row.outage_rate),
            format!("{:.2}", row.p99_e2e_s),
            format!("{:.2}", row.post_failure_p99_s),
            format!("{:.2}", row.mean_time_to_drain_s),
        ]);
    }
    table.finish();
    rows
}

// ---------------------------------------------------------------------------
// Checkpoint figure (new) — migration policy showdown under scheduled
// mid-trace deaths, with checkpointed resumes in the comparison set
// ---------------------------------------------------------------------------

/// One migration-policy column of the checkpoint showdown.
#[derive(Debug, Clone, PartialEq)]
pub struct FigCheckpointRow {
    pub policy: MigrationPolicyKind,
    pub requests: usize,
    pub served: usize,
    pub lost_to_failure: usize,
    pub migrated: usize,
    /// Requests finished elsewhere from a dead server's checkpoint.
    pub resumed: usize,
    /// Denoising steps salvaged from dead servers' checkpoints.
    pub recovered_steps: u64,
    pub mean_quality: f64,
    pub p99_e2e_s: f64,
    /// Deadline-censored post-failure p99 (`metrics::RecoveryStats`).
    pub post_failure_p99_s: f64,
}

/// Run every migration policy on one seeded trace against one scheduled
/// fault script — the fastest server dies for good a third of the way
/// in, the second-fastest drops out for a window at the halfway mark —
/// so the columns are directly comparable. In-flight work dies with its
/// server under every policy; only `CheckpointOnDeath` salvages the
/// finished step boundaries and resumes the remainder elsewhere (after
/// `cfg.migration.transfer_s` of latent transfer), so on `served` and
/// on the censored post-failure p99 the expected order is checkpoint ≥
/// requeue ≥ none (asserted strictly at bench scale by
/// `benches/fig_checkpoint.rs`).
pub fn fig_checkpoint(cfg: &ExperimentConfig, horizon_s: f64) -> Vec<FigCheckpointRow> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let servers = cfg.cluster.servers.max(2);
    let speeds = server_speeds(servers, cfg.cluster.speed_min, cfg.cluster.speed_max);
    let mut arrival = cfg.arrival;
    arrival.process = crate::config::ArrivalProcessKind::Poisson;
    arrival.horizon_s = horizon_s;
    let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
    // Speeds ascend with the server id, so the highest ids carry the
    // largest routed share — killing them strands the most work. The
    // death instants sit away from the epoch grid so they land inside
    // executing batches, not on their boundaries.
    let script = FaultScript::scheduled(vec![
        DownInterval::new(servers - 1, horizon_s / 3.0 + 0.37, horizon_s + 60.0).unwrap(),
        DownInterval::new(servers - 2, horizon_s / 2.0 + 0.37, horizon_s / 2.0 + 40.37).unwrap(),
    ])
    .expect("scheduled checkpoint-showdown script is valid");
    let mut table = TableWriter::new(
        "Checkpoint — migration policy showdown under scheduled mid-trace deaths",
        &[
            "policy", "requests", "served", "lost", "migrated", "resumed", "steps",
            "mean FID", "p99 e2e", "post p99",
        ],
    )
    .with_csv("fig_checkpoint");
    let policies = MigrationPolicyKind::all();
    let rows: Vec<FigCheckpointRow> = par_map(cfg.perf.threads, &policies, |_, &policy| {
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router: cfg.cluster.router,
            dynamic: DynamicConfig::from(&cfg.dynamic),
            faults: &script,
            migration: policy,
            resume_transfer_s: cfg.migration.transfer_s,
        };
        let report =
            simulate_event_cluster(&trace, &scheduler, &allocator, &delay, &quality, &event_cfg);
        let stats = report.fleet_stats();
        let rs = report.recovery_stats(cfg.dynamic.window_s);
        FigCheckpointRow {
            policy,
            requests: trace.len(),
            served: report.served(),
            lost_to_failure: report.lost_to_failure(),
            migrated: report.migrated(),
            resumed: report.resumed_elsewhere(),
            recovered_steps: report.recovered_steps(),
            mean_quality: stats.mean_quality,
            p99_e2e_s: stats.p99_e2e_s,
            post_failure_p99_s: rs.post_failure_p99_s,
        }
    });
    for row in &rows {
        table.row(&[
            row.policy.name().to_string(),
            row.requests.to_string(),
            row.served.to_string(),
            row.lost_to_failure.to_string(),
            row.migrated.to_string(),
            row.resumed.to_string(),
            row.recovered_steps.to_string(),
            format!("{:.2}", row.mean_quality),
            format!("{:.2}", row.p99_e2e_s),
            format!("{:.2}", row.post_failure_p99_s),
        ]);
    }
    table.finish();
    rows
}

// ---------------------------------------------------------------------------
// Pipeline figure (new) — solve latency × mode × router view on the event
// engine
// ---------------------------------------------------------------------------

/// One (solve-latency, mode, router) cell of the pipeline sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FigPipelineRow {
    pub solve_latency_s: f64,
    pub mode: SolveMode,
    pub router: RouterKind,
    pub requests: usize,
    pub served: usize,
    pub mean_quality: f64,
    pub outage_rate: f64,
    /// Mean deadline-censored end-to-end delay (drops charge their
    /// deadline) — the drop-robust delay aggregate.
    pub mean_e2e_censored_s: f64,
    /// p99 of the deadline-censored end-to-end delays.
    pub p99_e2e_censored_s: f64,
    /// Fleet solve-overlap fraction: hidden solve time / total solve
    /// time over the whole run (0 at zero latency or synchronous).
    pub solve_overlap: f64,
}

/// Sweep the per-epoch solve latency across both lifecycle modes
/// (synchronous vs pipelined) and both fleet views (virtual-queue JSQ
/// vs the live-state router) on the configured fleet, under the
/// configured *bursty* arrival process through the zero-fault event
/// engine. Quantifies (a) how much solve latency pipelining hides and
/// what that saves end-to-end, and (b) the stale-virtual-queue vs
/// live-view routing gap. Each solve latency draws its own seeded
/// trace, shared by its four cells, so columns are directly
/// comparable; the whole sweep replays bit-identically (asserted by
/// `benches/fig_pipeline.rs` and pinned by `golden_fig_pipeline.json`).
pub fn fig_pipeline(
    cfg: &ExperimentConfig,
    solve_latencies: &[f64],
    horizon_s: f64,
) -> Vec<FigPipelineRow> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let speeds = server_speeds(cfg.cluster.servers, cfg.cluster.speed_min, cfg.cluster.speed_max);
    let routers = [RouterKind::JoinShortestQueue, RouterKind::LiveState];
    let mut table = TableWriter::new(
        "Pipeline — solve latency × mode × router view: delay/overlap per cell",
        &[
            "solve s", "mode", "router", "requests", "served", "mean FID", "outage",
            "mean e2e*", "p99 e2e*", "overlap",
        ],
    )
    .with_csv("fig_pipeline");
    // A distinct seeded trace per solve latency: the sweep covers
    // distinct requests, while the mode/router cells inside a latency
    // share one (directly comparable). The latency × mode × router
    // cells fan out across `cfg.perf.threads`, borrowing the shared
    // trace/speeds and the static all-alive script — no per-cell
    // cloning.
    let traces: Vec<ArrivalTrace> = (0..solve_latencies.len())
        .map(|i| {
            let mut arrival = cfg.arrival;
            arrival.process = crate::config::ArrivalProcessKind::Burst;
            arrival.horizon_s = horizon_s;
            ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed + i as u64)
        })
        .collect();
    let cells: Vec<(usize, SolveMode, RouterKind)> = (0..solve_latencies.len())
        .flat_map(|i| {
            SolveMode::all()
                .into_iter()
                .flat_map(move |mode| routers.into_iter().map(move |router| (i, mode, router)))
        })
        .collect();
    let rows: Vec<FigPipelineRow> = par_map(cfg.perf.threads, &cells, |_, &(i, mode, router)| {
        let latency = solve_latencies[i];
        let trace = &traces[i];
        let mut dynamic = DynamicConfig::from(&cfg.dynamic);
        dynamic.solve_latency_s = latency;
        dynamic.solve_mode = mode;
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router,
            dynamic,
            faults: &NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        let report =
            simulate_event_cluster(trace, &scheduler, &allocator, &delay, &quality, &event_cfg);
        let stats = report.fleet_stats();
        let total_solve = report.total_epochs() as f64 * latency;
        let solve_overlap =
            if total_solve > 0.0 { report.solve_hidden_s() / total_solve } else { 0.0 };
        FigPipelineRow {
            solve_latency_s: latency,
            mode,
            router,
            requests: trace.len(),
            served: report.served(),
            mean_quality: stats.mean_quality,
            outage_rate: stats.outage_rate,
            mean_e2e_censored_s: report.mean_e2e_censored_s(),
            p99_e2e_censored_s: report.e2e_censored_percentile(99.0),
            solve_overlap,
        }
    });
    for row in &rows {
        table.row(&[
            format!("{:.2}", row.solve_latency_s),
            row.mode.name().to_string(),
            row.router.name().to_string(),
            row.requests.to_string(),
            row.served.to_string(),
            format!("{:.2}", row.mean_quality),
            format!("{:.3}", row.outage_rate),
            format!("{:.2}", row.mean_e2e_censored_s),
            format!("{:.2}", row.p99_e2e_censored_s),
            format!("{:.3}", row.solve_overlap),
        ]);
    }
    table.finish();
    println!("(* deadline-censored: dropped requests charge their relative deadline)");
    rows
}

// ---------------------------------------------------------------------------
// Generation-cache figure (new) — Zipf skew × capacity × router on the event
// engine
// ---------------------------------------------------------------------------

/// One (Zipf `s`, per-server capacity, router) cell of the cache sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FigCacheRow {
    pub zipf_s: f64,
    pub capacity: usize,
    pub router: RouterKind,
    pub requests: usize,
    pub served: usize,
    /// Requests answered straight from a server cache.
    pub served_from_cache: usize,
    /// Fleet hit rate: hits / (hits + misses) over marked lookups.
    pub hit_rate: f64,
    /// Model catalog loads/swaps charged across the fleet.
    pub swaps: u64,
    pub mean_quality: f64,
    pub outage_rate: f64,
    /// p99 of the deadline-censored end-to-end delays.
    pub p99_e2e_censored_s: f64,
}

/// Sweep prompt-popularity skew (Zipf `s`) × per-server cache capacity
/// × router (virtual-queue JSQ vs the cache-aware policy) on the
/// configured fleet through the zero-fault event engine, caches
/// enabled in every cell. Each skew draws its own seeded marked trace
/// over a 64-prompt, two-model universe, shared by its capacity ×
/// router cells so columns are directly comparable. The paper-level
/// claim — content-addressed reuse plus placement-aware dispatch
/// strictly beats load-only dispatch on served quality and on the
/// censored p99 once popularity is skewed — is asserted at bench scale
/// by `benches/fig_cache.rs` (which also pins bit-identical replay and
/// writes `BENCH_pr9.json`).
pub fn fig_cache(
    cfg: &ExperimentConfig,
    zipf_exponents: &[f64],
    capacities: &[usize],
    horizon_s: f64,
) -> Vec<FigCacheRow> {
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let speeds = server_speeds(cfg.cluster.servers, cfg.cluster.speed_min, cfg.cluster.speed_max);
    let routers = [RouterKind::JoinShortestQueue, RouterKind::CacheAware];
    let mut table = TableWriter::new(
        "Generation cache — Zipf skew × capacity × router: reuse per cell",
        &[
            "zipf s", "cap", "router", "requests", "served", "cached", "hit rate", "swaps",
            "mean FID", "outage", "p99 e2e*",
        ],
    )
    .with_csv("fig_cache");
    let traces: Vec<ArrivalTrace> = (0..zipf_exponents.len())
        .map(|i| {
            let mut arrival = cfg.arrival;
            arrival.process = crate::config::ArrivalProcessKind::Poisson;
            arrival.horizon_s = horizon_s;
            arrival.prompt_universe = 64;
            arrival.zipf_s = zipf_exponents[i];
            arrival.models = 2;
            ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed + i as u64)
        })
        .collect();
    let cells: Vec<(usize, usize, RouterKind)> = (0..zipf_exponents.len())
        .flat_map(|i| {
            capacities
                .iter()
                .flat_map(move |&cap| routers.into_iter().map(move |router| (i, cap, router)))
        })
        .collect();
    let rows: Vec<FigCacheRow> = par_map(cfg.perf.threads, &cells, |_, &(i, capacity, router)| {
        let trace = &traces[i];
        let mut dynamic = DynamicConfig::from(&cfg.dynamic);
        dynamic.cache = CacheSettings { enabled: true, capacity, ..cfg.cache };
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router,
            dynamic,
            faults: &NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        let report =
            simulate_event_cluster(trace, &scheduler, &allocator, &delay, &quality, &event_cfg);
        let stats = report.fleet_stats();
        let cs = report.cache_stats();
        FigCacheRow {
            zipf_s: zipf_exponents[i],
            capacity,
            router,
            requests: trace.len(),
            served: report.served(),
            served_from_cache: report.served_from_cache(),
            hit_rate: cs.hit_rate(),
            swaps: cs.swaps,
            mean_quality: stats.mean_quality,
            outage_rate: stats.outage_rate,
            p99_e2e_censored_s: report.e2e_censored_percentile(99.0),
        }
    });
    for row in &rows {
        table.row(&[
            format!("{:.2}", row.zipf_s),
            row.capacity.to_string(),
            row.router.name().to_string(),
            row.requests.to_string(),
            row.served.to_string(),
            row.served_from_cache.to_string(),
            format!("{:.3}", row.hit_rate),
            row.swaps.to_string(),
            format!("{:.2}", row.mean_quality),
            format!("{:.3}", row.outage_rate),
            format!("{:.2}", row.p99_e2e_censored_s),
        ]);
    }
    table.finish();
    println!("(* deadline-censored: dropped requests charge their relative deadline)");
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper();
        cfg.pso.particles = 6;
        cfg.pso.iterations = 6;
        cfg.pso.patience = 3;
        cfg
    }

    #[test]
    fn fig2b_shape_proposed_wins_and_single_collapses() {
        let cfg = quick_cfg();
        let rows = fig2b(&cfg, &[5, 20, 35], 1);
        for (k, vals) in &rows {
            let proposed = vals[0];
            // proposed is the minimum of all schemes (within tolerance)
            for (i, v) in vals.iter().enumerate() {
                assert!(
                    proposed <= v * 1.05 + 1e-9,
                    "K={k}: scheme {i} beats proposed ({v} < {proposed})"
                );
            }
        }
        // single-instance degrades much faster with K than proposed
        let first = &rows[0].1;
        let last = &rows[rows.len() - 1].1;
        let proposed_growth = last[0] / first[0];
        let single_growth = last[1] / first[1].max(1e-9);
        assert!(
            single_growth > proposed_growth,
            "single-instance should degrade faster: {single_growth} vs {proposed_growth}"
        );
    }

    #[test]
    fn fig2c_shape_quality_improves_with_looser_min_deadline() {
        let cfg = quick_cfg();
        let rows = fig2c(&cfg, &[3.0, 11.0, 19.0], 1);
        // proposed mean FID is non-increasing as tau_min loosens
        let proposed: Vec<f64> = rows.iter().map(|r| r.1[0]).collect();
        assert!(
            proposed.windows(2).all(|w| w[1] <= w[0] * 1.05),
            "proposed not improving: {proposed:?}"
        );
    }

    #[test]
    fn fig2a_all_services_meet_deadlines() {
        let cfg = quick_cfg();
        let rows = fig2a(&cfg);
        assert_eq!(rows.len(), 10);
        for (id, deadline, _gen, _tx, e2e, steps) in rows {
            assert!(steps > 0, "svc {id} outage");
            assert!(e2e <= deadline + 1e-9, "svc {id} misses deadline");
        }
    }

    #[test]
    fn fig3_load_degrades_quality_and_is_deterministic() {
        let cfg = ExperimentConfig::paper();
        let lambdas = [0.5, 8.0];
        let rows = fig3_dynamic(&cfg, &lambdas, 30.0);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().map(|r| r.requests).sum::<usize>() > 100);
        // overload must cost quality (mean FID grows with λ)
        assert!(
            rows[1].mean_quality > rows[0].mean_quality,
            "λ=8 quality {} vs λ=0.5 {}",
            rows[1].mean_quality,
            rows[0].mean_quality
        );
        assert!(rows[1].outage_rate >= rows[0].outage_rate);
        // bit-identical replay
        assert_eq!(rows, fig3_dynamic(&cfg, &lambdas, 30.0));
    }

    #[test]
    fn fig_cluster_covers_all_routers_and_replays() {
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.servers = 3;
        cfg.cluster.speed_min = 0.5;
        cfg.cluster.speed_max = 1.5;
        let rows = fig_cluster(&cfg, &[1.0, 6.0], 30.0);
        assert_eq!(rows.len(), 2 * RouterKind::all().len());
        for row in &rows {
            assert!(row.served <= row.requests);
            assert!((0.0..=1.0).contains(&row.outage_rate));
            assert!(row.max_share >= 1.0 / 3.0 - 1e-9, "shares must cover the trace");
        }
        // a router column is comparable across λ: same trace per λ
        assert_eq!(rows[0].requests, rows[1].requests);
        // bit-identical replay
        assert_eq!(rows, fig_cluster(&cfg, &[1.0, 6.0], 30.0));
    }

    #[test]
    fn fig_faults_covers_all_policies_and_replays() {
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.servers = 3;
        cfg.cluster.speed_min = 0.5;
        cfg.cluster.speed_max = 1.5;
        cfg.arrival.rate_hz = 4.0;
        let rows = fig_faults(&cfg, &[0.0, 2.0], 30.0);
        assert_eq!(rows.len(), 2 * MigrationPolicyKind::all().len());
        for row in &rows {
            assert_eq!(row.served + row.dropped, row.requests);
            assert!((0.0..=1.0).contains(&row.outage_rate));
            assert!(row.lost_to_failure <= row.dropped);
        }
        // zero fault rate: none and requeue-on-death have no faults to
        // react to, so their columns are identical and nothing is lost
        // or migrated (steal-when-idle reacts to idleness, not faults,
        // and may legitimately move work even fault-free)
        let zero: Vec<&FigFaultsRow> =
            rows.iter().filter(|r| r.fault_rate_per_min == 0.0).collect();
        for r in &zero {
            assert_eq!(r.failures, 0);
            assert_eq!(r.lost_to_failure, 0);
            if r.policy != MigrationPolicyKind::StealWhenIdle {
                assert_eq!(r.migrated, 0);
                assert_eq!(r.served, zero[0].served);
                assert_eq!(r.mean_quality.to_bits(), zero[0].mean_quality.to_bits());
            }
        }
        // the faulted rate actually injects failures
        assert!(rows.iter().any(|r| r.fault_rate_per_min > 0.0 && r.failures > 0));
        // bit-identical replay
        assert_eq!(rows, fig_faults(&cfg, &[0.0, 2.0], 30.0));
    }

    #[test]
    fn fig_checkpoint_policy_order_and_replays() {
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.servers = 3;
        cfg.cluster.speed_min = 0.5;
        cfg.cluster.speed_max = 1.5;
        cfg.arrival.rate_hz = 4.0;
        let rows = fig_checkpoint(&cfg, 60.0);
        assert_eq!(rows.len(), MigrationPolicyKind::all().len());
        let by = |p: MigrationPolicyKind| rows.iter().find(|r| r.policy == p).unwrap();
        let none = by(MigrationPolicyKind::None);
        let requeue = by(MigrationPolicyKind::RequeueOnDeath);
        let checkpoint = by(MigrationPolicyKind::Checkpoint);
        // the scheduled deaths must strand work without migration
        assert!(none.lost_to_failure > 0, "deaths stranded nothing: {none:?}");
        // only the checkpoint column resumes in-flight work
        for r in &rows {
            assert_eq!(r.requests, trace_len(&rows));
            assert!(r.served + r.lost_to_failure <= r.requests);
            if r.policy != MigrationPolicyKind::Checkpoint {
                assert_eq!(r.resumed, 0, "{r:?}");
                assert_eq!(r.recovered_steps, 0, "{r:?}");
            }
        }
        // served dominance: checkpoint >= requeue >= none (strictness
        // is asserted at bench scale by benches/fig_checkpoint.rs)
        assert!(
            checkpoint.served >= requeue.served && requeue.served >= none.served,
            "served order violated: checkpoint {} requeue {} none {}",
            checkpoint.served,
            requeue.served,
            none.served
        );
        assert!(
            checkpoint.post_failure_p99_s <= requeue.post_failure_p99_s,
            "checkpoint post-failure p99 {} worse than requeue {}",
            checkpoint.post_failure_p99_s,
            requeue.post_failure_p99_s
        );
        // bit-identical replay
        assert_eq!(rows, fig_checkpoint(&cfg, 60.0));
    }

    fn trace_len(rows: &[FigCheckpointRow]) -> usize {
        rows[0].requests
    }

    #[test]
    fn fig_pipeline_covers_cells_hides_latency_and_replays() {
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.servers = 2;
        cfg.cluster.speed_min = 0.5;
        cfg.cluster.speed_max = 1.5;
        cfg.arrival.rate_hz = 3.0;
        cfg.arrival.burst_rate_hz = 12.0;
        let rows = fig_pipeline(&cfg, &[0.0, 0.3], 30.0);
        assert_eq!(rows.len(), 2 * SolveMode::all().len() * 2);
        for row in &rows {
            assert!(row.served <= row.requests);
            assert!((0.0..=1.0).contains(&row.outage_rate));
            assert!((0.0..=1.0).contains(&row.solve_overlap));
            if row.mode == SolveMode::Synchronous || row.solve_latency_s == 0.0 {
                assert_eq!(row.solve_overlap, 0.0, "{row:?}");
            }
        }
        // zero solve latency: the two modes are bit-identical per router
        let zero: Vec<&FigPipelineRow> =
            rows.iter().filter(|r| r.solve_latency_s == 0.0).collect();
        for r in &zero {
            let twin = zero
                .iter()
                .find(|t| t.router == r.router && t.mode != r.mode)
                .expect("both modes present");
            assert_eq!(r.served, twin.served);
            assert_eq!(r.mean_e2e_censored_s.to_bits(), twin.mean_e2e_censored_s.to_bits());
            assert_eq!(r.mean_quality.to_bits(), twin.mean_quality.to_bits());
        }
        // nonzero latency under burst load: pipelining hides some solve
        // time and the hidden time buys delay
        let find = |mode: SolveMode, router: RouterKind| {
            rows.iter()
                .find(|r| r.solve_latency_s > 0.0 && r.mode == mode && r.router == router)
                .unwrap()
        };
        for router in [RouterKind::JoinShortestQueue, RouterKind::LiveState] {
            let pipelined = find(SolveMode::Pipelined, router);
            let sync = find(SolveMode::Synchronous, router);
            assert!(pipelined.solve_overlap > 0.0, "{router:?}: nothing hidden");
            assert!(
                pipelined.mean_e2e_censored_s < sync.mean_e2e_censored_s,
                "{router:?}: pipelined {} vs synchronous {}",
                pipelined.mean_e2e_censored_s,
                sync.mean_e2e_censored_s
            );
        }
        // bit-identical replay
        assert_eq!(rows, fig_pipeline(&cfg, &[0.0, 0.3], 30.0));
    }

    #[test]
    fn fig_cache_covers_cells_hits_at_high_skew_and_replays() {
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.servers = 2;
        cfg.cluster.speed_min = 0.5;
        cfg.cluster.speed_max = 1.5;
        cfg.arrival.rate_hz = 5.0;
        let rows = fig_cache(&cfg, &[0.6, 1.8], &[8, 64], 30.0);
        assert_eq!(rows.len(), 2 * 2 * 2);
        for row in &rows {
            assert!(row.served <= row.requests);
            assert!(row.served_from_cache <= row.served);
            assert!((0.0..=1.0).contains(&row.hit_rate));
            assert!((0.0..=1.0).contains(&row.outage_rate));
            assert!(row.swaps > 0, "two models on the default single slot must swap: {row:?}");
        }
        // High skew with a roomy cache must actually reuse content.
        let hot = rows
            .iter()
            .find(|r| r.zipf_s == 1.8 && r.capacity == 64 && r.router == RouterKind::CacheAware)
            .unwrap();
        assert!(hot.served_from_cache > 0, "{hot:?}");
        assert!(hot.hit_rate > 0.0, "{hot:?}");
        // bit-identical replay (strict JSQ-dominance is asserted at
        // bench scale by benches/fig_cache.rs)
        assert_eq!(rows, fig_cache(&cfg, &[0.6, 1.8], &[8, 64], 30.0));
    }

    #[test]
    fn fig1b_monotone_measured_curve() {
        let cfg = ExperimentConfig::paper();
        if !cfg.quality_json_path().exists() {
            return;
        }
        let rows = fig1b(&cfg);
        assert!(rows.len() >= 5);
        // measured FD decreases with steps
        for w in rows.windows(2) {
            assert!(w[1].1 <= w[0].1 * 1.05, "curve not decreasing: {rows:?}");
        }
    }
}
