//! Aligned-table printing + CSV mirroring for benchmark results.

use std::io::Write;
use std::path::{Path, PathBuf};

/// Collects rows, prints an aligned table, writes a CSV copy.
pub struct TableWriter {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    csv_path: Option<PathBuf>,
}

impl TableWriter {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            csv_path: None,
        }
    }

    /// Also mirror to `results/<name>.csv` under the repo root.
    pub fn with_csv(mut self, name: &str) -> Self {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
        let _ = std::fs::create_dir_all(&dir);
        self.csv_path = Some(dir.join(format!("{name}.csv")));
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    /// Print the table and flush the CSV. Returns the rendered text.
    pub fn finish(self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        print!("{out}");
        if let Some(path) = &self.csv_path {
            let mut csv = String::new();
            csv.push_str(&self.headers.join(","));
            csv.push('\n');
            for row in &self.rows {
                csv.push_str(&row.join(","));
                csv.push('\n');
            }
            if let Ok(mut f) = std::fs::File::create(path) {
                let _ = f.write_all(csv.as_bytes());
                println!("(csv: {})", path.display());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableWriter::new("demo", &["K", "mean FID"]);
        t.row(&["5".into(), "31.4".into()]);
        t.row(&["40".into(), "123.45".into()]);
        let text = t.finish();
        assert!(text.contains("demo"));
        assert!(text.contains("mean FID"));
        assert!(text.contains("123.45"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn rejects_wrong_arity() {
        let mut t = TableWriter::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_mirror_written() {
        let mut t = TableWriter::new("csv test", &["x"]).with_csv("_test_table");
        t.row(&["1".into()]);
        t.finish();
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("results/_test_table.csv");
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "x\n1\n");
        let _ = std::fs::remove_file(path);
    }
}
