//! Fleet-size routing sweep behind `BENCH_pr10.json`: indexed dispatch
//! versus the O(N) reference scan across N ∈ {4, 64, 512, 4096} for
//! every routing policy that has an index fast path.
//!
//! Two things are measured per (N, router) cell, on the same arrival
//! trace:
//!  * **decision identity** — the per-arrival assignment from
//!    [`route_arrivals`] (indexed) is compared element-for-element to
//!    [`route_trace_scan`] (the executable specification), and a panel
//!    of `route_resume` probes (fresh, small and saturating step
//!    credits) is cross-checked the same way;
//!  * **work** — the index's deterministic op counters
//!    ([`IndexStats`](crate::routing::IndexStats): queries, entries
//!    examined, heap settles), which
//!    are what the sub-linearity gate in `benches/fig_fleet.rs` reads
//!    (wall-clock is recorded for the curious but never gated — CI
//!    machines are noisy).

use std::time::Instant;

use crate::cache::CacheSettings;
use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use crate::delay::BatchDelayModel;
use crate::routing::{
    route_arrivals, route_trace_scan, FleetIndex, RouteContext, Router, RouterKind, ServerState,
};
use crate::sim::server_speeds;
use crate::trace::ArrivalTrace;

/// One (fleet size, router) cell of the sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FigFleetRow {
    pub n: usize,
    pub router: RouterKind,
    /// Routed arrivals (identical across cells — one shared trace).
    pub arrivals: usize,
    /// Indexed assignment == scan assignment, element for element.
    pub identical: bool,
    /// Every `route_resume` probe picked the scan's server.
    pub resume_identical: bool,
    /// [`IndexStats`](crate::routing::IndexStats) totals over the
    /// indexed pass (plus probes).
    pub queries: u64,
    pub examined: u64,
    pub settles: u64,
    /// (examined + settles) / queries — the gated cost proxy.
    pub ops_per_arrival: f64,
    /// FNV-1a over the indexed assignment — replay fingerprint.
    pub assignment_fnv: u64,
    /// Wall-clock, informational only (never gated).
    pub indexed_ms: f64,
    pub scan_ms: f64,
}

impl FigFleetRow {
    /// The deterministic projection of the row — everything except
    /// wall-clock. Bitwise replay is gated on this.
    pub fn key(&self) -> (usize, &'static str, usize, bool, bool, u64, u64, u64, u64) {
        (
            self.n,
            self.router.name(),
            self.arrivals,
            self.identical,
            self.resume_identical,
            self.queries,
            self.examined,
            self.settles,
            self.assignment_fnv,
        )
    }
}

fn fnv1a(values: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in values {
        for b in (v as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// A marked trace shared by every cell: prompt marks ride along so the
/// cache-aware router's shadow machinery is exercised; the virtual-view
/// policies ignore them.
fn sweep_trace(max_requests: usize, seed: u64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: 40.0,
        burst_rate_hz: 40.0,
        period_s: 60.0,
        duty: 0.5,
        // 4x headroom over the cap so the trace always fills it.
        horizon_s: max_requests as f64 / 10.0,
        max_requests,
        prompt_universe: 128,
        zipf_s: 1.2,
        models: 4,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
}

fn build(router: RouterKind, delay: BatchDelayModel) -> Box<dyn Router> {
    let cache = CacheSettings { enabled: true, capacity: 16, ..CacheSettings::default() };
    router.build_with_cache(delay, cache)
}

/// Run the sweep: every `fleet_sizes` × `routers` cell on one shared
/// trace of `max_requests` arrivals. Deterministic up to wall-clock.
pub fn fig_fleet(
    fleet_sizes: &[usize],
    routers: &[RouterKind],
    max_requests: usize,
    seed: u64,
) -> Vec<FigFleetRow> {
    let trace = sweep_trace(max_requests, seed);
    let delay = BatchDelayModel::paper();
    let ctx = RouteContext {
        total_bandwidth_hz: trace.total_bandwidth_hz,
        content_bits: trace.content_bits,
    };
    let mut rows = Vec::with_capacity(fleet_sizes.len() * routers.len());
    for &n in fleet_sizes {
        let speeds = server_speeds(n, 0.5, 2.0);
        for &router in routers {
            // Separate router instances per pass: stateful policies
            // (the cache-aware shadow) must evolve independently.
            let mut indexed_router = build(router, delay);
            let mut scan_router = build(router, delay);

            let mut fleet = ServerState::fleet(&speeds);
            let mut index = FleetIndex::new(&fleet);
            let mut assignment = Vec::with_capacity(trace.len());
            let t0 = Instant::now();
            route_arrivals(
                &trace.arrivals,
                &mut fleet,
                indexed_router.as_mut(),
                &delay,
                &ctx,
                &mut index,
                &mut assignment,
            );
            let indexed_ms = t0.elapsed().as_secs_f64() * 1e3;

            let mut scan_fleet = ServerState::fleet(&speeds);
            let t0 = Instant::now();
            let scan_assignment =
                route_trace_scan(&trace, &mut scan_fleet, scan_router.as_mut(), &delay);
            let scan_ms = t0.elapsed().as_secs_f64() * 1e3;

            let identical = assignment == scan_assignment;

            // Resume probes: a late arrival re-entering the router with
            // a step credit (0 = fresh dispatch must match `route`;
            // 7 = partial; 500 = near-saturating). Both passes left
            // their fleets in identical states iff `identical`, so the
            // probe comparison is meaningful exactly then.
            let mut resume_identical = true;
            if let Some(last) = trace.arrivals.last() {
                for done in [0u32, 7, 500] {
                    let probe = *last;
                    let r = indexed_router.as_mut();
                    let via_index = r.route_resume_indexed(&probe, done, &fleet, &ctx, &mut index);
                    let via_scan = scan_router.route_resume(&probe, done, &scan_fleet, &ctx);
                    resume_identical &= via_index == via_scan;
                }
            }

            let stats = index.stats;
            let ops = (stats.examined + stats.settles) as f64 / (stats.queries.max(1)) as f64;
            rows.push(FigFleetRow {
                n,
                router,
                arrivals: trace.len(),
                identical,
                resume_identical,
                queries: stats.queries,
                examined: stats.examined,
                settles: stats.settles,
                ops_per_arrival: ops,
                assignment_fnv: fnv1a(&assignment),
                indexed_ms,
                scan_ms,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_is_identical_and_deterministic() {
        let kinds =
            [RouterKind::JoinShortestQueue, RouterKind::QualityAware, RouterKind::CacheAware];
        let a = fig_fleet(&[3, 9], &kinds, 200, 5);
        assert_eq!(a.len(), 6);
        for row in &a {
            assert!(row.identical, "{} n={}", row.router.name(), row.n);
            assert!(row.resume_identical, "{} n={}", row.router.name(), row.n);
            assert!(row.queries >= 200, "{} n={}", row.router.name(), row.n);
        }
        let b = fig_fleet(&[3, 9], &kinds, 200, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.key(), y.key());
        }
    }
}
