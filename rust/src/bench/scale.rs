//! Scale harness — the 10⁷-request λ sweep behind `BENCH_pr6.json`.
//!
//! Each cell streams a Poisson arrival process straight through the
//! dynamic engine (`simulate_dynamic_streaming`): arrivals are
//! generated lazily and every resolved request folds into a GK
//! quantile sketch, so the resident state is the epoch queue plus the
//! sketch — flat in the request count. Three properties are asserted
//! by the callers, not just reported:
//!
//! * **memory flatness** — the sketch support must stay under the
//!   O((1/eps)·log(eps·n)) bound at every cell size;
//! * **agreement** — streaming percentiles must sit within
//!   `⌈eps·n⌉ + 1` ranks of the exact sorted-vector percentiles on
//!   the same arrival stream;
//! * **bit-identity** — re-running a cell reproduces every output
//!   float bit-for-bit (the sketch is deterministic: no randomness,
//!   no clocks, batch-merged inserts).
//!
//! Two entry points: `benches/fig_scale.rs` (CI size, 10⁵ per cell by
//! default; `FIG_SCALE_FULL=1` runs the full 10⁷) and `cargo test`
//! (tiny sizes through the unit tests below).

use std::path::Path;
use std::time::Instant;

use crate::bandwidth::EqualAllocator;
use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use crate::delay::BatchDelayModel;
use crate::metrics::OutcomeAccumulator;
use crate::quality::PowerLawQuality;
use crate::scheduler::Stacking;
use crate::sim::{simulate_dynamic, simulate_dynamic_streaming, DynamicConfig};
use crate::trace::{ArrivalStream, ArrivalTrace};

/// Sweep knobs.
#[derive(Debug, Clone)]
pub struct ScaleOptions {
    /// Target arrivals per λ cell (the horizon is sized as
    /// `requests / λ`, so the Poisson draw lands near the target).
    pub requests_per_cell: usize,
    /// Arrival rates swept.
    pub lambdas: Vec<f64>,
    /// Sketch rank-error fraction, in (0, 0.5).
    pub sketch_eps: f64,
    pub seed: u64,
}

impl Default for ScaleOptions {
    fn default() -> Self {
        Self {
            requests_per_cell: 100_000,
            lambdas: vec![2.0, 6.0, 12.0],
            sketch_eps: 0.01,
            seed: 2025,
        }
    }
}

/// One λ cell's streamed summary.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub rate_hz: f64,
    /// Arrivals actually generated (Poisson draw around the target).
    pub requests: usize,
    pub served: usize,
    pub outage_rate: f64,
    pub p50_e2e_s: f64,
    pub p95_e2e_s: f64,
    pub p99_e2e_s: f64,
    pub mean_wait_s: f64,
    pub wall_s: f64,
    /// Sketch footprint after the run — every value still retained.
    pub support: usize,
    /// The O((1/eps)·log(eps·n)) bound `support` must stay under.
    pub support_bound: usize,
    pub peak_queue_depth: usize,
}

/// Loose but safe form of the GK footprint bound — the same formula
/// `util::stats` asserts in its own growth test. Flat for practical
/// purposes: doubling `n` adds one log step, never a linear term.
pub fn support_bound(eps: f64, n: u64) -> usize {
    (12.0 / eps * (2.0 * eps * n as f64 + 4.0).log2()).ceil() as usize + 64
}

/// The cell's arrival settings: Poisson at `rate_hz`, horizon sized to
/// hit the per-cell request target.
fn cell_arrival(cfg: &ExperimentConfig, opts: &ScaleOptions, rate_hz: f64) -> ArrivalSettings {
    let mut arrival = cfg.arrival;
    arrival.process = ArrivalProcessKind::Poisson;
    arrival.rate_hz = rate_hz;
    arrival.horizon_s = opts.requests_per_cell as f64 / rate_hz;
    arrival
}

/// Stream one λ cell through the dynamic engine without ever
/// materializing the trace.
pub fn run_cell(cfg: &ExperimentConfig, opts: &ScaleOptions, rate_hz: f64) -> ScaleRow {
    let arrival = cell_arrival(cfg, opts, rate_hz);
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let dyn_cfg = DynamicConfig::from(&cfg.dynamic);
    let stream = ArrivalStream::new(&cfg.scenario, &arrival, opts.seed);
    let (bw, bits) = (stream.total_bandwidth_hz(), stream.content_bits());
    let start = Instant::now();
    let report = simulate_dynamic_streaming(
        stream,
        bw,
        bits,
        &scheduler,
        &EqualAllocator,
        &delay,
        &quality,
        &dyn_cfg,
        OutcomeAccumulator::streaming(opts.sketch_eps),
    );
    let wall_s = start.elapsed().as_secs_f64();
    let stats = report.stats();
    ScaleRow {
        rate_hz,
        requests: report.count(),
        served: report.served(),
        outage_rate: stats.outage_rate,
        p50_e2e_s: stats.p50_e2e_s,
        p95_e2e_s: stats.p95_e2e_s,
        p99_e2e_s: stats.p99_e2e_s,
        mean_wait_s: stats.mean_wait_s,
        wall_s,
        support: report.accumulator.support_len(),
        support_bound: support_bound(opts.sketch_eps, report.count() as u64),
        peak_queue_depth: report.peak_queue_depth,
    }
}

/// The full sweep. Callers treat `support > support_bound` in any row
/// as a hard failure — it means per-request state leaked into the
/// "streaming" path.
pub fn run_scale(cfg: &ExperimentConfig, opts: &ScaleOptions) -> Vec<ScaleRow> {
    opts.lambdas.iter().map(|&l| run_cell(cfg, opts, l)).collect()
}

/// Streaming-vs-exact agreement on one materialized cell: the scalar
/// tallies must match exactly, and every reported percentile must be
/// an actually-served delay whose rank sits within `⌈eps·n⌉ + 1` of
/// the exact target rank. Returns the worst observed rank distance.
pub fn verify_agreement(
    cfg: &ExperimentConfig,
    opts: &ScaleOptions,
    rate_hz: f64,
) -> Result<u64, String> {
    let arrival = cell_arrival(cfg, opts, rate_hz);
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let dyn_cfg = DynamicConfig::from(&cfg.dynamic);
    let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, opts.seed);
    let exact = simulate_dynamic(&trace, &scheduler, &EqualAllocator, &delay, &quality, &dyn_cfg);
    let streamed = simulate_dynamic_streaming(
        trace.arrivals.iter().copied(),
        trace.total_bandwidth_hz,
        trace.content_bits,
        &scheduler,
        &EqualAllocator,
        &delay,
        &quality,
        &dyn_cfg,
        OutcomeAccumulator::streaming(opts.sketch_eps),
    );
    if streamed.count() != exact.outcomes.len() || streamed.served() != exact.served() {
        return Err(format!(
            "scalar tallies diverged: streaming {}/{} vs exact {}/{}",
            streamed.served(),
            streamed.count(),
            exact.served(),
            exact.outcomes.len()
        ));
    }
    let mut sorted: Vec<f64> = exact
        .outcomes
        .iter()
        .filter(|o| o.disposition.is_served())
        .map(|o| o.e2e_s)
        .collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if sorted.is_empty() {
        return Err("no served requests — the cell cannot exercise the sketch".into());
    }
    let n = sorted.len() as u64;
    let budget = (opts.sketch_eps * n as f64).ceil() as u64 + 1;
    let stats = streamed.stats();
    let mut worst = 0u64;
    for (p, v) in [(50.0, stats.p50_e2e_s), (95.0, stats.p95_e2e_s), (99.0, stats.p99_e2e_s)] {
        let target = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        // the value's rank interval in the exact sorted delays
        let lo = sorted.partition_point(|&x| x < v) as u64 + 1;
        let hi = sorted.partition_point(|&x| x <= v) as u64;
        if hi < lo {
            return Err(format!("p{p}: sketch value {v} is not a served sample"));
        }
        let dist = if target < lo {
            lo - target
        } else if target > hi {
            target - hi
        } else {
            0
        };
        if dist > budget {
            return Err(format!(
                "p{p}: rank {lo}..{hi} sits {dist} ranks from target {target} (budget {budget})"
            ));
        }
        worst = worst.max(dist);
    }
    Ok(worst)
}

/// Serialize the sweep as the tracked `BENCH_pr6.json` document.
pub fn scale_json(rows: &[ScaleRow], opts: &ScaleOptions) -> String {
    let mut cells = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            cells.push_str(",\n");
        }
        cells.push_str(&format!(
            "    {{\"rate_hz\": {}, \"requests\": {}, \"served\": {}, \"outage_rate\": {:.6}, \
             \"p50_e2e_s\": {:.6}, \"p95_e2e_s\": {:.6}, \"p99_e2e_s\": {:.6}, \
             \"wall_s\": {:.3}, \"support\": {}, \"support_bound\": {}, \
             \"peak_queue_depth\": {}}}",
            r.rate_hz,
            r.requests,
            r.served,
            r.outage_rate,
            r.p50_e2e_s,
            r.p95_e2e_s,
            r.p99_e2e_s,
            r.wall_s,
            r.support,
            r.support_bound,
            r.peak_queue_depth
        ));
    }
    format!(
        "{{\n  \"pr\": 6,\n  \"requests_per_cell\": {},\n  \"sketch_eps\": {},\n  \"seed\": {},\n  \"cells\": [\n{}\n  ]\n}}\n",
        opts.requests_per_cell,
        opts.sketch_eps,
        opts.seed,
        cells
    )
}

/// Write `BENCH_pr6.json`.
pub fn write_scale_json(
    path: &Path,
    rows: &[ScaleRow],
    opts: &ScaleOptions,
) -> std::io::Result<()> {
    std::fs::write(path, scale_json(rows, opts))
}

/// The tracked trajectory location, `<repo root>/BENCH_pr6.json` —
/// derived from the compile-time checkout like `perf::default_bench_path`,
/// so only callers that run where they were built should use it.
pub fn default_scale_path() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr6.json")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ScaleOptions {
        ScaleOptions {
            requests_per_cell: 600,
            lambdas: vec![4.0, 8.0],
            sketch_eps: 0.02,
            seed: 11,
        }
    }

    #[test]
    fn sweep_rows_obey_the_support_bound_and_replay_bitwise() {
        let cfg = ExperimentConfig::paper();
        let opts = tiny_opts();
        let rows = run_scale(&cfg, &opts);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.requests > 0 && r.served > 0, "cell λ={} served nothing", r.rate_hz);
            assert!(
                r.support <= r.support_bound,
                "λ={}: support {} exceeds flatness bound {}",
                r.rate_hz,
                r.support,
                r.support_bound
            );
        }
        let again = run_scale(&cfg, &opts);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.requests, b.requests);
            assert_eq!(a.served, b.served);
            assert_eq!(a.p50_e2e_s.to_bits(), b.p50_e2e_s.to_bits());
            assert_eq!(a.p95_e2e_s.to_bits(), b.p95_e2e_s.to_bits());
            assert_eq!(a.p99_e2e_s.to_bits(), b.p99_e2e_s.to_bits());
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn streaming_percentiles_agree_with_exact_within_budget() {
        let cfg = ExperimentConfig::paper();
        let worst = verify_agreement(&cfg, &tiny_opts(), 6.0).unwrap();
        // with eps = 0.02 on ~600 requests the budget is ~13 ranks
        assert!(worst <= 13, "worst rank distance {worst} exceeds the tiny-cell budget");
    }

    #[test]
    fn scale_json_parses_with_in_tree_parser() {
        let cfg = ExperimentConfig::paper();
        let mut opts = tiny_opts();
        opts.lambdas.truncate(1);
        let rows = run_scale(&cfg, &opts);
        let json = scale_json(&rows, &opts);
        for key in ["\"pr\": 6", "requests_per_cell", "support_bound", "cells"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let doc = crate::util::json::parse(&json).unwrap();
        assert!(doc.required("cells").is_ok());
    }
}
