//! Tracked perf harness — times the three parallelized hot loops
//! (per-epoch PSO solve, per-server cluster epochs, bench sweep cells)
//! at `threads = 1` versus `threads = auto`, asserts the outputs are
//! bit-identical, and emits the machine-readable `BENCH_pr5.json` perf
//! trajectory at the repository root.
//!
//! Two entry points drive it: `aigc-edge perf` (full-size loops) and
//! `benches/perf_smoke.rs` (CI-size loops; the bit-identity assert is
//! blocking there, the wall-clock numbers are uploaded as an artifact
//! with a *soft* threshold — shared CI runners make hard speedup gates
//! flaky).

use std::path::Path;
use std::time::Instant;

use crate::bandwidth::{EqualAllocator, PsoAllocator, PsoConfig};
use crate::config::ExperimentConfig;
use crate::delay::BatchDelayModel;
use crate::quality::PowerLawQuality;
use crate::routing::RouterKind;
use crate::scheduler::Stacking;
use crate::sim::{server_speeds, simulate_cluster, solve_joint, ClusterConfig, DynamicConfig};
use crate::trace::{generate, ArrivalTrace};
use crate::util::exec::{par_map, resolve_threads};

/// One hot loop's serial-vs-parallel measurement.
#[derive(Debug, Clone)]
pub struct PerfRow {
    pub loop_name: &'static str,
    /// Wall-clock at `threads = 1`.
    pub serial_s: f64,
    /// Wall-clock at `threads = auto`.
    pub parallel_s: f64,
    /// Parallel output bitwise equal to serial (must always hold).
    pub bit_identical: bool,
}

impl PerfRow {
    pub fn speedup(&self) -> f64 {
        if self.parallel_s > 0.0 {
            self.serial_s / self.parallel_s
        } else {
            0.0
        }
    }
}

/// Harness knobs.
#[derive(Debug, Clone, Copy)]
pub struct PerfOptions {
    /// The "parallel" thread count to compare against serial (0 =
    /// auto — the default and what `BENCH_pr5.json` records).
    pub threads: usize,
    /// Shrink every loop to CI size (the `perf_smoke` setting).
    pub quick: bool,
}

impl Default for PerfOptions {
    fn default() -> Self {
        Self { threads: 0, quick: false }
    }
}

fn bits_of(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Hot loop 1: the per-epoch (P1)∘(P2) solve — PSO particle fitness
/// fan-out inside `bandwidth::pso`.
fn measure_pso(cfg: &ExperimentConfig, opts: &PerfOptions) -> PerfRow {
    let workload = generate(&cfg.scenario, cfg.seed);
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let reps = if opts.quick { 2 } else { 5 };
    let run = |threads: usize| {
        let pso = PsoAllocator::new(PsoConfig {
            particles: cfg.pso.particles,
            iterations: cfg.pso.iterations,
            patience: cfg.pso.patience,
            threads,
            ..Default::default()
        });
        // warm once (untimed) so scratch/thread startup is steady-state
        let mut alloc = solve_joint(&workload, &scheduler, &pso, &delay, &quality);
        let start = Instant::now();
        for _ in 0..reps {
            alloc = solve_joint(&workload, &scheduler, &pso, &delay, &quality);
        }
        (start.elapsed().as_secs_f64(), bits_of(&alloc.outcome.allocation_hz))
    };
    let (serial_s, serial_bits) = run(1);
    let (parallel_s, parallel_bits) = run(opts.threads);
    PerfRow {
        loop_name: "pso_solve",
        serial_s,
        parallel_s,
        bit_identical: serial_bits == parallel_bits,
    }
}

fn perf_trace(cfg: &ExperimentConfig, rate_hz: f64, horizon_s: f64, seed: u64) -> ArrivalTrace {
    let mut arrival = cfg.arrival;
    arrival.process = crate::config::ArrivalProcessKind::Poisson;
    arrival.rate_hz = rate_hz;
    arrival.horizon_s = horizon_s;
    ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
}

/// Bitwise fingerprint of a cluster run — every per-request float plus
/// the dispositions, so any divergence (not just aggregate drift)
/// trips the identity check.
fn cluster_fingerprint(report: &crate::sim::ClusterReport) -> Vec<u64> {
    let mut out = Vec::with_capacity(report.outcomes.len() * 4 + 1);
    for o in &report.outcomes {
        out.push(o.steps as u64);
        out.push(o.quality.to_bits());
        out.push(o.e2e_s.to_bits());
        out.push(o.resolved_s.to_bits());
    }
    out.push(report.horizon_s.to_bits());
    out
}

/// Hot loop 2: independent per-server epoch solves in `sim::cluster`.
fn measure_cluster(cfg: &ExperimentConfig, opts: &PerfOptions) -> PerfRow {
    let horizon = if opts.quick { 30.0 } else { 90.0 };
    let trace = perf_trace(cfg, 6.0, horizon, cfg.seed);
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let speeds = server_speeds(4, 0.7, 1.3);
    let run = |threads: usize| {
        let mut dynamic = DynamicConfig::from(&cfg.dynamic);
        dynamic.threads = threads;
        let cluster = ClusterConfig {
            speeds: speeds.clone(),
            router: RouterKind::JoinShortestQueue,
            dynamic,
        };
        let start = Instant::now();
        let report =
            simulate_cluster(&trace, &scheduler, &EqualAllocator, &delay, &quality, &cluster);
        (start.elapsed().as_secs_f64(), cluster_fingerprint(&report))
    };
    run(1); // warmup (untimed)
    let (serial_s, serial_bits) = run(1);
    let (parallel_s, parallel_bits) = run(opts.threads);
    PerfRow {
        loop_name: "cluster_epochs",
        serial_s,
        parallel_s,
        bit_identical: serial_bits == parallel_bits,
    }
}

/// Hot loop 3: sweep-cell fan-out (the `fig_cluster`-shaped λ × router
/// grid, without the table printing).
fn measure_sweep(cfg: &ExperimentConfig, opts: &PerfOptions) -> PerfRow {
    let lambdas: &[f64] = if opts.quick { &[1.0, 4.0] } else { &[1.0, 2.0, 4.0, 6.0] };
    let horizon = if opts.quick { 30.0 } else { 60.0 };
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let traces: Vec<ArrivalTrace> =
        lambdas.iter().map(|&l| perf_trace(cfg, l, horizon, cfg.seed)).collect();
    let cells: Vec<(usize, RouterKind)> = (0..lambdas.len())
        .flat_map(|li| RouterKind::all().into_iter().map(move |r| (li, r)))
        .collect();
    let run = |threads: usize| {
        let start = Instant::now();
        let fingerprints: Vec<Vec<u64>> = par_map(threads, &cells, |_, &(li, router)| {
            let mut settings = cfg.cluster;
            settings.router = router;
            let cluster = ClusterConfig::from_settings(&settings, &cfg.dynamic);
            let report = simulate_cluster(
                &traces[li],
                &scheduler,
                &EqualAllocator,
                &delay,
                &quality,
                &cluster,
            );
            cluster_fingerprint(&report)
        });
        (start.elapsed().as_secs_f64(), fingerprints)
    };
    run(1); // warmup (untimed)
    let (serial_s, serial_bits) = run(1);
    let (parallel_s, parallel_bits) = run(opts.threads);
    PerfRow {
        loop_name: "sweep_cells",
        serial_s,
        parallel_s,
        bit_identical: serial_bits == parallel_bits,
    }
}

/// Run the three tracked loops. Every row's `bit_identical` must be
/// true — callers (CLI, `perf_smoke`) treat a false as a hard failure.
pub fn run_perf(cfg: &ExperimentConfig, opts: &PerfOptions) -> Vec<PerfRow> {
    vec![measure_pso(cfg, opts), measure_cluster(cfg, opts), measure_sweep(cfg, opts)]
}

/// Serialize the rows as the tracked `BENCH_pr5.json` document.
pub fn bench_json(rows: &[PerfRow], opts: &PerfOptions) -> String {
    let mut loops = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            loops.push_str(",\n");
        }
        loops.push_str(&format!(
            "    \"{}\": {{\"serial_s\": {:.6}, \"parallel_s\": {:.6}, \"speedup\": {:.3}, \
             \"bit_identical\": {}}}",
            r.loop_name,
            r.serial_s,
            r.parallel_s,
            r.speedup(),
            r.bit_identical
        ));
    }
    format!(
        "{{\n  \"pr\": 5,\n  \"quick\": {},\n  \"threads_auto\": {},\n  \"loops\": {{\n{}\n  }}\n}}\n",
        opts.quick,
        resolve_threads(opts.threads),
        loops
    )
}

/// Write `BENCH_pr5.json` (default location: the repository root, one
/// level above the crate).
pub fn write_bench_json(path: &Path, rows: &[PerfRow], opts: &PerfOptions) -> std::io::Result<()> {
    std::fs::write(path, bench_json(rows, opts))
}

/// The tracked trajectory location, `<repo root>/BENCH_pr5.json` —
/// derived from the compile-time checkout, so only callers that run
/// where they were built (`cargo bench --bench perf_smoke`, `cargo
/// test`) should use it; the installed CLI defaults to the invocation
/// directory instead.
pub fn default_bench_path() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr5.json")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_harness_is_bit_identical_and_serializes() {
        let mut cfg = ExperimentConfig::paper();
        // tiny sizes: this is a correctness test, not a measurement
        cfg.pso.particles = 6;
        cfg.pso.iterations = 6;
        cfg.pso.patience = 3;
        cfg.scenario.num_services = 8;
        let opts = PerfOptions { threads: 2, quick: true };
        let rows = run_perf(&cfg, &opts);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.bit_identical, "{}: parallel output diverged from serial", r.loop_name);
            assert!(r.serial_s > 0.0 && r.parallel_s > 0.0);
        }
        let json = bench_json(&rows, &opts);
        for key in ["pso_solve", "cluster_epochs", "sweep_cells", "threads_auto", "speedup"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        // the emitted document must parse with the in-tree JSON parser
        let doc = crate::util::json::parse(&json).unwrap();
        assert!(doc.required("loops").is_ok());
    }
}
