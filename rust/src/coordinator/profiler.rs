//! Batch-delay profiling — the Fig. 1a measurement, on this machine.
//!
//! Runs the real PJRT executable at every bucket size, measures the
//! per-batch latency, and fits `g(X) = aX + b` with [`DelayFit`]. The
//! resulting constants replace the paper's RTX 3050 numbers in the
//! `measured` preset.

use anyhow::Result;

use crate::delay::DelayFit;
use crate::runtime::{ArtifactStore, BatchInput, DenoiseExecutor};
use crate::util::Pcg64;

/// Pin XLA's CPU backend to single-threaded execution. On a many-core
/// CPU the d=64 model's per-task compute is otherwise fully parallelized
/// away and the measured slope `a` collapses into dispatch noise; the
/// paper's single-GPU setting corresponds to a fixed compute budget per
/// batch, which one CPU thread reproduces. MUST be called before the
/// first `PjRtClient` is created in the process to take effect.
pub fn pin_xla_single_threaded() {
    let flag = "--xla_cpu_multi_thread_eigen=false";
    let existing = std::env::var("XLA_FLAGS").unwrap_or_default();
    if !existing.contains(flag) {
        std::env::set_var("XLA_FLAGS", format!("{existing} {flag}").trim().to_string());
    }
}

/// Profiling parameters.
#[derive(Debug, Clone, Copy)]
pub struct ProfileConfig {
    /// Timed repetitions per bucket.
    pub reps: usize,
    /// Untimed warmup executions per bucket.
    pub warmup: usize,
    pub seed: u64,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        Self { reps: 20, warmup: 3, seed: 11 }
    }
}

/// Measure the denoising delay at every bucket size and fit the model.
/// Returns the fit plus the raw per-bucket median samples.
pub fn profile_batch_delay(store: &ArtifactStore, config: ProfileConfig) -> Result<DelayFit> {
    let mut exec = DenoiseExecutor::new(store);
    let dim = store.manifest().data_dim;
    let n_train = store.manifest().num_train_steps as i32;
    let mut rng = Pcg64::seeded(config.seed);

    let mut samples: Vec<(u32, f64)> = Vec::new();
    for bucket in store.buckets() {
        let bs = bucket as usize;
        let latents: Vec<Vec<f32>> =
            (0..bs).map(|_| (0..dim).map(|_| rng.normal() as f32).collect()).collect();
        fn make_batch(latents: &[Vec<f32>], n_train: i32) -> Vec<BatchInput<'_>> {
            latents
                .iter()
                .enumerate()
                .map(|(i, l)| BatchInput {
                    latent: l,
                    t_cur: n_train - (i as i32 % 100),
                    t_prev: n_train - (i as i32 % 100) - 50,
                })
                .collect()
        }
        for _ in 0..config.warmup {
            exec.step(&make_batch(&latents, n_train))?;
        }
        let mut times = Vec::with_capacity(config.reps);
        for _ in 0..config.reps {
            let out = exec.step(&make_batch(&latents, n_train))?;
            times.push(out.exec_seconds);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        samples.push((bucket, median));
    }
    Ok(DelayFit::from_samples(&samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_dir;

    #[test]
    fn profile_produces_affine_fit() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let store = ArtifactStore::load(&dir).unwrap();
        let fit = profile_batch_delay(&store, ProfileConfig { reps: 5, warmup: 1, seed: 1 })
            .unwrap();
        let m = fit.model();
        // Non-degenerate: positive per-batch cost, finite slope, and the
        // measurements are explained reasonably well by a line.
        assert!(m.g(1) > 0.0);
        assert!(fit.samples.len() == store.buckets().len());
        assert!(fit.fit.r2 > 0.3, "poor linear fit: {:?}", fit.fit);
        // amortization must hold on real hardware too: per-task cost at
        // the top bucket beats the singleton cost
        let top = store.max_bucket();
        assert!(m.per_task(top) < m.g(1), "no amortization measured");
    }
}
