//! The epoching policy — *when* a batch of queued requests becomes an
//! epoch and gets one (P0) solve.
//!
//! Both front-ends share this exact decision rule so their behaviour
//! stays comparable by construction:
//! * the TCP server (`server::serve`) applies it to wall-clock time;
//! * the dynamic simulator (`sim::dynamic`) applies it to simulated
//!   time.
//!
//! An epoch closes as soon as `max_batch` requests are waiting, or once
//! it has been open for `epoch_s` seconds with at least one request
//! queued (an empty epoch never closes — there is nothing to solve).

/// Epoch-closing rule shared by the online server and the simulator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochPolicy {
    /// Epoch length in seconds: the longest a queued request waits
    /// before the next solve.
    pub epoch_s: f64,
    /// Close early once this many requests are queued.
    pub max_batch: usize,
}

impl EpochPolicy {
    pub fn new(epoch_s: f64, max_batch: usize) -> Self {
        assert!(epoch_s > 0.0, "epoch length must be positive");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        Self { epoch_s, max_batch }
    }

    /// From the server's millisecond config.
    pub fn from_millis(epoch_ms: u64, max_batch: usize) -> Self {
        Self::new(epoch_ms.max(1) as f64 * 1e-3, max_batch)
    }

    /// Should an epoch that has been open for `open_for_s` seconds with
    /// `queued` requests waiting close now?
    pub fn should_close(&self, queued: usize, open_for_s: f64) -> bool {
        queued >= self.max_batch || (queued > 0 && open_for_s + 1e-12 >= self.epoch_s)
    }

    /// Latest instant an epoch opened at `opened_at_s` may stay open.
    pub fn close_deadline(&self, opened_at_s: f64) -> f64 {
        opened_at_s + self.epoch_s
    }
}

impl Default for EpochPolicy {
    fn default() -> Self {
        Self { epoch_s: 0.2, max_batch: 32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_on_batch_or_timeout_only_with_work() {
        let p = EpochPolicy::new(1.0, 4);
        assert!(!p.should_close(0, 10.0), "empty epochs never close");
        assert!(!p.should_close(1, 0.5));
        assert!(p.should_close(1, 1.0));
        assert!(p.should_close(4, 0.0), "full batch closes immediately");
        assert!(p.should_close(9, 0.0));
    }

    #[test]
    fn millis_conversion_and_deadline() {
        let p = EpochPolicy::from_millis(200, 32);
        assert!((p.epoch_s - 0.2).abs() < 1e-12);
        assert!((p.close_deadline(3.0) - 3.2).abs() < 1e-12);
        // zero ms clamps to something strictly positive
        assert!(EpochPolicy::from_millis(0, 1).epoch_s > 0.0);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_batch() {
        EpochPolicy::new(1.0, 0);
    }
}
