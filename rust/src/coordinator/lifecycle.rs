//! The per-server epoch lifecycle — *when* a frozen epoch's (P0) solve
//! runs relative to the GPU, and what that solve costs.
//!
//! The paper's serving loop is synchronous: the epoch closes, the
//! (P1)∘(P2) solve runs, and only then does the batch start — the GPU
//! idles through every solve. Accelerating-MEG (arXiv:2407.07245) shows
//! the real win at the edge is hiding that planning latency behind
//! generation, so the lifecycle is now explicit:
//!
//! ```text
//! Building ──freeze──▶ PlanPending ──solve done──▶ Solved
//!                                                    │ GPU frees
//!                                                    ▼
//!                          Closed ◀──batch done── Executing
//! ```
//!
//! * **Building** — the epoch is open; arrivals join until the
//!   time-or-batch rule ([`EpochPolicy`](super::EpochPolicy)) freezes
//!   membership.
//! * **PlanPending** — membership frozen, the solve is running on CPU.
//!   Under [`SolveMode::Pipelined`] it starts at the freeze instant —
//!   typically while the *previous* epoch's batch still occupies the
//!   GPU; under [`SolveMode::Synchronous`] it waits for the GPU.
//! * **Solved** — the plan is ready; the batch starts once the GPU
//!   frees (pipelined mode only; a synchronous solve ends with the GPU
//!   already free).
//! * **Executing → Closed** — the batch occupies the GPU for its
//!   makespan, then the epoch retires.
//!
//! [`SolveTiming::compute`] is the single timing rule both simulation
//! engines (`sim::dynamic`, `sim::event`) share, so their pipelines can
//! never drift apart — `tests/pipeline_equivalence.rs` holds them to
//! bit-identity. With `solve_latency_s = 0` the two modes coincide
//! exactly with the pre-pipeline engines (the batch starts at
//! `max(close, gpu_free)`), which keeps every historical replay
//! bit-identical.

/// Where an epoch's (P0) solve runs relative to the GPU timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveMode {
    /// The paper's loop: the solve occupies the gap between batches —
    /// it begins once the epoch is frozen *and* the GPU is free, and
    /// the batch starts only after it finishes. Nonzero solve latency
    /// is charged serially (the GPU idles through it).
    Synchronous,
    /// Decoupled: the solve begins on CPU at the epoch freeze, while
    /// the previous epoch's batch may still be executing on GPU. Solve
    /// latency is still charged, but hidden behind GPU execution
    /// whenever the GPU is busy past the freeze.
    Pipelined,
}

impl SolveMode {
    /// Parse the CLI/TOML name; the error lists the valid names
    /// (PR-3 parser convention).
    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "synchronous" | "sync" => Ok(Self::Synchronous),
            "pipelined" | "pipeline" => Ok(Self::Pipelined),
            other => anyhow::bail!(
                "unknown solve mode '{other}' (valid: synchronous|sync, pipelined|pipeline)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Synchronous => "synchronous",
            Self::Pipelined => "pipelined",
        }
    }

    /// Both modes, synchronous first (the baseline a sweep compares
    /// against).
    pub fn all() -> [Self; 2] {
        [Self::Synchronous, Self::Pipelined]
    }
}

/// The lifecycle phase of one epoch. `Building` is the open,
/// pre-freeze state; the four post-freeze phases are the pipeline
/// proper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EpochPhase {
    /// Open: arrivals still join.
    Building,
    /// Membership frozen; the solve is running (or queued) on CPU.
    PlanPending,
    /// Plan ready; waiting for the GPU to free.
    Solved,
    /// The batch occupies the GPU.
    Executing,
    /// Batch complete; the epoch has retired.
    Closed,
}

impl EpochPhase {
    /// The next phase in the only legal order. `Closed` is absorbing.
    pub fn advance(self) -> Self {
        match self {
            Self::Building => Self::PlanPending,
            Self::PlanPending => Self::Solved,
            Self::Solved => Self::Executing,
            Self::Executing | Self::Closed => Self::Closed,
        }
    }
}

/// Deterministic timing of one frozen epoch's solve + batch under a
/// [`SolveMode`] — the single rule both simulation engines share.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolveTiming {
    /// Instant the (P1)∘(P2) solve starts on CPU.
    pub solve_begin_s: f64,
    /// Instant the plan is ready (`solve_begin + solve_latency`).
    pub solve_end_s: f64,
    /// Instant the batch starts on GPU (`max(solve_end, gpu_free)`).
    /// Residual deadlines are evaluated here: the plan targets the
    /// start instant, which the engine knows exactly.
    pub batch_start_s: f64,
    /// Solve time that overlapped GPU execution — the hidden latency
    /// (always 0 in synchronous mode, where the solve waits for an
    /// idle GPU).
    pub hidden_s: f64,
}

impl SolveTiming {
    /// Timing for an epoch frozen at `close_s` on a server whose GPU
    /// frees at `gpu_free_s`, with a solve costing `solve_latency_s`
    /// CPU seconds. With `solve_latency_s = 0` both modes yield
    /// `batch_start = max(close, gpu_free)` — the pre-pipeline solve
    /// instant, bit-for-bit.
    pub fn compute(close_s: f64, gpu_free_s: f64, solve_latency_s: f64, mode: SolveMode) -> Self {
        debug_assert!(solve_latency_s >= 0.0 && solve_latency_s.is_finite());
        let solve_begin_s = match mode {
            SolveMode::Pipelined => close_s,
            SolveMode::Synchronous => close_s.max(gpu_free_s),
        };
        let solve_end_s = solve_begin_s + solve_latency_s;
        let batch_start_s = solve_end_s.max(gpu_free_s);
        let hidden_s = (gpu_free_s.min(solve_end_s) - solve_begin_s).clamp(0.0, solve_latency_s);
        Self { solve_begin_s, solve_end_s, batch_start_s, hidden_s }
    }

    /// The lifecycle phase at instant `t_s`, given the batch's
    /// makespan. Intervals are half-open on the right, so a boundary
    /// instant belongs to the later phase.
    pub fn phase_at(&self, t_s: f64, makespan_s: f64) -> EpochPhase {
        if t_s < self.solve_end_s {
            EpochPhase::PlanPending
        } else if t_s < self.batch_start_s {
            EpochPhase::Solved
        } else if t_s < self.batch_start_s + makespan_s {
            EpochPhase::Executing
        } else {
            EpochPhase::Closed
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_latency_reproduces_the_pre_pipeline_instant_in_both_modes() {
        for (close, gpu_free) in [(1.0, 0.0), (2.0, 5.0), (3.5, 3.5), (0.0, 0.0)] {
            for mode in SolveMode::all() {
                let t = SolveTiming::compute(close, gpu_free, 0.0, mode);
                assert_eq!(
                    t.batch_start_s.to_bits(),
                    close.max(gpu_free).to_bits(),
                    "{mode:?} close={close} gpu={gpu_free}"
                );
                assert_eq!(t.hidden_s, 0.0);
            }
        }
    }

    #[test]
    fn pipelined_hides_solve_behind_a_busy_gpu() {
        // GPU busy until 5.0; epoch freezes at 2.0; solve costs 1.0.
        let p = SolveTiming::compute(2.0, 5.0, 1.0, SolveMode::Pipelined);
        assert_eq!(p.solve_begin_s, 2.0);
        assert_eq!(p.solve_end_s, 3.0);
        assert_eq!(p.batch_start_s, 5.0, "fully hidden: batch starts the instant the GPU frees");
        assert_eq!(p.hidden_s, 1.0);
        let s = SolveTiming::compute(2.0, 5.0, 1.0, SolveMode::Synchronous);
        assert_eq!(s.solve_begin_s, 5.0);
        assert_eq!(s.batch_start_s, 6.0, "synchronous charges the solve after the GPU frees");
        assert_eq!(s.hidden_s, 0.0);
    }

    #[test]
    fn pipelined_partial_overlap_and_idle_gpu() {
        // GPU frees mid-solve: only the busy part is hidden.
        let t = SolveTiming::compute(2.0, 2.4, 1.0, SolveMode::Pipelined);
        assert_eq!(t.batch_start_s, 3.0);
        assert!((t.hidden_s - 0.4).abs() < 1e-12);
        // Idle GPU: nothing to hide behind, both modes pay in full.
        let p = SolveTiming::compute(2.0, 1.0, 1.0, SolveMode::Pipelined);
        let s = SolveTiming::compute(2.0, 1.0, 1.0, SolveMode::Synchronous);
        assert_eq!(p.batch_start_s.to_bits(), s.batch_start_s.to_bits());
        assert_eq!(p.hidden_s, 0.0);
    }

    #[test]
    fn pipelined_batch_never_starts_later_than_synchronous() {
        // max(close + L, gpu) <= max(close, gpu) + L, for every input —
        // the per-epoch dominance the delay savings build on.
        let grid = [0.0, 0.3, 1.0, 2.7, 5.0, 9.9];
        for &close in &grid {
            for &gpu in &grid {
                for latency in [0.0, 0.1, 1.0, 4.0] {
                    let p = SolveTiming::compute(close, gpu, latency, SolveMode::Pipelined);
                    let s = SolveTiming::compute(close, gpu, latency, SolveMode::Synchronous);
                    assert!(p.batch_start_s <= s.batch_start_s, "close={close} gpu={gpu}");
                    assert!(p.hidden_s <= latency && p.hidden_s >= 0.0);
                    // the hidden time is exactly the saving
                    assert!(
                        (s.batch_start_s - p.batch_start_s - p.hidden_s).abs() < 1e-12,
                        "saving must equal the hidden solve time"
                    );
                }
            }
        }
    }

    #[test]
    fn phase_order_is_the_only_legal_one() {
        let mut phase = EpochPhase::Building;
        let expected = [
            EpochPhase::PlanPending,
            EpochPhase::Solved,
            EpochPhase::Executing,
            EpochPhase::Closed,
            EpochPhase::Closed, // absorbing
        ];
        for want in expected {
            phase = phase.advance();
            assert_eq!(phase, want);
        }
    }

    #[test]
    fn phase_at_walks_the_machine() {
        let t = SolveTiming::compute(2.0, 5.0, 1.0, SolveMode::Pipelined);
        assert_eq!(t.phase_at(2.5, 4.0), EpochPhase::PlanPending);
        assert_eq!(t.phase_at(3.5, 4.0), EpochPhase::Solved);
        assert_eq!(t.phase_at(5.0, 4.0), EpochPhase::Executing);
        assert_eq!(t.phase_at(9.0, 4.0), EpochPhase::Closed);
    }

    #[test]
    fn solve_mode_names_round_trip_and_errors_list_valid_values() {
        for mode in SolveMode::all() {
            assert_eq!(SolveMode::from_name(mode.name()).unwrap(), mode);
        }
        assert_eq!(SolveMode::from_name("sync").unwrap(), SolveMode::Synchronous);
        assert_eq!(SolveMode::from_name("pipeline").unwrap(), SolveMode::Pipelined);
        let err = SolveMode::from_name("eager").unwrap_err().to_string();
        assert!(err.contains("synchronous") && err.contains("pipelined"), "{err}");
    }
}
