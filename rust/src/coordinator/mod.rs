//! The online serving coordinator — the Layer-3 engine that turns the
//! paper's offline optimization into a running service.
//!
//! Requests arrive with a deadline and a channel estimate; the engine
//! groups an epoch of requests, solves the joint problem (bandwidth via
//! PSO, batch denoising via STACKING), then drives the plan against the
//! *real* PJRT artifacts batch by batch, maintaining each service's
//! latent state. Transmission is simulated against the channel model
//! (no radio on this testbed); generation is real compute.

pub mod engine;
pub mod epoch;
pub mod lifecycle;
pub mod profiler;

pub use engine::{Engine, EngineConfig, EngineReport, ServedRequest};
pub use epoch::EpochPolicy;
pub use lifecycle::{EpochPhase, SolveMode, SolveTiming};
pub use profiler::{pin_xla_single_threaded, profile_batch_delay, ProfileConfig};
