//! The epoch-based serving engine.
//!
//! One epoch = one (P0) solve + real execution:
//!  1. take the epoch's requests (deadline + channel per device);
//!  2. allocate bandwidth (outer (P1), PSO by default);
//!  3. plan batch denoising (inner (P2), STACKING by default);
//!  4. execute the plan's batches in order on the PJRT artifacts,
//!     carrying each service's latent row forward;
//!  5. account simulated transmission delay per the channel model and
//!     report per-request outcomes.
//!
//! The engine is deliberately synchronous within an epoch — the paper's
//! system model is a single shared GPU executing batches sequentially
//! (Eq. 6), so a single worker loop *is* the faithful topology.

use anyhow::{Context, Result};

use crate::bandwidth::{Allocator, PsoAllocator};
use crate::delay::BatchDelayModel;
use crate::metrics::Metrics;
use crate::quality::QualityModel;
use crate::runtime::{ArtifactStore, BatchInput, DenoiseExecutor};
use crate::scheduler::{BatchScheduler, Stacking};
use crate::sim::{gen_budgets, solve_joint};
use crate::trace::Workload;
use crate::util::Pcg64;

/// A request as the engine serves it.
#[derive(Debug, Clone, Copy)]
pub struct ServedRequest {
    pub id: usize,
    pub deadline: f64,
    /// Steps the plan promised (0 = rejected/outage).
    pub steps: u32,
    /// Planned generation delay from the analytical model.
    pub planned_gen_s: f64,
    /// Actual wall-clock spent in PJRT executions for this service's
    /// batches (sum over its batches).
    pub actual_gen_s: f64,
    /// Simulated transmission delay under the allocated bandwidth.
    pub tx_s: f64,
    /// Quality the model predicts for `steps`.
    pub predicted_quality: f64,
}

/// Outcome of serving one epoch.
#[derive(Debug)]
pub struct EngineReport {
    pub requests: Vec<ServedRequest>,
    /// Generated latents, one row per request (empty row if outage).
    pub latents: Vec<Vec<f32>>,
    /// Total wall-clock of the execution phase.
    pub exec_wall_s: f64,
    /// Number of batches executed.
    pub batches: usize,
    /// Mean predicted quality (the (P0) objective).
    pub mean_quality: f64,
}

/// Engine construction parameters.
pub struct EngineConfig {
    pub delay: BatchDelayModel,
    /// Seed for the initial noise latents.
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { delay: BatchDelayModel::paper(), seed: 7 }
    }
}

/// The serving engine. Owns the executor; borrows scheduler/allocator
/// per epoch so callers can swap policies between epochs (as the
/// benches do).
pub struct Engine<'a> {
    store: &'a ArtifactStore,
    executor: DenoiseExecutor<'a>,
    config: EngineConfig,
    pub metrics: Metrics,
}

impl<'a> Engine<'a> {
    pub fn new(store: &'a ArtifactStore, config: EngineConfig) -> Self {
        Self { store, executor: DenoiseExecutor::new(store), config, metrics: Metrics::new() }
    }

    /// Serve one epoch of requests described by `workload`.
    pub fn serve_epoch(
        &mut self,
        workload: &Workload,
        scheduler: &dyn BatchScheduler,
        allocator: &dyn Allocator,
        quality: &dyn QualityModel,
    ) -> Result<EngineReport> {
        let k = workload.k();
        self.metrics.add("requests", k as u64);

        // ---- plan (P1) ∘ (P2) ----
        let plan_start = std::time::Instant::now();
        let solution = solve_joint(workload, scheduler, allocator, &self.config.delay, quality);
        self.metrics.record_latency("plan", plan_start.elapsed().as_secs_f64());
        let outcome = &solution.outcome;
        let services = gen_budgets(workload, &outcome.allocation_hz);
        debug_assert_eq!(services.len(), k);
        let schedule = &outcome.schedule;

        // ---- per-service DDIM timestep grids ----
        // Service k with T_k planned steps follows the uniform DDIM
        // sub-sequence of length T_k (same grid as model.ddim_timesteps).
        let n_train = self.store.manifest().num_train_steps as f64;
        let grids: Vec<Vec<i32>> = schedule
            .steps
            .iter()
            .map(|&t_k| {
                (0..=t_k)
                    .map(|i| {
                        if t_k == 0 {
                            0
                        } else {
                            (n_train * (1.0 - i as f64 / t_k as f64)).round() as i32
                        }
                    })
                    .collect()
            })
            .collect();

        // ---- latent state ----
        let dim = self.store.manifest().data_dim;
        let mut rng = Pcg64::seeded(self.config.seed);
        let mut latents: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut actual_gen = vec![0.0f64; k];

        // ---- execute the plan ----
        let exec_start = std::time::Instant::now();
        let mut executed_batches = 0usize;
        for batch in &schedule.batches {
            // Split oversized batches across the top bucket (the planner
            // may batch more than the largest compiled executable).
            let top = self.store.max_bucket() as usize;
            for chunk in batch.tasks.chunks(top) {
                let inputs: Vec<BatchInput> = chunk
                    .iter()
                    .map(|t| {
                        let grid = &grids[t.service];
                        let s = t.step as usize; // 1-based
                        BatchInput {
                            latent: &latents[t.service],
                            t_cur: grid[s - 1],
                            t_prev: grid[s],
                        }
                    })
                    .collect();
                let out = self.executor.step(&inputs).context("batch execution")?;
                self.metrics.record_latency("batch_exec", out.exec_seconds);
                self.metrics.add("tasks", chunk.len() as u64);
                self.metrics.set_gauge("last_bucket", out.bucket as f64);
                for (task, latent) in chunk.iter().zip(out.latents) {
                    latents[task.service] = latent;
                    actual_gen[task.service] += out.exec_seconds;
                }
                executed_batches += 1;
            }
        }
        let exec_wall_s = exec_start.elapsed().as_secs_f64();
        self.metrics.record_latency("epoch_exec", exec_wall_s);

        // ---- assemble report ----
        let requests: Vec<ServedRequest> = (0..k)
            .map(|i| ServedRequest {
                id: workload.devices[i].id,
                deadline: workload.devices[i].deadline,
                steps: schedule.steps[i],
                planned_gen_s: schedule.completion[i],
                actual_gen_s: actual_gen[i],
                tx_s: outcome.services[i].tx_delay,
                predicted_quality: outcome.services[i].quality,
            })
            .collect();
        let outages = requests.iter().filter(|r| r.steps == 0).count();
        self.metrics.add("outages", outages as u64);
        let latents_out: Vec<Vec<f32>> = (0..k)
            .map(|i| if schedule.steps[i] > 0 { latents[i].clone() } else { Vec::new() })
            .collect();
        Ok(EngineReport {
            requests,
            latents: latents_out,
            exec_wall_s,
            batches: executed_batches,
            mean_quality: outcome.mean_quality(),
        })
    }

    /// Default-policy convenience: STACKING + PSO.
    pub fn serve_epoch_default(
        &mut self,
        workload: &Workload,
        quality: &dyn QualityModel,
    ) -> Result<EngineReport> {
        self.serve_epoch(workload, &Stacking::default(), &PsoAllocator::default(), quality)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::EqualAllocator;
    use crate::config::{default_artifacts_dir, ExperimentConfig};
    use crate::quality::PowerLawQuality;
    use crate::trace::generate;

    fn store() -> Option<ArtifactStore> {
        let dir = default_artifacts_dir();
        dir.join("manifest.json").exists().then(|| ArtifactStore::load(&dir).unwrap())
    }

    #[test]
    fn serves_epoch_end_to_end() {
        let Some(store) = store() else { return };
        let mut cfg = ExperimentConfig::paper();
        cfg.scenario.num_services = 6;
        // Short deadlines keep the test fast (few steps).
        cfg.scenario.deadline_lo = 2.0;
        cfg.scenario.deadline_hi = 4.0;
        let workload = generate(&cfg.scenario, 3);
        let mut engine = Engine::new(&store, EngineConfig::default());
        let quality = PowerLawQuality::paper();
        let report = engine
            .serve_epoch(&workload, &Stacking::default(), &EqualAllocator, &quality)
            .unwrap();
        assert_eq!(report.requests.len(), 6);
        for r in &report.requests {
            assert!(r.steps > 0, "unexpected outage: {r:?}");
            assert!(r.tx_s > 0.0);
            assert!(r.actual_gen_s > 0.0);
        }
        for (r, latent) in report.requests.iter().zip(&report.latents) {
            assert_eq!(latent.len(), store.manifest().data_dim);
            assert!(latent.iter().all(|v| v.is_finite()), "{:?}", r.id);
        }
        assert!(report.batches > 0);
        assert_eq!(engine.metrics.counter("requests"), 6);
        assert_eq!(engine.metrics.counter("outages"), 0);
        assert!(engine.metrics.counter("tasks") > 0);
    }

    #[test]
    fn infeasible_request_reported_as_outage() {
        let Some(store) = store() else { return };
        let mut cfg = ExperimentConfig::paper();
        cfg.scenario.num_services = 3;
        let mut workload = generate(&cfg.scenario, 4);
        workload.devices[0].deadline = 0.01; // cannot even transmit
        let mut engine = Engine::new(&store, EngineConfig::default());
        let quality = PowerLawQuality::paper();
        let report = engine
            .serve_epoch(&workload, &Stacking::default(), &EqualAllocator, &quality)
            .unwrap();
        assert_eq!(report.requests[0].steps, 0);
        assert!(report.latents[0].is_empty());
        assert_eq!(engine.metrics.counter("outages"), 1);
    }
}
