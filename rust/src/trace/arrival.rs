//! Continuous-time arrival traces — the workload model for dynamic
//! serving (`sim::dynamic`).
//!
//! The paper evaluates one static snapshot (K requests present at
//! t = 0); real edge servers face *streams* of AIGC requests. This
//! module generates those streams:
//!
//! * seeded **Poisson** arrivals (rate λ),
//! * seeded **burst/diurnal** arrivals — a square-wave-modulated
//!   Poisson process sampled by thinning (base rate off-peak, burst
//!   rate for a duty fraction of every period),
//! * **replayable traces**: any trace serializes to a small CSV and
//!   loads back bit-identically, so captured workloads rerun exactly.
//!
//! Every arrival carries the paper's per-request marks: a relative
//! deadline τ ~ U[lo, hi] and a downlink with η ~ U[eta_lo, eta_hi].
//! When the prompt-popularity knobs are on, each arrival additionally
//! carries a `(model_id, prompt_id)` [`PromptMark`] drawn from a
//! seeded Zipf law — the content identity the generation cache keys
//! on. With the knobs at their defaults every mark is
//! [`PromptMark::ZERO`], zero extra RNG draws happen, and traces
//! serialize in the unversioned-v1 formats unchanged.

use anyhow::{bail, Context, Result};

use crate::channel::{ChannelGenerator, FadingModel, Link};
use crate::config::{ArrivalProcessKind, ArrivalSettings, ScenarioConfig};
use crate::util::Pcg64;

/// Content identity of a request: which diffusion model serves it and
/// which prompt (bucketed into a finite universe) it asks for. Two
/// requests with equal marks want the identical content — the unit the
/// generation cache is addressed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PromptMark {
    pub model: u32,
    pub prompt: u32,
}

impl PromptMark {
    /// The unmarked identity (model 0, prompt 0) every arrival carries
    /// when prompt popularity is disabled.
    pub const ZERO: PromptMark = PromptMark { model: 0, prompt: 0 };

    pub fn is_zero(&self) -> bool {
        *self == Self::ZERO
    }
}

/// Seeded Zipf popularity law over prompt ids (and a uniform model
/// choice): prompt k (1-based rank) is drawn with probability
/// k^-s / Σ j^-s. Runs on its own PCG stream so enabling marks never
/// perturbs the arrival-time/deadline/channel draws.
#[derive(Debug, Clone)]
pub struct PromptLaw {
    rng: Pcg64,
    /// Cumulative normalized Zipf weights; `cumulative[k]` is
    /// P(prompt ≤ k), with the last entry pinned to 1.0.
    cumulative: Vec<f64>,
    models: u32,
}

/// Dedicated PCG stream for prompt marks (arrivals use 0xA221).
const PROMPT_STREAM: u64 = 0xA227;

impl PromptLaw {
    pub fn new(universe: usize, zipf_s: f64, models: u32, seed: u64) -> Self {
        assert!(universe >= 1, "prompt universe must be at least 1");
        assert!(zipf_s.is_finite() && zipf_s > 0.0, "zipf_s must be finite and positive");
        assert!(models >= 1, "at least one model");
        let mut cumulative: Vec<f64> = (1..=universe).map(|k| (k as f64).powf(-zipf_s)).collect();
        let total: f64 = cumulative.iter().sum();
        let mut acc = 0.0;
        for w in cumulative.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        // Guard the tail against rounding so every uniform lands.
        *cumulative.last_mut().expect("universe >= 1") = 1.0;
        Self { rng: Pcg64::new(seed, PROMPT_STREAM), cumulative, models }
    }

    /// Build the law for `settings` iff its prompt knobs are active.
    pub fn from_settings(settings: &ArrivalSettings, seed: u64) -> Option<Self> {
        if settings.prompts_enabled() {
            Some(Self::new(settings.prompt_universe, settings.zipf_s, settings.models, seed))
        } else {
            None
        }
    }

    /// Draw one mark: a Zipf-ranked prompt id (0 = most popular) and a
    /// uniform model id.
    pub fn draw(&mut self) -> PromptMark {
        let u = self.rng.uniform();
        let prompt = self.cumulative.partition_point(|&c| c <= u) as u32;
        let model = if self.models > 1 { self.rng.below(self.models as u64) as u32 } else { 0 };
        PromptMark { model, prompt }
    }
}

/// One dynamically-arriving request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Dense index in arrival order (also the outcome index).
    pub id: usize,
    /// Arrival instant, seconds from trace start.
    pub t_s: f64,
    /// Relative end-to-end deadline τ in seconds (absolute deadline is
    /// `t_s + deadline_s`).
    pub deadline_s: f64,
    pub link: Link,
    /// Content identity; [`PromptMark::ZERO`] unless the prompt
    /// popularity knobs are on.
    pub mark: PromptMark,
}

/// A complete, replayable arrival trace plus the shared wireless
/// scenario constants the requests compete over.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalTrace {
    /// Arrivals sorted by `t_s`, ids dense in order.
    pub arrivals: Vec<Arrival>,
    /// Total downlink bandwidth B in Hz.
    pub total_bandwidth_hz: f64,
    /// Content size S in bits.
    pub content_bits: f64,
}

impl ArrivalTrace {
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Time of the last arrival (0.0 for an empty trace).
    pub fn duration_s(&self) -> f64 {
        self.arrivals.last().map(|a| a.t_s).unwrap_or(0.0)
    }

    /// Empirical arrival rate over the trace span.
    pub fn mean_rate_hz(&self) -> f64 {
        let d = self.duration_s();
        if d <= 0.0 {
            0.0
        } else {
            self.arrivals.len() as f64 / d
        }
    }

    /// Draw a trace from the configured arrival process. Deterministic
    /// per seed; deadline/η marks use the Section-IV distributions of
    /// `scenario`. Exactly `ArrivalStream::new(..).collect()`, so the
    /// buffered and streaming paths are bit-identical per seed.
    pub fn generate(scenario: &ScenarioConfig, arrival: &ArrivalSettings, seed: u64) -> Self {
        let stream = ArrivalStream::new(scenario, arrival, seed);
        Self {
            arrivals: stream.collect(),
            total_bandwidth_hz: scenario.total_bandwidth_hz,
            content_bits: scenario.content_bits,
        }
    }

    /// Any arrival carrying a non-zero prompt mark? Marked traces
    /// serialize in the v2 formats; unmarked ones keep writing the v1
    /// bytes so pre-existing captures and fixtures stay byte-identical.
    pub fn is_marked(&self) -> bool {
        self.arrivals.iter().any(|a| !a.mark.is_zero())
    }

    /// Serialize to the replay CSV (`t_s,deadline_s,eta` per line, with
    /// a header carrying the scenario constants). Traces with prompt
    /// marks write the versioned v2 header and two extra columns
    /// (`model,prompt`); unmarked traces write v1 byte-for-byte as
    /// before.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let marked = self.is_marked();
        let version = if marked { 2 } else { 1 };
        out.push_str(&format!(
            "# aigc-edge arrival trace v{version} total_bandwidth_hz={} content_bits={}\n",
            self.total_bandwidth_hz, self.content_bits
        ));
        if marked {
            out.push_str("t_s,deadline_s,eta,model,prompt\n");
            for a in &self.arrivals {
                out.push_str(&format!(
                    "{},{},{},{},{}\n",
                    a.t_s, a.deadline_s, a.link.spectral_efficiency, a.mark.model, a.mark.prompt
                ));
            }
        } else {
            out.push_str("t_s,deadline_s,eta\n");
            for a in &self.arrivals {
                out.push_str(&format!(
                    "{},{},{}\n",
                    a.t_s, a.deadline_s, a.link.spectral_efficiency
                ));
            }
        }
        out
    }

    /// Load a trace written by [`to_csv`]; f64 `Display` round-trips, so
    /// replayed simulations are bit-identical to the original.
    pub fn from_csv(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty trace file")?;
        let mut total_bandwidth_hz = 0.0;
        let mut content_bits = 0.0;
        for token in header.split_whitespace() {
            if let Some(v) = token.strip_prefix("total_bandwidth_hz=") {
                total_bandwidth_hz = v.parse().context("bad total_bandwidth_hz in header")?;
            } else if let Some(v) = token.strip_prefix("content_bits=") {
                content_bits = v.parse().context("bad content_bits in header")?;
            }
        }
        if total_bandwidth_hz <= 0.0 || content_bits <= 0.0 {
            bail!("trace header missing scenario constants: '{header}'");
        }
        let mut arrivals = Vec::new();
        let mut prev_t = f64::NEG_INFINITY;
        for (i, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with("t_s") {
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            if fields.len() != 3 && fields.len() != 5 {
                bail!(
                    "trace line {}: expected t,deadline,eta[,model,prompt], got '{line}'",
                    i + 2
                );
            }
            let t_s: f64 = fields[0].parse().with_context(|| format!("line {}: bad t", i + 2))?;
            let deadline_s: f64 =
                fields[1].parse().with_context(|| format!("line {}: bad deadline", i + 2))?;
            let eta: f64 =
                fields[2].parse().with_context(|| format!("line {}: bad eta", i + 2))?;
            let mark = if fields.len() == 5 {
                let model: u32 =
                    fields[3].parse().with_context(|| format!("line {}: bad model", i + 2))?;
                let prompt: u32 =
                    fields[4].parse().with_context(|| format!("line {}: bad prompt", i + 2))?;
                PromptMark { model, prompt }
            } else {
                PromptMark::ZERO
            };
            if t_s < prev_t {
                bail!("trace line {}: arrivals must be time-sorted", i + 2);
            }
            if deadline_s <= 0.0 || eta <= 0.0 {
                bail!("trace line {}: deadline and eta must be positive", i + 2);
            }
            prev_t = t_s;
            arrivals.push(Arrival {
                id: arrivals.len(),
                t_s,
                deadline_s,
                link: Link::new(eta),
                mark,
            });
        }
        Ok(Self { arrivals, total_bandwidth_hz, content_bits })
    }
}

/// Lazy arrival generator: yields the identical request stream as
/// [`ArrivalTrace::generate`] (same RNG draws, in the same order) one
/// arrival at a time, so a 10⁷-request sweep never materializes a
/// `Vec<Arrival>` for the whole horizon.
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    rng: Pcg64,
    channels: ChannelGenerator,
    /// Zipf prompt/model marks; `None` when the knobs are off, so
    /// disabled runs make zero extra draws.
    prompts: Option<PromptLaw>,
    settings: ArrivalSettings,
    deadline_lo: f64,
    deadline_hi: f64,
    total_bandwidth_hz: f64,
    content_bits: f64,
    /// Thinning envelope: the largest instantaneous rate.
    max_rate: f64,
    t: f64,
    next_id: usize,
}

impl ArrivalStream {
    pub fn new(scenario: &ScenarioConfig, arrival: &ArrivalSettings, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0xA221);
        let channels = ChannelGenerator::new(
            FadingModel::UniformEfficiency { lo: scenario.eta_lo, hi: scenario.eta_hi },
            rng.next_u64(),
        );
        let max_rate = match arrival.process {
            ArrivalProcessKind::Poisson => arrival.rate_hz,
            ArrivalProcessKind::Burst => arrival.burst_rate_hz.max(arrival.rate_hz),
        };
        Self {
            rng,
            channels,
            prompts: PromptLaw::from_settings(arrival, seed),
            settings: *arrival,
            deadline_lo: scenario.deadline_lo,
            deadline_hi: scenario.deadline_hi,
            total_bandwidth_hz: scenario.total_bandwidth_hz,
            content_bits: scenario.content_bits,
            max_rate,
            t: 0.0,
            next_id: 0,
        }
    }

    /// Shared scenario constant B (Hz) — carried so streaming consumers
    /// don't need the originating [`ScenarioConfig`].
    pub fn total_bandwidth_hz(&self) -> f64 {
        self.total_bandwidth_hz
    }

    /// Shared scenario constant S (bits).
    pub fn content_bits(&self) -> f64 {
        self.content_bits
    }

    /// Arrivals yielded so far.
    pub fn generated(&self) -> usize {
        self.next_id
    }
}

impl Iterator for ArrivalStream {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        if self.settings.max_requests > 0 && self.next_id >= self.settings.max_requests {
            return None;
        }
        loop {
            self.t += self.rng.exponential(self.max_rate);
            if self.t > self.settings.horizon_s {
                return None;
            }
            // Thinning: accept with probability rate(t)/max_rate. The
            // uniform draw happens for the Poisson case too so the two
            // processes consume the stream identically (a trace at
            // burst==base reproduces plain Poisson exactly).
            let accept = self.rng.uniform() < self.settings.rate_at(self.t) / self.max_rate;
            if !accept {
                continue;
            }
            let deadline_s = self.rng.uniform_in(self.deadline_lo, self.deadline_hi);
            let mark = self.prompts.as_mut().map(|p| p.draw()).unwrap_or(PromptMark::ZERO);
            let arrival = Arrival {
                id: self.next_id,
                t_s: self.t,
                deadline_s,
                link: self.channels.draw(),
                mark,
            };
            self.next_id += 1;
            return Some(arrival);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn settings(process: ArrivalProcessKind, rate: f64, horizon: f64) -> ArrivalSettings {
        ArrivalSettings {
            process,
            rate_hz: rate,
            burst_rate_hz: rate * 4.0,
            period_s: 40.0,
            duty: 0.25,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 1,
            zipf_s: 1.0,
            models: 1,
        }
    }

    fn scenario() -> ScenarioConfig {
        ExperimentConfig::paper().scenario
    }

    #[test]
    fn poisson_rate_and_marks() {
        let s = settings(ArrivalProcessKind::Poisson, 5.0, 400.0);
        let trace = ArrivalTrace::generate(&scenario(), &s, 7);
        let n = trace.len() as f64;
        // ~2000 expected; 5 sigma ≈ 112
        assert!((n - 2000.0).abs() < 250.0, "n = {n}");
        for a in &trace.arrivals {
            assert!((7.0..20.0).contains(&a.deadline_s));
            assert!((5.0..10.0).contains(&a.link.spectral_efficiency));
            assert!(a.t_s > 0.0 && a.t_s <= 400.0);
        }
    }

    #[test]
    fn arrivals_sorted_with_dense_ids() {
        let s = settings(ArrivalProcessKind::Poisson, 3.0, 100.0);
        let trace = ArrivalTrace::generate(&scenario(), &s, 1);
        for (i, a) in trace.arrivals.iter().enumerate() {
            assert_eq!(a.id, i);
        }
        assert!(trace.arrivals.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn deterministic_per_seed() {
        let s = settings(ArrivalProcessKind::Burst, 2.0, 200.0);
        let a = ArrivalTrace::generate(&scenario(), &s, 42);
        let b = ArrivalTrace::generate(&scenario(), &s, 42);
        assert_eq!(a, b);
        let c = ArrivalTrace::generate(&scenario(), &s, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn burst_concentrates_arrivals_in_duty_windows() {
        let mut s = settings(ArrivalProcessKind::Burst, 1.0, 1000.0);
        s.burst_rate_hz = 10.0;
        let trace = ArrivalTrace::generate(&scenario(), &s, 5);
        let in_burst = trace
            .arrivals
            .iter()
            .filter(|a| (a.t_s % s.period_s) < s.duty * s.period_s)
            .count() as f64;
        let frac = in_burst / trace.len() as f64;
        // expected: 10*0.25 / (10*0.25 + 1*0.75) = 0.769
        assert!(frac > 0.65 && frac < 0.88, "burst fraction {frac}");
    }

    #[test]
    fn burst_equal_rates_is_poisson() {
        let mut s = settings(ArrivalProcessKind::Burst, 4.0, 150.0);
        s.burst_rate_hz = 4.0;
        let burst = ArrivalTrace::generate(&scenario(), &s, 9);
        s.process = ArrivalProcessKind::Poisson;
        let poisson = ArrivalTrace::generate(&scenario(), &s, 9);
        assert_eq!(burst, poisson);
    }

    #[test]
    fn max_requests_caps_trace() {
        let mut s = settings(ArrivalProcessKind::Poisson, 50.0, 1000.0);
        s.max_requests = 120;
        let trace = ArrivalTrace::generate(&scenario(), &s, 3);
        assert_eq!(trace.len(), 120);
    }

    #[test]
    fn stream_matches_generate_bitwise() {
        let cases = [
            (ArrivalProcessKind::Poisson, 0),
            (ArrivalProcessKind::Burst, 0),
            (ArrivalProcessKind::Poisson, 75),
        ];
        for (process, cap) in cases {
            let mut s = settings(process, 4.0, 150.0);
            s.max_requests = cap;
            let trace = ArrivalTrace::generate(&scenario(), &s, 7);
            let streamed: Vec<Arrival> = ArrivalStream::new(&scenario(), &s, 7).collect();
            assert_eq!(trace.arrivals, streamed);
        }
        let s = settings(ArrivalProcessKind::Poisson, 4.0, 150.0);
        let stream = ArrivalStream::new(&scenario(), &s, 7);
        assert_eq!(stream.total_bandwidth_hz(), scenario().total_bandwidth_hz);
        assert_eq!(stream.content_bits(), scenario().content_bits);
    }

    #[test]
    fn csv_roundtrip_is_exact() {
        let s = settings(ArrivalProcessKind::Burst, 3.0, 120.0);
        let trace = ArrivalTrace::generate(&scenario(), &s, 11);
        assert!(trace.len() > 50);
        let replayed = ArrivalTrace::from_csv(&trace.to_csv()).unwrap();
        assert_eq!(trace, replayed);
    }

    #[test]
    fn disabled_prompts_draw_nothing_and_stay_unmarked() {
        // universe = 1, models = 1 is the off position: the trace must
        // be bit-identical to one generated before marks existed —
        // same times, deadlines, links — and every mark is ZERO.
        let off = settings(ArrivalProcessKind::Poisson, 4.0, 200.0);
        let mut on = off;
        on.prompt_universe = 100;
        on.zipf_s = 1.2;
        on.models = 3;
        let base = ArrivalTrace::generate(&scenario(), &off, 7);
        let marked = ArrivalTrace::generate(&scenario(), &on, 7);
        assert!(base.arrivals.iter().all(|a| a.mark.is_zero()));
        assert!(!base.is_marked());
        assert!(marked.is_marked());
        assert_eq!(base.len(), marked.len(), "marks must not perturb arrival times");
        for (a, b) in base.arrivals.iter().zip(&marked.arrivals) {
            assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
            assert_eq!(a.deadline_s.to_bits(), b.deadline_s.to_bits());
            assert_eq!(
                a.link.spectral_efficiency.to_bits(),
                b.link.spectral_efficiency.to_bits()
            );
        }
    }

    #[test]
    fn zipf_marks_are_skewed_deterministic_and_in_range() {
        let mut s = settings(ArrivalProcessKind::Poisson, 10.0, 400.0);
        s.prompt_universe = 50;
        s.zipf_s = 1.5;
        s.models = 4;
        let a = ArrivalTrace::generate(&scenario(), &s, 11);
        let b = ArrivalTrace::generate(&scenario(), &s, 11);
        assert_eq!(a, b, "marks replay bit-identically per seed");
        assert!(a.len() > 1000);
        let mut counts = vec![0usize; 50];
        let mut model_seen = vec![false; 4];
        for arr in &a.arrivals {
            assert!((arr.mark.prompt as usize) < 50);
            assert!((arr.mark.model as usize) < 4);
            counts[arr.mark.prompt as usize] += 1;
            model_seen[arr.mark.model as usize] = true;
        }
        assert!(model_seen.iter().all(|&m| m), "all models drawn");
        // Zipf s=1.5 over 50: rank 0 carries ~38% of the mass; the
        // head must dominate the tail decisively.
        let head = counts[0] as f64 / a.len() as f64;
        assert!(head > 0.25, "head share {head}");
        let tail: usize = counts[25..].iter().sum();
        assert!(counts[0] > tail, "rank-0 ({}) must outweigh the tail half ({tail})", counts[0]);
    }

    #[test]
    fn marked_csv_roundtrip_is_exact_and_versioned() {
        let mut s = settings(ArrivalProcessKind::Poisson, 3.0, 120.0);
        s.prompt_universe = 20;
        s.zipf_s = 1.1;
        s.models = 2;
        let trace = ArrivalTrace::generate(&scenario(), &s, 13);
        assert!(trace.is_marked());
        let csv = trace.to_csv();
        assert!(csv.starts_with("# aigc-edge arrival trace v2"), "{}", &csv[..60]);
        assert!(csv.contains("t_s,deadline_s,eta,model,prompt"));
        let replayed = ArrivalTrace::from_csv(&csv).unwrap();
        assert_eq!(trace, replayed);
        // Unmarked traces keep the v1 bytes.
        let plain_settings = settings(ArrivalProcessKind::Poisson, 3.0, 120.0);
        let plain = ArrivalTrace::generate(&scenario(), &plain_settings, 13);
        assert!(plain.to_csv().starts_with("# aigc-edge arrival trace v1"));
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(ArrivalTrace::from_csv("").is_err());
        assert!(ArrivalTrace::from_csv("# no constants\nt_s,deadline_s,eta\n").is_err());
        let good_header =
            "# aigc-edge arrival trace v1 total_bandwidth_hz=40000 content_bits=24000\n";
        assert!(ArrivalTrace::from_csv(&format!("{good_header}1.0,5.0\n")).is_err());
        assert!(ArrivalTrace::from_csv(&format!("{good_header}2.0,5.0,6.0\n1.0,5.0,6.0\n"))
            .is_err());
        assert!(ArrivalTrace::from_csv(&format!("{good_header}1.0,-5.0,6.0\n")).is_err());
        assert!(ArrivalTrace::from_csv(&format!("{good_header}1.0,5.0,6.0\n")).is_ok());
    }
}
