//! Workload generation: the device populations behind every figure.
//!
//! A [`Workload`] is K devices, each with a deadline τ_k and a downlink
//! [`Link`]; generators are seeded so every experiment replays exactly.

pub mod arrival;
pub mod columnar;

pub use arrival::{Arrival, ArrivalStream, ArrivalTrace, PromptLaw, PromptMark};
pub use columnar::ColumnarReader;

use crate::channel::{ChannelGenerator, Link};
use crate::config::ScenarioConfig;
use crate::util::Pcg64;

/// One device's service request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceRequest {
    pub id: usize,
    /// End-to-end deadline τ_k in seconds.
    pub deadline: f64,
    pub link: Link,
}

/// A complete scenario instance.
#[derive(Debug, Clone)]
pub struct Workload {
    pub devices: Vec<DeviceRequest>,
    /// Total downlink bandwidth B in Hz.
    pub total_bandwidth_hz: f64,
    /// Content size S in bits.
    pub content_bits: f64,
}

impl Workload {
    pub fn k(&self) -> usize {
        self.devices.len()
    }

    pub fn links(&self) -> Vec<Link> {
        self.devices.iter().map(|d| d.link).collect()
    }

    pub fn deadlines(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.deadline).collect()
    }
}

/// Draw a workload from a scenario config (deadlines ~ U[lo, hi],
/// η ~ U[eta_lo, eta_hi] — the paper's Section IV distributions).
pub fn generate(scenario: &ScenarioConfig, seed: u64) -> Workload {
    let mut rng = Pcg64::new(seed, 0x7ace);
    let mut channels = ChannelGenerator::new(
        crate::channel::FadingModel::UniformEfficiency {
            lo: scenario.eta_lo,
            hi: scenario.eta_hi,
        },
        rng.next_u64(),
    );
    let devices = (0..scenario.num_services)
        .map(|id| DeviceRequest {
            id,
            deadline: rng.uniform_in(scenario.deadline_lo, scenario.deadline_hi),
            link: channels.draw(),
        })
        .collect();
    Workload {
        devices,
        total_bandwidth_hz: scenario.total_bandwidth_hz,
        content_bits: scenario.content_bits,
    }
}

/// Variations used by the figure sweeps.
pub mod sweeps {
    use super::*;

    /// Fig. 2b: vary the number of services, all else per `base`.
    pub fn with_num_services(base: &ScenarioConfig, k: usize) -> ScenarioConfig {
        let mut s = base.clone();
        s.num_services = k;
        s
    }

    /// Fig. 2c: vary the minimum delay requirement, max fixed at
    /// `base.deadline_hi`.
    pub fn with_min_deadline(base: &ScenarioConfig, lo: f64) -> ScenarioConfig {
        let mut s = base.clone();
        s.deadline_lo = lo;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn scenario() -> ScenarioConfig {
        ExperimentConfig::paper().scenario
    }

    #[test]
    fn respects_distributions() {
        let w = generate(&scenario(), 1);
        assert_eq!(w.k(), 20);
        for d in &w.devices {
            assert!((7.0..20.0).contains(&d.deadline));
            assert!((5.0..10.0).contains(&d.link.spectral_efficiency));
        }
        assert_eq!(w.total_bandwidth_hz, 40_000.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&scenario(), 42);
        let b = generate(&scenario(), 42);
        assert_eq!(a.devices, b.devices);
        let c = generate(&scenario(), 43);
        assert_ne!(a.devices, c.devices);
    }

    #[test]
    fn ids_are_dense() {
        let w = generate(&scenario(), 5);
        for (i, d) in w.devices.iter().enumerate() {
            assert_eq!(d.id, i);
        }
    }

    #[test]
    fn sweeps_change_one_axis() {
        let base = scenario();
        let k = sweeps::with_num_services(&base, 35);
        assert_eq!(k.num_services, 35);
        assert_eq!(k.deadline_lo, base.deadline_lo);
        let d = sweeps::with_min_deadline(&base, 3.0);
        assert_eq!(d.deadline_lo, 3.0);
        assert_eq!(d.num_services, base.num_services);
    }

    #[test]
    fn deadline_spread_covers_range() {
        let mut lo_seen = f64::INFINITY;
        let mut hi_seen = f64::NEG_INFINITY;
        for seed in 0..50 {
            for d in generate(&scenario(), seed).devices {
                lo_seen = lo_seen.min(d.deadline);
                hi_seen = hi_seen.max(d.deadline);
            }
        }
        assert!(lo_seen < 8.0, "lo={lo_seen}");
        assert!(hi_seen > 19.0, "hi={hi_seen}");
    }
}
