//! Compact binary columnar encoding for arrival traces.
//!
//! The replay CSV is human-greppable but costs ~40 bytes of text per
//! request and must be fully parsed before the first arrival replays —
//! hopeless at the 10⁷-request scale the streaming metrics layer
//! targets. This format stores the same three per-request columns
//! (`t_s`, `deadline_s`, `eta`) as raw little-endian f64 bit patterns
//! in fixed-size chunks:
//!
//! ```text
//! [magic 8B "AIGCTRC\0"] [version u32] [chunk_len u32]
//! [total_bandwidth_hz f64] [content_bits f64] [count u64]
//! repeated frames: [n u32] [t_s f64 × n] [deadline_s f64 × n] [eta f64 × n]
//! ```
//!
//! Round-trips are bit-identical with the CSV path (both preserve the
//! exact f64 bits), 24 bytes per request, and [`ColumnarReader`]
//! replays chunk-by-chunk so a simulation can consume arrivals without
//! holding the whole `Vec<Arrival>`.
//!
//! Traces carrying prompt marks write version 2: each frame appends
//! two u32 columns (`model × n`, `prompt × n`, 32 bytes per request
//! total). Unmarked traces keep emitting the version-1 bytes
//! unchanged, and the reader accepts both.

use anyhow::{bail, ensure, Result};

use crate::channel::Link;
use crate::trace::{Arrival, ArrivalTrace, PromptMark};

const MAGIC: &[u8; 8] = b"AIGCTRC\0";
const VERSION: u32 = 1;
/// Version written when any arrival carries a non-zero prompt mark.
const VERSION_MARKED: u32 = 2;
const HEADER_LEN: usize = 8 + 4 + 4 + 8 + 8 + 8;
/// Default requests per frame: 64 KiB of payload per column chunk.
pub const DEFAULT_CHUNK_LEN: usize = 8192;

// Shared with `obs::span`, which frames its span streams the same way.
pub(crate) fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn read_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    ensure!(bytes.len() >= *pos + 4, "columnar trace truncated at byte {}", *pos);
    let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

pub(crate) fn read_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    ensure!(bytes.len() >= *pos + 8, "columnar trace truncated at byte {}", *pos);
    let v = u64::from_le_bytes(bytes[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

pub(crate) fn read_f64(bytes: &[u8], pos: &mut usize) -> Result<f64> {
    Ok(f64::from_bits(read_u64(bytes, pos)?))
}

/// Encode a trace with the given chunk length (requests per frame).
/// `chunk_len` must fit the frame header's u32 — silently truncating
/// it would emit frames the reader cannot reconcile with the count.
pub fn encode_chunked(trace: &ArrivalTrace, chunk_len: usize) -> Vec<u8> {
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert!(
        chunk_len <= u32::MAX as usize,
        "chunk_len {chunk_len} exceeds the u32 frame header"
    );
    let n = trace.arrivals.len();
    let marked = trace.is_marked();
    let stride = if marked { 32 } else { 24 };
    let mut out = Vec::with_capacity(HEADER_LEN + n * stride + (n / chunk_len + 1) * 4);
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, if marked { VERSION_MARKED } else { VERSION });
    push_u32(&mut out, chunk_len as u32);
    push_f64(&mut out, trace.total_bandwidth_hz);
    push_f64(&mut out, trace.content_bits);
    push_u64(&mut out, n as u64);
    for chunk in trace.arrivals.chunks(chunk_len) {
        push_u32(&mut out, chunk.len() as u32);
        for a in chunk {
            push_f64(&mut out, a.t_s);
        }
        for a in chunk {
            push_f64(&mut out, a.deadline_s);
        }
        for a in chunk {
            push_f64(&mut out, a.link.spectral_efficiency);
        }
        if marked {
            for a in chunk {
                push_u32(&mut out, a.mark.model);
            }
            for a in chunk {
                push_u32(&mut out, a.mark.prompt);
            }
        }
    }
    out
}

/// Encode with the default chunk length.
pub fn encode(trace: &ArrivalTrace) -> Vec<u8> {
    encode_chunked(trace, DEFAULT_CHUNK_LEN)
}

/// Decode a complete trace (ids re-densified in arrival order), with
/// the same validation as the CSV loader: time-sorted arrivals and
/// positive deadlines/η.
pub fn decode(bytes: &[u8]) -> Result<ArrivalTrace> {
    let mut reader = ColumnarReader::new(bytes)?;
    let mut arrivals = Vec::with_capacity(reader.remaining());
    for a in &mut reader {
        arrivals.push(a?);
    }
    Ok(ArrivalTrace {
        arrivals,
        total_bandwidth_hz: reader.total_bandwidth_hz,
        content_bits: reader.content_bits,
    })
}

/// Chunked replay: yields arrivals one at a time, buffering at most one
/// frame, so consumers (`sim::dynamic`'s streaming entry) never hold
/// the whole trace.
#[derive(Debug)]
pub struct ColumnarReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Scenario constant B (Hz) from the header.
    pub total_bandwidth_hz: f64,
    /// Scenario constant S (bits) from the header.
    pub content_bits: f64,
    count: usize,
    next_id: usize,
    prev_t: f64,
    /// Version-2 stream: frames carry the two u32 mark columns.
    marked: bool,
    chunk: Vec<Arrival>,
    chunk_pos: usize,
    failed: bool,
}

impl<'a> ColumnarReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Result<Self> {
        let mut pos = 0usize;
        ensure!(bytes.len() >= HEADER_LEN, "columnar trace shorter than its header");
        ensure!(&bytes[..8] == MAGIC, "not a columnar arrival trace (bad magic)");
        pos += 8;
        let version = read_u32(bytes, &mut pos)?;
        ensure!(
            version == VERSION || version == VERSION_MARKED,
            "unsupported columnar trace version {version}"
        );
        let chunk_len = read_u32(bytes, &mut pos)?;
        ensure!(chunk_len > 0, "columnar trace declares zero chunk length");
        let total_bandwidth_hz = read_f64(bytes, &mut pos)?;
        let content_bits = read_f64(bytes, &mut pos)?;
        // A NaN (e.g. zeroed/absent bytes decoded as garbage) means the
        // constants are effectively missing; a finite nonpositive value
        // is present but invalid — report which, so a writer bug is
        // distinguishable from a truncated/blank header.
        if !total_bandwidth_hz.is_finite() || !content_bits.is_finite() {
            bail!("columnar trace header missing scenario constants");
        }
        if total_bandwidth_hz <= 0.0 || content_bits <= 0.0 {
            bail!(
                "columnar trace header has nonpositive scenario constants \
                 (bandwidth {total_bandwidth_hz} Hz, content {content_bits} bits)"
            );
        }
        let count = read_u64(bytes, &mut pos)? as usize;
        Ok(Self {
            bytes,
            pos,
            total_bandwidth_hz,
            content_bits,
            count,
            next_id: 0,
            prev_t: f64::NEG_INFINITY,
            marked: version == VERSION_MARKED,
            chunk: Vec::new(),
            chunk_pos: 0,
            failed: false,
        })
    }

    /// Total arrivals declared by the header.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Arrivals not yet yielded.
    pub fn remaining(&self) -> usize {
        self.count - self.next_id
    }

    fn load_frame(&mut self) -> Result<()> {
        let n = read_u32(self.bytes, &mut self.pos)? as usize;
        ensure!(n > 0, "columnar trace frame at byte {} is empty", self.pos - 4);
        ensure!(
            self.next_id + n <= self.count,
            "columnar trace frames exceed declared count {}",
            self.count
        );
        self.chunk.clear();
        self.chunk.reserve(n);
        let t_base = self.pos;
        for i in 0..n {
            let mut pos = t_base + 8 * i;
            let t_s = read_f64(self.bytes, &mut pos)?;
            let mut pos = t_base + 8 * (n + i);
            let deadline_s = read_f64(self.bytes, &mut pos)?;
            let mut pos = t_base + 8 * (2 * n + i);
            let eta = read_f64(self.bytes, &mut pos)?;
            let mark = if self.marked {
                let mut pos = t_base + 24 * n + 4 * i;
                let model = read_u32(self.bytes, &mut pos)?;
                let mut pos = t_base + 24 * n + 4 * (n + i);
                let prompt = read_u32(self.bytes, &mut pos)?;
                PromptMark { model, prompt }
            } else {
                PromptMark::ZERO
            };
            if t_s < self.prev_t {
                bail!("columnar trace: arrivals must be time-sorted (id {})", self.next_id + i);
            }
            if deadline_s <= 0.0 || eta <= 0.0 {
                bail!(
                    "columnar trace: deadline and eta must be positive (id {})",
                    self.next_id + i
                );
            }
            self.prev_t = t_s;
            let arrival =
                Arrival { id: self.next_id + i, t_s, deadline_s, link: Link::new(eta), mark };
            self.chunk.push(arrival);
        }
        self.pos = t_base + if self.marked { 32 * n } else { 24 * n };
        self.chunk_pos = 0;
        Ok(())
    }
}

impl Iterator for ColumnarReader<'_> {
    type Item = Result<Arrival>;

    fn next(&mut self) -> Option<Result<Arrival>> {
        if self.failed || self.next_id >= self.count {
            return None;
        }
        if self.chunk_pos >= self.chunk.len() {
            if let Err(e) = self.load_frame() {
                self.failed = true;
                return Some(Err(e));
            }
        }
        let a = self.chunk[self.chunk_pos];
        self.chunk_pos += 1;
        self.next_id += 1;
        Some(Ok(a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};

    fn seed7_trace() -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Burst,
            rate_hz: 3.0,
            burst_rate_hz: 9.0,
            period_s: 40.0,
            duty: 0.25,
            horizon_s: 120.0,
            max_requests: 0,
            prompt_universe: 1,
            zipf_s: 1.0,
            models: 1,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, 7)
    }

    fn marked_trace() -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: 4.0,
            burst_rate_hz: 4.0,
            period_s: 40.0,
            duty: 0.25,
            horizon_s: 120.0,
            max_requests: 0,
            prompt_universe: 30,
            zipf_s: 1.3,
            models: 3,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, 7)
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let trace = seed7_trace();
        assert!(trace.len() > 100);
        let decoded = decode(&encode(&trace)).unwrap();
        assert_eq!(trace, decoded);
    }

    #[test]
    fn roundtrip_matches_csv_roundtrip() {
        let trace = seed7_trace();
        let via_csv = ArrivalTrace::from_csv(&trace.to_csv()).unwrap();
        let via_columnar = decode(&encode(&trace)).unwrap();
        assert_eq!(via_csv, via_columnar);
    }

    #[test]
    fn chunk_length_does_not_change_payload() {
        let trace = seed7_trace();
        for chunk_len in [1, 7, 64, 100_000] {
            let decoded = decode(&encode_chunked(&trace, chunk_len)).unwrap();
            assert_eq!(trace, decoded, "chunk_len={chunk_len}");
        }
    }

    #[test]
    fn reader_streams_with_bounded_buffer() {
        let trace = seed7_trace();
        let bytes = encode_chunked(&trace, 32);
        let mut reader = ColumnarReader::new(&bytes).unwrap();
        assert_eq!(reader.len(), trace.len());
        let mut seen = 0usize;
        for (a, expect) in (&mut reader).zip(&trace.arrivals) {
            let a = a.unwrap();
            assert_eq!(&a, expect);
            seen += 1;
        }
        assert_eq!(seen, trace.len());
        assert_eq!(reader.remaining(), 0);
        assert!(reader.next().is_none());
    }

    #[test]
    fn empty_trace_roundtrips() {
        let trace = ArrivalTrace {
            arrivals: Vec::new(),
            total_bandwidth_hz: 40_000.0,
            content_bits: 24_000.0,
        };
        let decoded = decode(&encode(&trace)).unwrap();
        assert_eq!(trace, decoded);
    }

    #[test]
    fn size_is_24_bytes_per_request_plus_overhead() {
        let trace = seed7_trace();
        let bytes = encode(&trace);
        let overhead = bytes.len() - 24 * trace.len();
        assert!(overhead < 64, "overhead {overhead}");
        assert!(bytes.len() < trace.to_csv().len(), "binary should beat CSV text");
    }

    #[test]
    fn marked_trace_roundtrips_as_version_2() {
        let trace = marked_trace();
        assert!(trace.is_marked());
        let bytes = encode(&trace);
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        assert_eq!(version, VERSION_MARKED);
        let decoded = decode(&bytes).unwrap();
        assert_eq!(trace, decoded);
        // 32 bytes per request once the two u32 mark columns ride along.
        let overhead = bytes.len() - 32 * trace.len();
        assert!(overhead < 64, "overhead {overhead}");
        // Chunking still never changes the payload.
        for chunk_len in [1, 7, 64] {
            let decoded = decode(&encode_chunked(&trace, chunk_len)).unwrap();
            assert_eq!(trace, decoded, "chunk_len={chunk_len}");
        }
    }

    #[test]
    fn unmarked_trace_still_writes_version_1_bytes() {
        let trace = seed7_trace();
        let bytes = encode(&trace);
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        assert_eq!(version, VERSION, "unmarked traces must stay loadable by v1 readers");
        assert_eq!(bytes.len(), HEADER_LEN + 24 * trace.len() + 4 * trace.len().div_ceil(8192));
    }

    #[test]
    fn rejects_corrupt_inputs() {
        let trace = seed7_trace();
        let good = encode(&trace);
        assert!(decode(&good[..10]).is_err(), "truncated header");
        assert!(decode(&good[..good.len() - 5]).is_err(), "truncated frame");
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(decode(&bad_magic).is_err(), "bad magic");
        let mut bad_version = good.clone();
        bad_version[8] = 99;
        assert!(decode(&bad_version).is_err(), "bad version");
        // Flip a deadline sign inside the first frame: the 40-byte
        // header ends with the count, frame n follows at byte 40, the
        // t column at 44, then the deadline column.
        let mut negative_deadline = good.clone();
        let n0 = u32::from_le_bytes(good[40..44].try_into().unwrap()) as usize;
        let deadline0_at = 44 + 8 * n0;
        let d = f64::from_le_bytes(good[deadline0_at..deadline0_at + 8].try_into().unwrap());
        negative_deadline[deadline0_at..deadline0_at + 8].copy_from_slice(&(-d).to_le_bytes());
        assert!(decode(&negative_deadline).is_err(), "negative deadline");
    }

    /// Regression: a frame header is a u32, so a chunk length above
    /// u32::MAX used to truncate silently and emit frames the reader
    /// could never reconcile with the declared count. It must refuse.
    #[test]
    #[should_panic(expected = "u32 frame header")]
    #[cfg(target_pointer_width = "64")]
    fn oversized_chunk_len_is_rejected_not_truncated() {
        let trace = ArrivalTrace {
            arrivals: Vec::new(),
            total_bandwidth_hz: 40_000.0,
            content_bits: 24_000.0,
        };
        encode_chunked(&trace, u32::MAX as usize + 1);
    }

    /// Regression: a present-but-nonpositive scenario constant used to
    /// be reported as "missing", hiding writer bugs behind the wrong
    /// diagnosis. The two failure modes must read differently.
    #[test]
    fn header_distinguishes_missing_from_nonpositive_constants() {
        let trace = seed7_trace();
        let good = encode(&trace);
        // Bandwidth f64 lives at bytes 16..24 (magic 8, version 4,
        // chunk_len 4), content bits at 24..32.
        let mut nonpositive = good.clone();
        nonpositive[16..24].copy_from_slice(&(-5.0f64).to_le_bytes());
        let err = decode(&nonpositive).unwrap_err().to_string();
        assert!(err.contains("nonpositive"), "got: {err}");
        assert!(!err.contains("missing"), "got: {err}");
        let mut zeroed = good.clone();
        zeroed[24..32].copy_from_slice(&0.0f64.to_le_bytes());
        let err = decode(&zeroed).unwrap_err().to_string();
        assert!(err.contains("nonpositive"), "zero is present but invalid: {err}");
        let mut nan = good;
        nan[16..24].copy_from_slice(&f64::NAN.to_le_bytes());
        let err = decode(&nan).unwrap_err().to_string();
        assert!(err.contains("missing"), "NaN reads as absent: {err}");
    }
}
