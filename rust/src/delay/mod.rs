//! Batch denoising delay model — Eq. (4) of the paper:
//!
//! `g(X) = a·X + b·‖X‖₀`
//!
//! i.e. affine in the batch size with a fixed per-batch cost `b`
//! (weight/activation streaming, kernel launch) and a marginal per-task
//! cost `a`. `g(0) = 0`. Fig. 1a measures a = 0.0240 s, b = 0.3543 s on
//! an RTX 3050; `examples/profile_batch.rs` re-measures both on this
//! machine's PJRT runtime and [`DelayFit`] re-fits them.

use crate::util::{fit_linear, LinearFit};

/// The affine batch-delay model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchDelayModel {
    /// Marginal per-task seconds (slope).
    pub a: f64,
    /// Fixed per-batch seconds (intercept), charged iff the batch is
    /// non-empty (the ℓ₀ term).
    pub b: f64,
}

impl BatchDelayModel {
    pub fn new(a: f64, b: f64) -> Self {
        assert!(a >= 0.0 && b >= 0.0, "negative delay constants");
        Self { a, b }
    }

    /// The paper's measured constants (DDIM on CIFAR-10, RTX 3050).
    pub fn paper() -> Self {
        Self::new(0.0240, 0.3543)
    }

    /// Denoising delay of a batch with `x` tasks (Eq. 4). `g(0) = 0`.
    #[inline]
    pub fn g(&self, x: u32) -> f64 {
        if x == 0 {
            0.0
        } else {
            self.a * x as f64 + self.b
        }
    }

    /// Per-task cost at batch size `x` — the amortization batching buys.
    pub fn per_task(&self, x: u32) -> f64 {
        assert!(x > 0);
        self.g(x) / x as f64
    }

    /// Largest batch size whose delay fits in `budget` seconds
    /// (0 if even a singleton batch does not fit).
    pub fn max_batch_within(&self, budget: f64) -> u32 {
        if budget < self.g(1) {
            return 0;
        }
        if self.a == 0.0 {
            return u32::MAX;
        }
        // epsilon guards the exact-boundary case against float rounding
        (((budget - self.b) / self.a) + 1e-9).floor() as u32
    }

    /// Time for one service to run `steps` sequential singleton batches —
    /// the single-instance (no batching) reference point.
    pub fn single_instance_delay(&self, steps: u32) -> f64 {
        steps as f64 * self.g(1)
    }
}

/// Fit the model from measured (batch size, seconds) samples — the
/// Fig. 1a procedure.
#[derive(Debug, Clone)]
pub struct DelayFit {
    pub fit: LinearFit,
    pub samples: Vec<(u32, f64)>,
}

impl DelayFit {
    /// Least-squares `y = a·x + b` over the measurements. Requires at
    /// least two distinct batch sizes.
    pub fn from_samples(samples: &[(u32, f64)]) -> Self {
        let xs: Vec<f64> = samples.iter().map(|s| s.0 as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|s| s.1).collect();
        let fit = fit_linear(&xs, &ys);
        Self { fit, samples: samples.to_vec() }
    }

    /// The fitted model (slope/intercept clamped to be non-negative:
    /// measurement noise on a flat curve may produce slightly negative
    /// estimates).
    pub fn model(&self) -> BatchDelayModel {
        BatchDelayModel::new(self.fit.a.max(0.0), self.fit.b.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn paper_constants() {
        let m = BatchDelayModel::paper();
        assert!(approx_eq(m.g(1), 0.3783, 1e-9));
        assert!(approx_eq(m.g(20), 0.0240 * 20.0 + 0.3543, 1e-9));
        assert_eq!(m.g(0), 0.0);
    }

    #[test]
    fn batching_amortizes_fixed_cost() {
        let m = BatchDelayModel::paper();
        // Per-task cost strictly decreasing in batch size.
        let mut prev = m.per_task(1);
        for x in 2..=32 {
            let cur = m.per_task(x);
            assert!(cur < prev, "per-task not decreasing at X={x}");
            prev = cur;
        }
        // The b >> a regime the paper exploits: one 20-batch beats
        // 20 singletons by ~an order of magnitude.
        assert!(20.0 * m.g(1) > 8.0 * m.g(20));
    }

    #[test]
    fn max_batch_within_budget() {
        let m = BatchDelayModel::new(0.1, 0.5);
        assert_eq!(m.max_batch_within(0.05), 0); // can't fit even X=1
        assert_eq!(m.max_batch_within(0.6), 1);
        assert_eq!(m.max_batch_within(1.5), 10);
        // exact boundary
        assert_eq!(m.max_batch_within(0.5 + 0.1 * 7.0), 7);
    }

    #[test]
    fn single_instance_is_linear_in_steps() {
        let m = BatchDelayModel::paper();
        assert!(approx_eq(m.single_instance_delay(10), 10.0 * m.g(1), 1e-12));
    }

    #[test]
    fn fit_recovers_paper_constants_from_exact_samples() {
        let m = BatchDelayModel::paper();
        let samples: Vec<(u32, f64)> = (1..=32).map(|x| (x, m.g(x))).collect();
        let fit = DelayFit::from_samples(&samples);
        assert!(approx_eq(fit.fit.a, m.a, 1e-9));
        assert!(approx_eq(fit.fit.b, m.b, 1e-9));
        assert!(fit.fit.r2 > 0.999_999);
    }

    #[test]
    fn fit_with_noise_close() {
        let m = BatchDelayModel::new(0.05, 0.2);
        let mut rng = crate::util::Pcg64::seeded(17);
        let samples: Vec<(u32, f64)> =
            (1..=32).map(|x| (x, m.g(x) * (1.0 + 0.01 * rng.normal()))).collect();
        let fit = DelayFit::from_samples(&samples).model();
        assert!(approx_eq(fit.a, m.a, 0.05));
        assert!(approx_eq(fit.b, m.b, 0.05));
    }

    #[test]
    fn fit_clamps_negative_noise_estimates() {
        // All-equal y: slope 0 exactly; tiny negative slope from noise
        // must clamp to zero rather than panic.
        let samples = vec![(1u32, 0.5), (2, 0.5), (3, 0.4999)];
        let m = DelayFit::from_samples(&samples).model();
        assert!(m.a >= 0.0 && m.b >= 0.0);
    }

    #[test]
    #[should_panic]
    fn negative_constants_rejected() {
        BatchDelayModel::new(-0.1, 0.3);
    }
}
