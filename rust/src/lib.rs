//! # aigc-edge
//!
//! A production-grade reproduction of *"Batch Denoising for AIGC Service
//! Provisioning in Wireless Edge Networks"* (Xu, Guo, Teng, Liu, Feng —
//! CS.DC 2025): an edge server runs a diffusion model for K mobile
//! devices with heterogeneous deadlines, jointly optimizing **batch
//! denoising** (the STACKING algorithm) and **downlink bandwidth
//! allocation** (PSO).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** Pallas kernels + **L2** JAX DDIM step — compiled AOT by
//!   `make artifacts` into HLO-text executables, one per batch-size
//!   bucket.
//! * **L3** (this crate) — the serving coordinator: schedulers,
//!   bandwidth allocators, the wireless/delay models, an offline
//!   simulator for the paper's figures, and an online engine that
//!   executes the real artifacts through PJRT.

pub mod bandwidth;
pub mod bench;
pub mod cache;
pub mod channel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod delay;
pub mod faults;
pub mod metrics;
pub mod obs;
pub mod quality;
pub mod routing;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod trace;
pub mod util;

// PJRT bindings: the in-tree stub keeps the crate building and testable
// without libxla_extension; `--features pjrt` drops the stub so `xla::`
// paths resolve to the real crate (which must then be supplied — see
// rust/Cargo.toml).
#[cfg(not(feature = "pjrt"))]
#[path = "runtime/xla_stub.rs"]
pub mod xla;
