//! Typed view of `artifacts/manifest.json` — the contract between the
//! Python AOT pipeline and this runtime (pinned on the Python side by
//! `python/tests/test_aot.py::TestManifestContract`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Latent dimensionality D (f32 row per denoising task).
    pub data_dim: usize,
    /// Diffusion training discretization (timestep indices are 0..=N).
    pub num_train_steps: usize,
    /// Batch-size buckets, ascending.
    pub buckets: Vec<u32>,
    /// bucket -> HLO text file name (relative to the artifacts dir).
    pub hlo_files: BTreeMap<u32, String>,
    /// bucket -> golden test-vector file name (optional).
    pub golden_files: BTreeMap<u32, String>,
    /// File with target-distribution moments (mu then cov, f32 LE).
    pub moments_file: Option<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let data_dim = doc.required("data_dim")?.as_usize().context("data_dim")?;
        let num_train_steps =
            doc.required("num_train_steps")?.as_usize().context("num_train_steps")?;
        let buckets: Vec<u32> = doc
            .required("buckets")?
            .as_arr()
            .context("buckets not an array")?
            .iter()
            .map(|b| b.as_usize().map(|v| v as u32).context("bucket not an integer"))
            .collect::<Result<_>>()?;
        if buckets.is_empty() {
            bail!("manifest has no buckets");
        }
        if buckets.windows(2).any(|w| w[0] >= w[1]) {
            bail!("buckets must be strictly ascending: {buckets:?}");
        }
        let hlo = doc.required("hlo")?;
        let mut hlo_files = BTreeMap::new();
        for &b in &buckets {
            let entry = hlo
                .get(&b.to_string())
                .with_context(|| format!("missing hlo entry for bucket {b}"))?;
            let file = entry.required("file")?.as_str().context("hlo file")?;
            hlo_files.insert(b, file.to_string());
        }
        let mut golden_files = BTreeMap::new();
        if let Some(Json::Obj(map)) = doc.get("golden") {
            for (k, v) in map {
                if let (Ok(bucket), Some(file)) = (k.parse::<u32>(), v.as_str()) {
                    golden_files.insert(bucket, file.to_string());
                }
            }
        }
        let moments_file = doc.get("moments").and_then(|m| m.as_str()).map(str::to_string);
        Ok(Self { data_dim, num_train_steps, buckets, hlo_files, golden_files, moments_file })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "data_dim": 64,
        "num_train_steps": 1000,
        "buckets": [1, 2, 4],
        "hlo": {
            "1": {"file": "denoise_b1.hlo.txt"},
            "2": {"file": "denoise_b2.hlo.txt"},
            "4": {"file": "denoise_b4.hlo.txt"}
        },
        "golden": {"1": "golden_b1.bin", "2": "golden_b2.bin"},
        "moments": "moments.bin"
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.data_dim, 64);
        assert_eq!(m.num_train_steps, 1000);
        assert_eq!(m.buckets, vec![1, 2, 4]);
        assert_eq!(m.hlo_files[&2], "denoise_b2.hlo.txt");
        assert_eq!(m.golden_files.len(), 2);
        assert_eq!(m.moments_file.as_deref(), Some("moments.bin"));
    }

    #[test]
    fn rejects_missing_bucket_entry() {
        let bad =
            SAMPLE.replace("\"4\": {\"file\": \"denoise_b4.hlo.txt\"}", "\"9\": {\"file\": \"x\"}");
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(err.to_string().contains("bucket 4"), "{err}");
    }

    #[test]
    fn rejects_unsorted_buckets() {
        let bad = SAMPLE.replace("[1, 2, 4]", "[2, 1, 4]");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn golden_and_moments_optional() {
        let minimal = r#"{
            "data_dim": 8, "num_train_steps": 100, "buckets": [1],
            "hlo": {"1": {"file": "f.hlo.txt"}}
        }"#;
        let m = Manifest::parse(minimal).unwrap();
        assert!(m.golden_files.is_empty());
        assert!(m.moments_file.is_none());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let path = crate::config::default_artifacts_dir().join("manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.data_dim, 64);
        assert!(m.buckets.contains(&1));
        assert_eq!(m.hlo_files.len(), m.buckets.len());
    }
}
