//! Batch execution of the denoising step through PJRT.
//!
//! A scheduled batch `n` (from STACKING or a baseline) contains `X_n`
//! heterogeneous tasks: latents at possibly different timesteps. The
//! executor pads the batch to the nearest compiled bucket, builds the
//! three input literals (x, t_cur, t_prev), executes, and returns the
//! advanced latents. Padding rows replay row 0's inputs (any valid
//! timestep pair works — padded outputs are discarded).

use anyhow::{bail, Context, Result};

#[cfg(not(feature = "pjrt"))]
use crate::xla;

use super::ArtifactStore;

/// One task's inputs within a batch.
#[derive(Debug, Clone)]
pub struct BatchInput<'a> {
    /// Latent row, length = manifest.data_dim.
    pub latent: &'a [f32],
    /// Current timestep index (1..=num_train_steps).
    pub t_cur: i32,
    /// Target timestep index (0..t_cur).
    pub t_prev: i32,
}

/// The advanced latents, one row per input task (padding removed).
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub latents: Vec<Vec<f32>>,
    /// Bucket actually executed (≥ the requested batch size).
    pub bucket: u32,
    /// Wall-clock seconds of the PJRT execution alone.
    pub exec_seconds: f64,
}

/// Executes denoising batches against an [`ArtifactStore`].
pub struct DenoiseExecutor<'a> {
    store: &'a ArtifactStore,
    /// Scratch for the padded input batch (reused across calls — the
    /// request path allocates nothing beyond PJRT's own buffers).
    x_scratch: Vec<f32>,
    t_cur_scratch: Vec<i32>,
    t_prev_scratch: Vec<i32>,
}

impl<'a> DenoiseExecutor<'a> {
    pub fn new(store: &'a ArtifactStore) -> Self {
        let top = store.max_bucket() as usize;
        let dim = store.manifest().data_dim;
        Self {
            store,
            x_scratch: vec![0.0; top * dim],
            t_cur_scratch: vec![0; top],
            t_prev_scratch: vec![0; top],
        }
    }

    pub fn data_dim(&self) -> usize {
        self.store.manifest().data_dim
    }

    /// Execute one denoising step for a batch of tasks.
    pub fn step(&mut self, tasks: &[BatchInput<'_>]) -> Result<StepOutput> {
        if tasks.is_empty() {
            bail!("empty batch");
        }
        let dim = self.data_dim();
        let n = tasks.len() as u32;
        let bucket = self
            .store
            .bucket_for(n)
            .with_context(|| {
                format!("batch of {n} exceeds top bucket {}", self.store.max_bucket())
            })?;
        let bs = bucket as usize;

        for (i, task) in tasks.iter().enumerate() {
            if task.latent.len() != dim {
                bail!("task {i}: latent len {} != data_dim {dim}", task.latent.len());
            }
            if task.t_prev < 0 || task.t_cur <= task.t_prev {
                bail!("task {i}: invalid timestep pair ({}, {})", task.t_cur, task.t_prev);
            }
            self.x_scratch[i * dim..(i + 1) * dim].copy_from_slice(task.latent);
            self.t_cur_scratch[i] = task.t_cur;
            self.t_prev_scratch[i] = task.t_prev;
        }
        // Padding rows: replay row 0 (valid inputs, outputs discarded).
        for i in tasks.len()..bs {
            self.x_scratch.copy_within(0..dim, i * dim);
            self.t_cur_scratch[i] = self.t_cur_scratch[0];
            self.t_prev_scratch[i] = self.t_prev_scratch[0];
        }

        let x_lit = xla::Literal::vec1(&self.x_scratch[..bs * dim])
            .reshape(&[bs as i64, dim as i64])
            .context("reshape x")?;
        let t_cur_lit = xla::Literal::vec1(&self.t_cur_scratch[..bs]);
        let t_prev_lit = xla::Literal::vec1(&self.t_prev_scratch[..bs]);

        let exe = self.store.executable(bucket).context("missing executable")?;
        let start = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&[x_lit, t_cur_lit, t_prev_lit])
            .context("PJRT execute")?;
        let lit = result[0][0].to_literal_sync().context("fetch result")?;
        let exec_seconds = start.elapsed().as_secs_f64();

        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit.to_tuple1().context("unwrap tuple")?;
        let flat: Vec<f32> = out.to_vec().context("result to_vec")?;
        if flat.len() != bs * dim {
            bail!("result length {} != {}", flat.len(), bs * dim);
        }
        let latents =
            tasks.iter().enumerate().map(|(i, _)| flat[i * dim..(i + 1) * dim].to_vec()).collect();
        Ok(StepOutput { latents, bucket, exec_seconds })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_dir;
    use crate::runtime::ArtifactStore;

    fn store() -> Option<ArtifactStore> {
        let dir = default_artifacts_dir();
        dir.join("manifest.json").exists().then(|| ArtifactStore::load(&dir).unwrap())
    }

    #[test]
    fn rejects_bad_inputs() {
        let Some(store) = store() else { return };
        let mut exec = DenoiseExecutor::new(&store);
        assert!(exec.step(&[]).is_err());
        let short = vec![0.0f32; 3];
        assert!(exec
            .step(&[BatchInput { latent: &short, t_cur: 10, t_prev: 5 }])
            .is_err());
        let ok_len = vec![0.0f32; exec.data_dim()];
        // t_prev >= t_cur
        assert!(exec
            .step(&[BatchInput { latent: &ok_len, t_cur: 5, t_prev: 5 }])
            .is_err());
    }

    #[test]
    fn executes_singleton_batch() {
        let Some(store) = store() else { return };
        let mut exec = DenoiseExecutor::new(&store);
        let latent = vec![0.1f32; exec.data_dim()];
        let out = exec
            .step(&[BatchInput { latent: &latent, t_cur: 1000, t_prev: 900 }])
            .unwrap();
        assert_eq!(out.latents.len(), 1);
        assert_eq!(out.latents[0].len(), exec.data_dim());
        assert_eq!(out.bucket, 1);
        assert!(out.latents[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn padding_matches_unpadded_rows() {
        // A 3-task batch runs in the 4-bucket; each row must equal the
        // same task run alone (bucketing must not change numerics).
        let Some(store) = store() else { return };
        let mut exec = DenoiseExecutor::new(&store);
        let dim = exec.data_dim();
        let latents: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..dim).map(|j| ((i * dim + j) % 17) as f32 * 0.05 - 0.4).collect())
            .collect();
        let ts = [(1000, 800), (600, 400), (200, 0)];
        let batch: Vec<BatchInput> = latents
            .iter()
            .zip(&ts)
            .map(|(l, &(c, p))| BatchInput { latent: l, t_cur: c, t_prev: p })
            .collect();
        let out = exec.step(&batch).unwrap();
        assert_eq!(out.bucket, 4);
        for (i, (l, &(c, p))) in latents.iter().zip(&ts).enumerate() {
            let single = exec.step(&[BatchInput { latent: l, t_cur: c, t_prev: p }]).unwrap();
            for (a, b) in out.latents[i].iter().zip(&single.latents[0]) {
                assert!((a - b).abs() < 2e-3, "row {i}: {a} vs {b}");
            }
        }
    }
}
