//! Stub replacement for the `xla` PJRT bindings, compiled when the
//! `pjrt` feature is off (the default in the offline environment).
//!
//! The stub keeps the whole crate — including the artifact loading
//! paths and their failure-injection tests — compiling and running
//! without libxla_extension:
//!
//! * manifest/HLO *loading* behaves like the real bindings (files are
//!   read and sanity-checked, so corrupted artifacts still fail loudly
//!   with the same error shapes the tests pin);
//! * *execution* returns a descriptive error, so every artifact-gated
//!   test or example that would actually run a denoising batch skips or
//!   fails with an actionable message instead of linking errors.
//!
//! With `--features pjrt` this module is not compiled and `xla::` paths
//! resolve to the real crate instead (see rust/Cargo.toml).

use std::fmt;
use std::rc::Rc;

/// Error type standing in for `xla::Error`.
#[derive(Debug, Clone)]
pub struct XlaError {
    pub message: String,
}

impl XlaError {
    fn new(message: impl Into<String>) -> Self {
        Self { message: message.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

const NO_PJRT: &str = "PJRT execution unavailable: built without the `pjrt` feature (stub runtime)";

/// Element types the stub's literals accept (f32/i32 are all the
/// executor uses).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for f64 {}
impl NativeType for i64 {}

/// Parsed-HLO stand-in. Holds nothing; parsing only validates shape.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text_len: usize,
}

impl HloModuleProto {
    /// Read and sanity-check an HLO text file. Real HLO text always
    /// carries an `HloModule` header and an `ENTRY` computation; missing
    /// either means the artifact is corrupt or truncated.
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError::new(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") || !text.contains("ENTRY") {
            return Err(XlaError::new(format!(
                "Syntax error: {path} is not HLO text (stub parser; wants HloModule + ENTRY)"
            )));
        }
        Ok(Self { text_len: text.len() })
    }
}

/// Computation stand-in.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { _proto_len: proto.text_len }
    }
}

/// Host literal stand-in. Carries no data — execution is impossible in
/// the stub, so the contents are never observable.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(XlaError::new(NO_PJRT))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(XlaError::new(NO_PJRT))
    }
}

/// Device buffer stand-in.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(NO_PJRT))
    }
}

/// Loaded-executable stand-in: compiles fine, refuses to execute.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(NO_PJRT))
    }
}

/// Client stand-in. `Rc` mirrors the real client's !Send internals so
/// threading assumptions stay honest under the stub too.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    platform: Rc<String>,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self { platform: Rc::new("stub-cpu (no PJRT; enable the `pjrt` feature)".into()) })
    }

    pub fn platform_name(&self) -> String {
        self.platform.as_ref().clone()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hlo_validation_accepts_plausible_and_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("xla-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule m\n\nENTRY main { ROOT x = f32[] constant(0) }\n")
            .unwrap();
        assert!(HloModuleProto::from_text_file(good.to_str().unwrap()).is_ok());
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(&bad, "HloModule garbage\nthis is not hlo\n").unwrap();
        assert!(HloModuleProto::from_text_file(bad.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file(dir.join("absent").to_str().unwrap()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn execution_is_a_described_failure() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let exe = client
            .compile(&XlaComputation::from_proto(&HloModuleProto { text_len: 0 }))
            .unwrap();
        let lit = Literal::vec1(&[0.0f32; 4]).reshape(&[2, 2]).unwrap();
        let err = exe.execute::<Literal>(&[lit]).unwrap_err();
        assert!(err.to_string().contains("PJRT"), "{err}");
    }
}
