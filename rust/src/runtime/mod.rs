//! PJRT runtime: loads the AOT artifacts and executes real denoising
//! batches — the request-path compute engine (Python is never here).
//!
//! `make artifacts` emits one HLO-text executable per batch-size bucket
//! (`denoise_bX.hlo.txt`); [`ArtifactStore`] compiles each once at
//! startup, and [`DenoiseExecutor`] runs a heterogeneous batch by
//! padding it up to the nearest bucket.

pub mod executor;
pub mod manifest;

pub use executor::{BatchInput, DenoiseExecutor, StepOutput};
pub use manifest::Manifest;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

#[cfg(not(feature = "pjrt"))]
use crate::xla;

/// Compiled executables per batch-size bucket plus model metadata.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    executables: BTreeMap<u32, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
}

impl ArtifactStore {
    /// Load `manifest.json` from `dir`, compile every bucket's HLO on the
    /// PJRT CPU client. One-time startup cost (measured in
    /// `benches/micro_hotpath.rs`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for (&bucket, file) in &manifest.hlo_files {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling bucket {bucket}"))?;
            executables.insert(bucket, exe);
        }
        Ok(Self { client, executables, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available buckets, ascending.
    pub fn buckets(&self) -> Vec<u32> {
        self.executables.keys().copied().collect()
    }

    /// Smallest bucket that fits `batch` tasks (None if above the top
    /// bucket — the coordinator must split such batches).
    pub fn bucket_for(&self, batch: u32) -> Option<u32> {
        self.executables.range(batch..).next().map(|(&b, _)| b)
    }

    /// Largest supported batch size.
    pub fn max_bucket(&self) -> u32 {
        self.executables.keys().next_back().copied().unwrap_or(0)
    }

    pub(crate) fn executable(&self, bucket: u32) -> Option<&xla::PjRtLoadedExecutable> {
        self.executables.get(&bucket)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_dir;

    fn store() -> Option<ArtifactStore> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(ArtifactStore::load(&dir).expect("artifacts load"))
        } else {
            None // `make artifacts` not run in this checkout
        }
    }

    #[test]
    fn loads_all_buckets() {
        let Some(store) = store() else { return };
        assert!(!store.buckets().is_empty());
        assert_eq!(store.buckets(), store.manifest().buckets);
    }

    #[test]
    fn bucket_for_rounds_up() {
        let Some(store) = store() else { return };
        // buckets include 1,2,4,8,...: 3 → 4, 5 → 8
        assert_eq!(store.bucket_for(1), Some(1));
        assert_eq!(store.bucket_for(3), Some(4));
        assert_eq!(store.bucket_for(5), Some(8));
        assert_eq!(store.bucket_for(store.max_bucket()), Some(store.max_bucket()));
        assert_eq!(store.bucket_for(store.max_bucket() + 1), None);
    }
}
