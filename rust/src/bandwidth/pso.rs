//! Particle swarm optimization over the bandwidth simplex — the paper's
//! solver for problem (P1) [Kennedy & Eberhart, 1995].
//!
//! Standard global-best PSO with inertia, cognitive and social terms;
//! positions are re-projected onto the feasible simplex after every
//! move. The objective (the inner (P2) solve) is expensive, so the
//! swarm is deliberately small and the iteration budget explicit; both
//! are ablated in `benches/ablations.rs`.
//!
//! **Deterministic parallel fitness.** The swarm uses a *synchronous*
//! update discipline: every particle draws its velocity randomness from
//! its **own** PCG stream (`seed`, stream `0x50_50 + p`), positions for
//! iteration *n* are fixed before any of iteration *n*'s objective
//! evaluations run, and personal/global bests are folded in ascending
//! particle order once all evaluations return. Evaluation order
//! therefore cannot influence the trajectory, so fanning the fitness
//! evaluations out across threads (`PsoConfig::threads`, via
//! [`crate::util::exec::par_map`]) is **bit-identical** to the serial
//! loop at any thread count — pinned by `tests/exec_determinism.rs`.
//! (The classic asynchronous variant, which updates the global best
//! mid-sweep, serializes every evaluation behind the previous one and
//! cannot be parallelized without changing its results.)
//!
//! **Zero-alloc hot path.** Position/velocity/best buffers live in a
//! per-allocator scratch reused across `allocate` calls (epochs), so a
//! steady-state solve allocates O(1) amortized — pinned by
//! `tests/hotpath_alloc.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::exec::{par_map, resolve_threads};
use crate::util::Pcg64;

use super::{project_to_simplex, AllocationProblem, Allocator};

/// Quantized-position objective memo (see `PsoConfig::cache_quantum_hz`).
struct ObjectiveCache {
    quantum: f64,
    map: HashMap<Vec<u64>, f64>,
    pub hits: usize,
}

impl ObjectiveCache {
    fn new(quantum: f64) -> Self {
        Self { quantum, map: HashMap::new(), hits: 0 }
    }

    fn disabled(&self) -> bool {
        self.quantum <= 0.0
    }

    fn key(&self, pos: &[f64]) -> Vec<u64> {
        pos.iter().map(|&b| (b / self.quantum).round() as u64).collect()
    }

    fn get(&mut self, key: &[u64]) -> Option<f64> {
        match self.map.get(key) {
            Some(&v) => {
                self.hits += 1;
                Some(v)
            }
            None => None,
        }
    }

    fn insert(&mut self, key: Vec<u64>, v: f64) {
        self.map.insert(key, v);
    }

    fn eval(&mut self, pos: &[f64], objective: &mut dyn FnMut(&[f64]) -> f64) -> f64 {
        if self.disabled() {
            return objective(pos);
        }
        let key = self.key(pos);
        if let Some(v) = self.get(&key) {
            return v;
        }
        let v = objective(pos);
        self.insert(key, v);
        v
    }
}

/// PSO hyper-parameters. `Default` is the classic (ω, c1, c2) =
/// (0.729, 1.494, 1.494) constriction setting.
#[derive(Debug, Clone, Copy)]
pub struct PsoConfig {
    pub particles: usize,
    pub iterations: usize,
    /// Inertia weight ω.
    pub inertia: f64,
    /// Cognitive coefficient c₁ (pull toward each particle's own best).
    pub cognitive: f64,
    /// Social coefficient c₂ (pull toward the global best).
    pub social: f64,
    pub seed: u64,
    /// Stop early after this many iterations without global-best
    /// improvement (0 disables early stopping).
    pub patience: usize,
    /// Memoize objective values on a quantized position grid (Hz). The
    /// inner (P2) solve is step-quantized anyway — allocations closer
    /// than the grid almost always schedule identically — so late-stage
    /// converged swarms stop paying for re-evaluations. 0 disables.
    pub cache_quantum_hz: f64,
    /// Warm-start the swarm from the previous `allocate` call on this
    /// allocator: one particle is seeded with the last global-best
    /// allocation shape (stored as band fractions, re-projected for the
    /// new device count). Off by default — warm starting makes
    /// `allocate` stateful across calls, so replaying a run
    /// bit-identically requires a fresh (or [`PsoAllocator::reset`])
    /// allocator, and sharing one instance across simulations (e.g.
    /// every server of `sim::cluster`) carries swarm state between
    /// them. The equal-split particle 0 is kept either way, so
    /// per-solve dominance over [`super::EqualAllocator`] is unaffected
    /// (exercised under dynamics by `tests/pso_dynamics.rs`).
    pub warm_start: bool,
    /// Fitness-evaluation fan-out: worker threads for the per-iteration
    /// objective evaluations (0 = auto from `available_parallelism`,
    /// 1 = serial). Any value yields bit-identical allocations — the
    /// swarm update is evaluation-order-free by construction — so this
    /// is a pure performance knob. Parallelism engages only through
    /// [`Allocator::allocate_par`] (the objective must be `Sync`); the
    /// `FnMut` entry point always runs serially.
    pub threads: usize,
}

impl Default for PsoConfig {
    fn default() -> Self {
        Self {
            particles: 24,
            iterations: 40,
            inertia: 0.729,
            cognitive: 1.494,
            social: 1.494,
            seed: 0x9e3779b9,
            patience: 12,
            cache_quantum_hz: 0.0, // measured: <1% hit rate on converging swarms — off
            warm_start: false,
            threads: 1,
        }
    }
}

/// The PSO bandwidth allocator.
#[derive(Debug)]
pub struct PsoAllocator {
    pub config: PsoConfig,
    /// Last global-best allocation as fractions of the total band
    /// (`warm_start` only).
    warm: Mutex<Option<Vec<f64>>>,
    /// How many `allocate` calls actually seeded a warm particle.
    warm_uses: AtomicUsize,
    /// Reusable swarm buffers (positions/velocities/bests/streams),
    /// carried across `allocate` calls so steady-state epoch solves
    /// stop allocating. Pure cache: contents are fully re-initialized
    /// per solve, so reuse never changes a result. `None` while a
    /// solve on another thread has the buffers checked out (that solve
    /// builds fresh ones).
    scratch: Mutex<Option<Swarm>>,
}

impl Default for PsoAllocator {
    fn default() -> Self {
        Self::new(PsoConfig::default())
    }
}

impl Clone for PsoAllocator {
    fn clone(&self) -> Self {
        Self {
            config: self.config,
            warm: Mutex::new(self.warm.lock().unwrap().clone()),
            warm_uses: AtomicUsize::new(self.warm_uses.load(Ordering::Relaxed)),
            scratch: Mutex::new(None),
        }
    }
}

impl PsoAllocator {
    pub fn new(config: PsoConfig) -> Self {
        Self {
            config,
            warm: Mutex::new(None),
            warm_uses: AtomicUsize::new(0),
            scratch: Mutex::new(None),
        }
    }

    /// Number of solves that seeded a particle from the previous epoch.
    pub fn warm_starts(&self) -> usize {
        self.warm_uses.load(Ordering::Relaxed)
    }

    /// Forget the carried swarm state (start the next `allocate` cold).
    pub fn reset(&self) {
        *self.warm.lock().unwrap() = None;
        self.warm_uses.store(0, Ordering::Relaxed);
    }

    /// Adapt stored band fractions to a (possibly different) device
    /// count: truncate or pad with the mean fraction, renormalize, and
    /// scale to the new total. Device identities do not persist across
    /// epochs — the carried signal is the *shape* of the allocation
    /// (how uneven the band split was), which is what the next swarm
    /// iteration refines.
    fn warm_position(fractions: &[f64], k: usize, total: f64) -> Vec<f64> {
        debug_assert!(!fractions.is_empty());
        let mean = fractions.iter().sum::<f64>() / fractions.len() as f64;
        let mut pos: Vec<f64> = (0..k).map(|i| fractions.get(i).copied().unwrap_or(mean)).collect();
        let sum: f64 = pos.iter().sum();
        if sum > 0.0 {
            for v in pos.iter_mut() {
                *v *= total / sum;
            }
        }
        pos
    }
}

#[derive(Debug)]
struct Particle {
    pos: Vec<f64>,
    vel: Vec<f64>,
    best_pos: Vec<f64>,
    best_val: f64,
}

/// Reusable per-solve swarm state (see `PsoAllocator::scratch`).
#[derive(Debug, Default)]
struct Swarm {
    particles: Vec<Particle>,
    /// Objective value per particle for the current round.
    vals: Vec<f64>,
    /// One independent PCG stream per particle.
    rngs: Vec<Pcg64>,
    global_best_pos: Vec<f64>,
}

impl Swarm {
    /// Size the buffers for `n` particles over `k` dimensions. Every
    /// slot is overwritten by `init_positions`, so stale contents from
    /// a previous solve can never leak.
    fn reset(&mut self, n: usize, k: usize, seed: u64) {
        self.particles.truncate(n);
        while self.particles.len() < n {
            self.particles.push(Particle {
                pos: Vec::new(),
                vel: Vec::new(),
                best_pos: Vec::new(),
                best_val: f64::INFINITY,
            });
        }
        for p in self.particles.iter_mut() {
            p.best_val = f64::INFINITY;
        }
        self.rngs.clear();
        self.rngs.extend((0..n).map(|p| Pcg64::new(seed, 0x50_50 + p as u64)));
        self.vals.clear();
        self.global_best_pos.clear();
        self.global_best_pos.resize(k, 0.0);
    }
}

/// How the swarm evaluates a round of candidate positions. Both paths
/// produce bitwise-identical value vectors: the serial path maps in
/// particle order, the parallel path replays the serial cache
/// semantics (first occurrence of a quantized key evaluates; later
/// ones reuse it) and fans only the fresh evaluations out.
enum Objective<'a> {
    Serial(&'a mut dyn FnMut(&[f64]) -> f64),
    Parallel { f: &'a (dyn Fn(&[f64]) -> f64 + Sync), threads: usize },
}

impl Objective<'_> {
    fn eval_all(
        &mut self,
        cache: &mut ObjectiveCache,
        particles: &[Particle],
        vals: &mut Vec<f64>,
    ) {
        vals.clear();
        match self {
            Objective::Serial(f) => {
                for part in particles {
                    let v = cache.eval(&part.pos, &mut **f);
                    vals.push(v);
                }
            }
            Objective::Parallel { f, threads } => {
                let f: &(dyn Fn(&[f64]) -> f64 + Sync) = *f;
                let threads = *threads;
                if cache.disabled() {
                    vals.extend(par_map(threads, particles, |_, part| f(&part.pos)));
                    return;
                }
                enum Plan {
                    Cached(f64),
                    Fresh(usize),
                }
                let mut plan: Vec<Plan> = Vec::with_capacity(particles.len());
                let mut fresh: Vec<usize> = Vec::new();
                let mut keys: Vec<Vec<u64>> = Vec::new();
                for (i, part) in particles.iter().enumerate() {
                    let key = cache.key(&part.pos);
                    if let Some(v) = cache.get(&key) {
                        plan.push(Plan::Cached(v));
                    } else if let Some(j) = keys.iter().position(|k| *k == key) {
                        // Same key seen earlier this round: the serial
                        // loop would hit the entry that evaluation
                        // inserted.
                        cache.hits += 1;
                        plan.push(Plan::Fresh(j));
                    } else {
                        plan.push(Plan::Fresh(fresh.len()));
                        fresh.push(i);
                        keys.push(key);
                    }
                }
                let results = par_map(threads, &fresh, |_, &pi| f(&particles[pi].pos));
                for (key, &v) in keys.into_iter().zip(&results) {
                    cache.insert(key, v);
                }
                for p in plan {
                    vals.push(match p {
                        Plan::Cached(v) => v,
                        Plan::Fresh(j) => results[j],
                    });
                }
            }
        }
    }
}

impl PsoAllocator {
    /// The synchronous-update PSO core shared by both `Allocator` entry
    /// points (see the module docs for why it is evaluation-order-free).
    fn solve(&self, problem: &AllocationProblem, objective: &mut Objective) -> Vec<f64> {
        let cfg = self.config;
        let k = problem.k();
        let total = problem.total_hz;
        let min_hz = problem.min_hz;
        let n = cfg.particles.max(1);
        let mut cache = ObjectiveCache::new(cfg.cache_quantum_hz);

        // Warm start (off by default): particle 1 resumes from the last
        // solve's global best, adapted to this problem's device count.
        let warm_pos: Option<Vec<f64>> = if cfg.warm_start && cfg.particles >= 2 {
            let stored = self.warm.lock().unwrap();
            stored.as_ref().map(|fractions| Self::warm_position(fractions, k, total))
        } else {
            None
        };
        if warm_pos.is_some() {
            self.warm_uses.fetch_add(1, Ordering::Relaxed);
        }

        let mut swarm = self.scratch.lock().unwrap().take().unwrap_or_default();
        swarm.reset(n, k, cfg.seed);
        let Swarm { particles, vals, rngs, global_best_pos } = &mut swarm;

        // ---- initialize swarm ----
        // Particle 0 starts at the equal split (a strong prior: it is
        // the paper's baseline), particle 1 at the warm position when
        // carried, the rest at random simplex points from their own
        // streams.
        for (p, part) in particles.iter_mut().enumerate() {
            part.pos.clear();
            if p == 0 {
                part.pos.resize(k, total / k as f64);
            } else if p == 1 && warm_pos.is_some() {
                part.pos.extend_from_slice(warm_pos.as_deref().unwrap());
            } else {
                // exponential draws normalized → uniform on the simplex
                let rng = &mut rngs[p];
                for _ in 0..k {
                    part.pos.push(rng.exponential(1.0));
                }
                let sum: f64 = part.pos.iter().sum();
                for v in part.pos.iter_mut() {
                    *v = *v / sum * total;
                }
            }
            project_to_simplex(&mut part.pos, total, min_hz);
            part.vel.clear();
            part.vel.resize(k, 0.0);
        }
        for v in global_best_pos.iter_mut() {
            *v = total / k as f64;
        }
        let mut global_best_val = f64::INFINITY;
        objective.eval_all(&mut cache, particles, vals);
        for (part, &val) in particles.iter_mut().zip(vals.iter()) {
            part.best_pos.clone_from(&part.pos);
            part.best_val = val;
            if val < global_best_val {
                global_best_val = val;
                global_best_pos.clone_from(&part.pos);
            }
        }

        // ---- iterate ----
        let vel_cap = 0.25 * total; // per-dimension velocity clamp
        let mut stall = 0usize;
        for _ in 0..cfg.iterations {
            for (p, part) in particles.iter_mut().enumerate() {
                let rng = &mut rngs[p];
                for d in 0..k {
                    let r1 = rng.uniform();
                    let r2 = rng.uniform();
                    let v = cfg.inertia * part.vel[d]
                        + cfg.cognitive * r1 * (part.best_pos[d] - part.pos[d])
                        + cfg.social * r2 * (global_best_pos[d] - part.pos[d]);
                    part.vel[d] = v.clamp(-vel_cap, vel_cap);
                    part.pos[d] += part.vel[d];
                }
                project_to_simplex(&mut part.pos, total, min_hz);
            }
            objective.eval_all(&mut cache, particles, vals);
            let mut improved = false;
            for (part, &val) in particles.iter_mut().zip(vals.iter()) {
                if val < part.best_val {
                    part.best_val = val;
                    part.best_pos.clone_from(&part.pos);
                }
                if val < global_best_val {
                    global_best_val = val;
                    global_best_pos.clone_from(&part.pos);
                    improved = true;
                }
            }
            if improved {
                stall = 0;
            } else {
                stall += 1;
                if cfg.patience > 0 && stall >= cfg.patience {
                    break;
                }
            }
        }
        if cfg.warm_start {
            let fractions: Vec<f64> = global_best_pos.iter().map(|&b| b / total).collect();
            *self.warm.lock().unwrap() = Some(fractions);
        }
        let best = global_best_pos.clone();
        *self.scratch.lock().unwrap() = Some(swarm);
        best
    }
}

impl Allocator for PsoAllocator {
    fn name(&self) -> &'static str {
        "pso"
    }

    fn allocate(
        &self,
        problem: &AllocationProblem,
        objective: &mut dyn FnMut(&[f64]) -> f64,
    ) -> Vec<f64> {
        self.solve(problem, &mut Objective::Serial(objective))
    }

    fn allocate_par(
        &self,
        problem: &AllocationProblem,
        objective: &(dyn Fn(&[f64]) -> f64 + Sync),
    ) -> Vec<f64> {
        if resolve_threads(self.config.threads) <= 1 {
            return self.solve(problem, &mut Objective::Serial(&mut |b| objective(b)));
        }
        let threads = self.config.threads;
        self.solve(problem, &mut Objective::Parallel { f: objective, threads })
    }

    fn parallel_replay_safe(&self) -> bool {
        !self.config.warm_start
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Link;
    use crate::util::approx_eq;

    fn problem(k: usize) -> AllocationProblem {
        AllocationProblem::new(
            40_000.0,
            (0..k).map(|i| Link::new(5.0 + (i as f64) * 0.5)).collect(),
        )
    }

    #[test]
    fn stays_feasible() {
        let p = problem(8);
        let mut evals = 0usize;
        let alloc = PsoAllocator::default().allocate(&p, &mut |b| {
            evals += 1;
            b.iter().map(|x| x * x).sum::<f64>() // convex dummy
        });
        assert!(approx_eq(alloc.iter().sum::<f64>(), 40_000.0, 1e-6));
        assert!(alloc.iter().all(|&b| b >= p.min_hz - 1e-9));
        assert!(evals > 0);
    }

    #[test]
    fn minimizes_convex_quadratic_near_equal_split() {
        // min Σ B_k² on the simplex → equal split.
        let p = problem(5);
        let alloc =
            PsoAllocator::default().allocate(&p, &mut |b| b.iter().map(|x| x * x).sum::<f64>());
        for &b in &alloc {
            assert!(approx_eq(b, 8_000.0, 0.02 * 8_000.0), "alloc={alloc:?}");
        }
    }

    #[test]
    fn finds_skewed_optimum() {
        // Objective rewards giving everything to device 0:
        // f(B) = -B_0. Optimum: B_0 = total − (k−1)·min.
        let p = problem(4);
        let alloc = PsoAllocator::default().allocate(&p, &mut |b| -b[0]);
        let expect = 40_000.0 - 3.0 * p.min_hz;
        assert!(alloc[0] > 0.95 * expect, "alloc={alloc:?}");
    }

    #[test]
    fn beats_equal_split_on_asymmetric_objective() {
        use crate::bandwidth::EqualAllocator;
        // Weighted delay objective: Σ w_k / B_k with very uneven weights —
        // the shape (P1) takes when one deadline is tight.
        let w = [100.0, 1.0, 1.0, 1.0];
        let mut obj = move |b: &[f64]| -> f64 { b.iter().zip(&w).map(|(x, wk)| wk / x).sum() };
        let p = problem(4);
        let pso_alloc = PsoAllocator::default().allocate(&p, &mut obj);
        let eq_alloc = EqualAllocator.allocate(&p, &mut obj);
        assert!(obj(&pso_alloc) < obj(&eq_alloc), "{:?}", pso_alloc);
        // analytic optimum: B_k ∝ √w_k → B_0/B_1 = 10
        assert!(pso_alloc[0] / pso_alloc[1] > 4.0, "{:?}", pso_alloc);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = problem(6);
        let mut obj = |b: &[f64]| b.iter().map(|x| (x - 1000.0).abs()).sum::<f64>();
        let a = PsoAllocator::default().allocate(&p, &mut obj);
        let b = PsoAllocator::default().allocate(&p, &mut obj);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_fitness_is_bit_identical_to_serial() {
        let p = problem(7);
        let obj = |b: &[f64]| -> f64 { b.iter().map(|x| (x - 2_000.0).abs().sqrt()).sum() };
        let serial = PsoAllocator::default().allocate(&p, &mut |b| obj(b));
        for threads in [0, 2, 8] {
            let cfg = PsoConfig { threads, ..Default::default() };
            let par = PsoAllocator::new(cfg).allocate_par(&p, &obj);
            let a: Vec<u64> = serial.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "threads={threads}");
        }
        // the FnMut entry point matches allocate_par at threads=1 too
        let one = PsoAllocator::new(PsoConfig { threads: 1, ..Default::default() })
            .allocate_par(&p, &obj);
        assert_eq!(serial, one);
    }

    #[test]
    fn parallel_fitness_with_cache_matches_serial_cache_semantics() {
        // A coarse quantum forces key collisions, exercising the
        // dedupe-then-fan-out replay of the serial memo.
        let p = problem(5);
        let cfg = PsoConfig { cache_quantum_hz: 500.0, ..Default::default() };
        let obj = |b: &[f64]| -> f64 { b.iter().map(|x| x * x).sum() };
        let serial = PsoAllocator::new(cfg).allocate(&p, &mut |b| obj(b));
        let par = PsoAllocator::new(PsoConfig { threads: 4, ..cfg }).allocate_par(&p, &obj);
        let a: Vec<u64> = serial.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = par.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn warm_start_off_keeps_allocate_stateless() {
        let p = problem(5);
        let alloc = PsoAllocator::default();
        let mut obj = |b: &[f64]| b.iter().map(|x| x * x).sum::<f64>();
        let a = alloc.allocate(&p, &mut obj);
        let b = alloc.allocate(&p, &mut obj);
        assert_eq!(a, b, "without warm_start repeated solves must be identical");
        assert_eq!(alloc.warm_starts(), 0);
    }

    #[test]
    fn scratch_reuse_never_leaks_across_problem_shapes() {
        // Same instance solving K=6 then K=3 then K=6 again: buffer
        // reuse across different dimensionalities must not perturb the
        // result (the K=6 answers must match a fresh allocator's).
        let alloc = PsoAllocator::default();
        let mut obj = |b: &[f64]| b.iter().map(|x| (x - 4_000.0).abs()).sum::<f64>();
        let first = alloc.allocate(&problem(6), &mut obj);
        alloc.allocate(&problem(3), &mut obj);
        let again = alloc.allocate(&problem(6), &mut obj);
        assert_eq!(first, again);
        let fresh = PsoAllocator::default().allocate(&problem(6), &mut obj);
        assert_eq!(first, fresh);
    }

    #[test]
    fn warm_start_carries_state_across_solves() {
        let cfg = PsoConfig { warm_start: true, ..Default::default() };
        let alloc = PsoAllocator::new(cfg);
        let p = problem(4);
        let mut obj = |b: &[f64]| -b[0];
        alloc.allocate(&p, &mut obj);
        assert_eq!(alloc.warm_starts(), 0, "first solve has nothing to resume");
        let warmed = alloc.allocate(&p, &mut obj);
        assert_eq!(alloc.warm_starts(), 1);
        // warm particle must stay feasible
        assert!(approx_eq(warmed.iter().sum::<f64>(), 40_000.0, 1e-6));
        alloc.reset();
        assert_eq!(alloc.warm_starts(), 0);
        alloc.allocate(&p, &mut obj);
        assert_eq!(alloc.warm_starts(), 0, "reset forgets the carried swarm");
    }

    #[test]
    fn warm_start_adapts_to_changed_device_count() {
        let cfg = PsoConfig { warm_start: true, ..Default::default() };
        let alloc = PsoAllocator::new(cfg);
        let mut obj = |b: &[f64]| b.iter().map(|x| (x - 9_000.0).abs()).sum::<f64>();
        alloc.allocate(&problem(3), &mut obj);
        for k in [6, 2] {
            let p = problem(k);
            let a = alloc.allocate(&p, &mut obj);
            assert_eq!(a.len(), k);
            assert!(approx_eq(a.iter().sum::<f64>(), 40_000.0, 1e-6));
            assert!(a.iter().all(|&b| b >= p.min_hz - 1e-9));
        }
        assert_eq!(alloc.warm_starts(), 2);
    }

    #[test]
    fn warm_fractions_pad_and_truncate() {
        let pos = PsoAllocator::warm_position(&[0.5, 0.25, 0.25], 2, 100.0);
        assert_eq!(pos.len(), 2);
        assert!(approx_eq(pos.iter().sum::<f64>(), 100.0, 1e-9));
        assert!(pos[0] > pos[1], "relative shape preserved under truncation");
        let pos = PsoAllocator::warm_position(&[0.6, 0.4], 4, 100.0);
        assert_eq!(pos.len(), 4);
        assert!(approx_eq(pos.iter().sum::<f64>(), 100.0, 1e-9));
        assert!(approx_eq(pos[2], pos[3], 1e-9), "padding uses the mean fraction");
    }

    #[test]
    fn early_stop_costs_fewer_evals() {
        let p = problem(4);
        let count_evals = |patience: usize| {
            let mut evals = 0usize;
            let cfg = PsoConfig { patience, iterations: 200, ..Default::default() };
            PsoAllocator::new(cfg).allocate(&p, &mut |_| {
                evals += 1;
                1.0 // flat objective: never improves
            });
            evals
        };
        assert!(count_evals(3) < count_evals(0));
    }
}
