//! Bandwidth allocation — problem (P1) of the paper.
//!
//! Given per-device links and the batch-denoising inner solver, choose
//! `B_k` with `Σ B_k ≤ B`, `B_k > 0` (Eqs. 9–10) to minimize the inner
//! objective `Q*(B_1..B_K)`. The paper uses PSO; [`PsoAllocator`] is a
//! full particle-swarm implementation whose particles live on the
//! simplex `{B : Σ B_k = B, B_k ≥ B_min}` (allocating less than the full
//! band is never optimal — transmission delay is strictly decreasing in
//! bandwidth).
//!
//! Baselines: [`EqualAllocator`] (the paper's comparison scheme) and
//! [`ProportionalAllocator`] (inverse-spectral-efficiency weighting — a
//! natural heuristic included for ablations).

pub mod pso;

pub use pso::{PsoAllocator, PsoConfig};

use crate::channel::Link;

/// An allocation problem instance: total band `total_hz` split across
/// `links.len()` devices.
#[derive(Debug, Clone)]
pub struct AllocationProblem {
    pub total_hz: f64,
    pub links: Vec<Link>,
    /// Smallest allocation a device may receive (keeps (10) strict).
    pub min_hz: f64,
}

impl AllocationProblem {
    pub fn new(total_hz: f64, links: Vec<Link>) -> Self {
        assert!(total_hz > 0.0 && !links.is_empty());
        // 0.1% of an equal share keeps every B_k strictly positive while
        // letting PSO starve hopeless links almost completely.
        let min_hz = 1e-3 * total_hz / links.len() as f64;
        Self { total_hz, links, min_hz }
    }

    pub fn k(&self) -> usize {
        self.links.len()
    }
}

/// A bandwidth allocator proposes `B_k` for the problem; the objective
/// (mean quality after the inner batch-denoising solve) is evaluated by
/// the caller-provided closure so allocators stay decoupled from the
/// scheduler.
///
/// `Send + Sync` is a supertrait: the engines fan independent solves
/// out across threads (`util::exec`), so allocator instances must be
/// shareable. Every implementation in-tree is plain data or guards its
/// state behind a `Mutex` (PSO warm start).
pub trait Allocator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Produce an allocation (Hz per device). Implementations must
    /// return a vector satisfying Σ B_k ≤ total and B_k ≥ min_hz.
    fn allocate(
        &self,
        problem: &AllocationProblem,
        objective: &mut dyn FnMut(&[f64]) -> f64,
    ) -> Vec<f64>;

    /// Parallel-capable entry point: the objective is a pure `Fn`, so
    /// implementations may evaluate candidate allocations concurrently
    /// (PSO fans its particle fitness out through `util::exec`; the
    /// result is bit-identical to the serial path at any thread
    /// count). The default falls back to [`Self::allocate`].
    fn allocate_par(
        &self,
        problem: &AllocationProblem,
        objective: &(dyn Fn(&[f64]) -> f64 + Sync),
    ) -> Vec<f64> {
        self.allocate(problem, &mut |b| objective(b))
    }

    /// True when concurrent solves on *one instance* cannot observe
    /// each other — i.e. `allocate` reads no carried state. The
    /// engines only fan per-server solves out in parallel when every
    /// involved allocator is replay-safe or the instances are pairwise
    /// distinct; otherwise they fall back to the serial solve order so
    /// stateful sharing (legacy shared warm-start PSO) replays exactly.
    fn parallel_replay_safe(&self) -> bool {
        true
    }
}

/// True when every allocator reference points at a distinct instance —
/// per-server solves touching distinct (even stateful) instances can
/// run concurrently without changing any per-server solve sequence.
pub fn distinct_instances(allocators: &[&dyn Allocator]) -> bool {
    let mut ptrs: Vec<*const ()> =
        allocators.iter().map(|a| *a as *const dyn Allocator as *const ()).collect();
    ptrs.sort();
    ptrs.dedup();
    ptrs.len() == allocators.len()
}

/// Per-server allocator instances for the cluster engines.
///
/// `simulate_cluster` and `sim::event` historically threaded one
/// allocator through every server's serving loop, which made a
/// *stateful* allocator (PSO `warm_start`) share swarm state across
/// the fleet — and made the two engines diverge bitwise under warm
/// starts, because they order solves differently (per-server vs
/// shared-clock). A pool gives each server its own instance, so PSO
/// warm-start state is per server: each server's solve sequence is
/// identical in both engines and replay from fresh pools is
/// bit-identical (`tests/pipeline_properties.rs`).
///
/// A pool of one ([`AllocatorPool::shared`]) reproduces the legacy
/// shared-instance behaviour exactly.
pub struct AllocatorPool {
    allocators: Vec<Box<dyn Allocator>>,
}

impl AllocatorPool {
    /// One allocator per server, built by `factory(server_id)`.
    pub fn per_server(servers: usize, factory: impl Fn(usize) -> Box<dyn Allocator>) -> Self {
        assert!(servers >= 1, "pool needs at least one allocator");
        Self { allocators: (0..servers).map(factory).collect() }
    }

    /// A single instance every server shares (the legacy semantics —
    /// only observable with stateful allocators).
    pub fn shared(allocator: Box<dyn Allocator>) -> Self {
        Self { allocators: vec![allocator] }
    }

    pub fn len(&self) -> usize {
        self.allocators.len()
    }

    pub fn is_empty(&self) -> bool {
        self.allocators.is_empty()
    }

    /// The allocator serving `server`. A shared pool (size 1) returns
    /// its one instance for every server; a per-server pool indexes
    /// exactly — out-of-range panics rather than silently aliasing
    /// warm-start state across servers.
    pub fn get(&self, server: usize) -> &dyn Allocator {
        if self.allocators.len() == 1 {
            return &*self.allocators[0];
        }
        &*self.allocators[server]
    }

    /// Per-server references for an `n`-server fleet — the shape the
    /// simulation engines consume. The pool must be shared (size 1) or
    /// sized exactly to the fleet.
    pub fn refs(&self, n: usize) -> Vec<&dyn Allocator> {
        assert!(
            self.allocators.len() == 1 || self.allocators.len() == n,
            "pool has {} allocators for {} servers (need 1 shared or exactly one per server)",
            self.allocators.len(),
            n
        );
        (0..n).map(|s| self.get(s)).collect()
    }
}

/// Equal split — the paper's "equal bandwidth allocation" baseline.
#[derive(Debug, Clone, Copy, Default)]
pub struct EqualAllocator;

impl Allocator for EqualAllocator {
    fn name(&self) -> &'static str {
        "equal"
    }

    fn allocate(
        &self,
        problem: &AllocationProblem,
        _objective: &mut dyn FnMut(&[f64]) -> f64,
    ) -> Vec<f64> {
        vec![problem.total_hz / problem.k() as f64; problem.k()]
    }
}

/// Weight each device by 1/η_k so all devices see (roughly) equal
/// transmission delay for equal content size.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProportionalAllocator;

impl Allocator for ProportionalAllocator {
    fn name(&self) -> &'static str {
        "proportional-inverse-eta"
    }

    fn allocate(
        &self,
        problem: &AllocationProblem,
        _objective: &mut dyn FnMut(&[f64]) -> f64,
    ) -> Vec<f64> {
        let weights: Vec<f64> = problem.links.iter().map(|l| 1.0 / l.spectral_efficiency).collect();
        let total_w: f64 = weights.iter().sum();
        weights.iter().map(|w| problem.total_hz * w / total_w).collect()
    }
}

/// Project an arbitrary non-negative vector onto the simplex
/// `{B : Σ B_k = total, B_k ≥ min_hz}` by clamping and rescaling the
/// free mass. Used by PSO after every position update.
pub fn project_to_simplex(b: &mut [f64], total: f64, min_hz: f64) {
    let k = b.len() as f64;
    debug_assert!(total > min_hz * k, "infeasible simplex");
    let free_total = total - min_hz * k;
    // shift to the "excess over minimum" coordinates, clamp at 0
    let mut sum = 0.0;
    for v in b.iter_mut() {
        *v = (*v - min_hz).max(0.0);
        sum += *v;
    }
    if sum <= 0.0 {
        // degenerate: spread evenly
        for v in b.iter_mut() {
            *v = min_hz + free_total / k;
        }
        return;
    }
    let scale = free_total / sum;
    for v in b.iter_mut() {
        *v = min_hz + *v * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    fn problem(etas: &[f64]) -> AllocationProblem {
        AllocationProblem::new(40_000.0, etas.iter().map(|&e| Link::new(e)).collect())
    }

    #[test]
    fn equal_split_sums_to_total() {
        let p = problem(&[5.0, 7.0, 9.0, 10.0]);
        let alloc = EqualAllocator.allocate(&p, &mut |_| 0.0);
        assert!(approx_eq(alloc.iter().sum::<f64>(), 40_000.0, 1e-9));
        assert!(alloc.iter().all(|&b| approx_eq(b, 10_000.0, 1e-9)));
    }

    #[test]
    fn proportional_favors_weak_links() {
        let p = problem(&[5.0, 10.0]);
        let alloc = ProportionalAllocator.allocate(&p, &mut |_| 0.0);
        assert!(alloc[0] > alloc[1]);
        // exact 2:1 split
        assert!(approx_eq(alloc[0] / alloc[1], 2.0, 1e-9));
        assert!(approx_eq(alloc.iter().sum::<f64>(), 40_000.0, 1e-9));
        // equal tx delay: B_k * eta_k equal
        assert!(approx_eq(alloc[0] * 5.0, alloc[1] * 10.0, 1e-6));
    }

    #[test]
    fn pool_per_server_hands_out_distinct_instances() {
        let pool = AllocatorPool::per_server(3, |_| Box::new(PsoAllocator::default()));
        assert_eq!(pool.len(), 3);
        let a = pool.get(0) as *const dyn Allocator as *const ();
        let b = pool.get(1) as *const dyn Allocator as *const ();
        assert!(a != b, "per-server pools must not alias instances");
        assert_eq!(pool.refs(3).len(), 3);
    }

    #[test]
    fn pool_shared_aliases_one_instance_for_every_server() {
        let pool = AllocatorPool::shared(Box::new(EqualAllocator));
        assert_eq!(pool.len(), 1);
        let a = pool.get(0) as *const dyn Allocator as *const ();
        let b = pool.get(7) as *const dyn Allocator as *const ();
        assert!(a == b, "a shared pool serves the same instance to everyone");
        assert_eq!(pool.refs(4).len(), 4);
    }

    #[test]
    #[should_panic(expected = "pool has 2 allocators for 4 servers")]
    fn undersized_per_server_pool_is_rejected_not_aliased() {
        let pool = AllocatorPool::per_server(2, |_| Box::new(EqualAllocator));
        pool.refs(4);
    }

    #[test]
    fn pooled_warm_start_state_is_isolated_per_server() {
        let pool = AllocatorPool::per_server(2, |_| {
            Box::new(PsoAllocator::new(PsoConfig { warm_start: true, ..Default::default() }))
        });
        let p = problem(&[5.0, 7.0, 9.0]);
        let mut obj = |b: &[f64]| b.iter().map(|x| x * x).sum::<f64>();
        // two solves on server 0, none on server 1: only server 0's
        // instance may have carried swarm state
        pool.get(0).allocate(&p, &mut obj);
        pool.get(0).allocate(&p, &mut obj);
        let first_on_1 = pool.get(1).allocate(&p, &mut obj);
        let cold = PsoAllocator::new(PsoConfig { warm_start: true, ..Default::default() })
            .allocate(&p, &mut obj);
        assert_eq!(first_on_1, cold, "server 1's allocator must still be cold");
    }

    #[test]
    fn replay_safety_and_instance_distinctness() {
        // Stateless allocators are always safe to solve concurrently.
        assert!(EqualAllocator.parallel_replay_safe());
        assert!(ProportionalAllocator.parallel_replay_safe());
        assert!(PsoAllocator::default().parallel_replay_safe());
        // Warm-start PSO carries swarm state across solves on one
        // instance — concurrent solves on it would be order-dependent.
        let warm = PsoAllocator::new(PsoConfig { warm_start: true, ..Default::default() });
        assert!(!warm.parallel_replay_safe());
        // Distinct instances are fine even when stateful.
        let pool = AllocatorPool::per_server(3, |_| {
            Box::new(PsoAllocator::new(PsoConfig { warm_start: true, ..Default::default() }))
        });
        assert!(distinct_instances(&pool.refs(3)));
        let shared = AllocatorPool::shared(Box::new(EqualAllocator));
        assert!(!distinct_instances(&shared.refs(3)));
        assert!(distinct_instances(&shared.refs(1)));
    }

    #[test]
    fn projection_preserves_total_and_min() {
        let mut b = vec![100.0, 0.0, 5000.0, -50.0];
        project_to_simplex(&mut b, 40_000.0, 10.0);
        assert!(approx_eq(b.iter().sum::<f64>(), 40_000.0, 1e-6));
        assert!(b.iter().all(|&v| v >= 10.0 - 1e-12));
        // ordering of positive mass is preserved
        assert!(b[2] > b[0]);
    }

    #[test]
    fn projection_degenerate_all_below_min() {
        let mut b = vec![0.0, 0.0, 0.0];
        project_to_simplex(&mut b, 300.0, 1.0);
        assert!(approx_eq(b.iter().sum::<f64>(), 300.0, 1e-9));
        assert!(b.iter().all(|&v| approx_eq(v, 100.0, 1e-9)));
    }

    #[test]
    fn projection_is_idempotent() {
        let mut b = vec![15_000.0, 5_000.0, 20_000.0];
        project_to_simplex(&mut b, 40_000.0, 10.0);
        let snapshot = b.clone();
        project_to_simplex(&mut b, 40_000.0, 10.0);
        for (x, y) in b.iter().zip(&snapshot) {
            assert!(approx_eq(*x, *y, 1e-9));
        }
    }
}
