//! Hand-rolled CLI (no `clap` in the vendored crate set).
//!
//! Subcommands:
//!   `serve    [--addr A] [--config F] [--epoch-ms N]` — TCP serving
//!   `simulate [--config F] [--scheduler S] [--allocator A] [--seed N]`
//!   `dynamic  [--config F] [--rate L] [--horizon S] [...]` — dynamic
//!             arrivals through the event-driven multi-epoch simulator
//!   `cluster  [--servers N] [--router R] [...]` — the dynamic workload
//!             sharded across N servers behind a routing policy
//!   `faults   [--fault-mode M] [--migration P] [--transfer-s T] [...]`
//!             — the cluster workload under failure injection and live
//!             migration (checkpointed resumes under `--migration
//!             checkpoint`)
//!   `trace    --in spans.bin [--perfetto out.json]` — summarize,
//!             audit and export a flight-recorder span capture
//!             (written by `--trace-spans` on the simulators)
//!   `profile  [--reps N]` — Fig. 1a measurement
//!   `figures  [--which 1a|1b|2a|2b|2c|3|cluster|faults|pipeline|checkpoint|cache|all] [--reps N]`
//!   `perf     [--threads N] [--quick true]` — parallel-fabric perf
//!             harness (serial vs auto threads, emits BENCH_pr5.json)
//!
//! Every subcommand that solves or sweeps accepts `--threads N`
//! (0 = auto-detect, 1 = serial): the parallel fabric is
//! bit-identical to serial at any thread count, so the flag only
//! changes wall-clock, never output.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Debug, Clone, PartialEq)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut iter = args.into_iter();
        let command = iter.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        while let Some(arg) = iter.next() {
            let key = arg
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{arg}'"))?;
            if key.is_empty() {
                bail!("empty flag name");
            }
            // `--flag=value` or `--flag value`
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else {
                let value = iter.next().with_context(|| format!("--{key} needs a value"))?;
                flags.insert(key.to_string(), value);
            }
        }
        Ok(Self { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
        }
    }

    /// Error on flags not in the allowed set (typo guard).
    pub fn expect_only(&self, allowed: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} for '{}' (allowed: {})",
                    self.command,
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
                );
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
aigc-edge — batch denoising for AIGC serving at the wireless edge

USAGE:
  aigc-edge serve    [--addr 127.0.0.1:7878] [--config file.toml] [--epoch-ms 200]
  aigc-edge simulate [--config file.toml] [--scheduler stacking|single|greedy|fixed]
                     [--allocator pso|equal|proportional] [--seed N] [--threads 0]
  aigc-edge dynamic  [--config file.toml] [--process poisson|burst] [--rate 2.0]
                     [--horizon 300] [--epoch-s 1.0] [--max-batch 32] [--window 30]
                     [--plan-horizon 2.0] [--solve-latency 0.0]
                     [--solve-mode pipelined|synchronous]
                     [--no-admission true] [--trace-out f.csv] [--trace-spans f.bin]
                     [--metrics-mode exact|streaming]
                     [--scheduler stacking|single|greedy|fixed]
                     [--allocator pso|equal|proportional] [--seed N] [--threads 0]
  aigc-edge cluster  [--config file.toml] [--servers 4]
                     [--router round-robin|jsq|quality|live|cache]
                     [--speed-min 1.0] [--speed-max 1.0] [--process poisson|burst]
                     [--rate 2.0] [--horizon 300] [--epoch-s 1.0] [--max-batch 32]
                     [--plan-horizon 2.0] [--adaptive-horizon true]
                     [--solve-latency 0.0] [--solve-mode pipelined|synchronous]
                     [--no-admission true] [--warm-start true] [--trace-spans f.bin]
                     [--scheduler stacking|single|greedy|fixed]
                     [--allocator pso|equal|proportional] [--seed N] [--threads 0]
  aigc-edge faults   [--config file.toml] [cluster flags...]
                     [--fault-mode none|random|scheduled] [--mtbf 120] [--mttr 15]
                     [--fault-seed N] [--down \"server:from:until,...\"]
                     [--migration none|requeue|steal|checkpoint] [--transfer-s 0.05]
                     [--trace-spans f.bin]
  aigc-edge trace    --in spans.bin [--perfetto out.json] [--window 30]
  aigc-edge profile  [--reps 20]
  aigc-edge figures  [--which all|1a|1b|2a|2b|2c|3|cluster|faults|pipeline|checkpoint|cache]
                     [--reps 3]
                     [--threads 0]
  aigc-edge perf     [--config file.toml] [--threads 0] [--quick true]
                     [--out BENCH_pr5.json] [--seed N]
  aigc-edge help

  --threads N selects the solve/sweep fan-out (0 = auto-detect, 1 =
  serial, else N workers); outputs are bit-identical at every value.

  --trace-spans f.bin captures the flight recorder — every request
  lifecycle event, sim-clock-stamped — to a columnar span file without
  changing any output bit. `aigc-edge trace` summarizes, audits and
  exports it to a perfetto timeline.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("simulate --seed 42 --scheduler stacking").unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get("scheduler"), Some("stacking"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("serve --addr=0.0.0.0:9000").unwrap();
        assert_eq!(a.get("addr"), Some("0.0.0.0:9000"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse("serve --addr").is_err());
    }

    #[test]
    fn non_flag_is_error() {
        assert!(parse("serve addr").is_err());
    }

    #[test]
    fn defaults_to_help() {
        let a = Args::parse(std::iter::empty()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 7").unwrap();
        assert_eq!(a.get_usize("n", 1).unwrap(), 7);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
        assert!(parse("x --n seven").unwrap().get_usize("n", 1).is_err());
    }

    #[test]
    fn float_getter() {
        let a = parse("dynamic --rate 2.5").unwrap();
        assert_eq!(a.get_f64("rate", 1.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 4.0).unwrap(), 4.0);
        assert!(parse("dynamic --rate fast").unwrap().get_f64("rate", 1.0).is_err());
    }

    #[test]
    fn expect_only_catches_typos() {
        let a = parse("serve --adr 1").unwrap();
        assert!(a.expect_only(&["addr"]).is_err());
        let b = parse("serve --addr 1").unwrap();
        assert!(b.expect_only(&["addr"]).is_ok());
    }
}
