//! Lightweight serving metrics: counters, gauges and latency recorders.
//!
//! The hot path records into pre-registered slots (no allocation, no
//! locking beyond one mutex acquire); `Report::render` formats the
//! snapshot the way the examples and the server's `STATS` command print
//! it.

pub mod window;

pub use window::{ServiceWindows, WindowedSeries};

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::{percentile, Welford};

/// One resolved request as the aggregate layer sees it — the common
/// denominator of `sim::dynamic` outcomes and server-side telemetry.
#[derive(Debug, Clone, Copy)]
pub struct ResolvedSample {
    /// Charged quality (outage quality when dropped).
    pub quality: f64,
    /// Served within the deadline.
    pub met: bool,
    /// Content was actually delivered.
    pub served: bool,
    /// End-to-end delay (meaningful only when served).
    pub e2e_s: f64,
    /// Arrival → solving epoch (meaningful only when served).
    pub wait_s: f64,
}

/// Aggregates over a set of resolved requests — the standard summary a
/// serving report prints per server and fleet-wide (`sim::cluster`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutcomeStats {
    pub count: usize,
    pub served: usize,
    /// Mean charged quality (the (P0) objective; lower FID = better).
    pub mean_quality: f64,
    /// Fraction of requests not served within their deadline.
    pub outage_rate: f64,
    pub p50_e2e_s: f64,
    pub p95_e2e_s: f64,
    pub p99_e2e_s: f64,
    /// Mean queueing delay over served requests.
    pub mean_wait_s: f64,
}

impl OutcomeStats {
    /// Compute the summary. Empty input yields all-zero stats.
    pub fn from_samples(samples: &[ResolvedSample]) -> Self {
        let count = samples.len();
        if count == 0 {
            return Self {
                count: 0,
                served: 0,
                mean_quality: 0.0,
                outage_rate: 0.0,
                p50_e2e_s: 0.0,
                p95_e2e_s: 0.0,
                p99_e2e_s: 0.0,
                mean_wait_s: 0.0,
            };
        }
        let served_e2e: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.e2e_s).collect();
        let served = served_e2e.len();
        let waits: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.wait_s).collect();
        let mean_wait_s = if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        };
        Self {
            count,
            served,
            mean_quality: samples.iter().map(|s| s.quality).sum::<f64>() / count as f64,
            outage_rate: samples.iter().filter(|s| !s.met).count() as f64 / count as f64,
            p50_e2e_s: percentile(&served_e2e, 50.0),
            p95_e2e_s: percentile(&served_e2e, 95.0),
            p99_e2e_s: percentile(&served_e2e, 99.0),
            mean_wait_s,
        }
    }
}

/// A latency series: streaming moments plus a bounded sample reservoir
/// for percentiles.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    welford: Welford,
    samples: Vec<f64>,
    max_samples: usize,
}

impl LatencyRecorder {
    pub fn new(max_samples: usize) -> Self {
        Self { welford: Welford::new(), samples: Vec::new(), max_samples: max_samples.max(16) }
    }

    pub fn record(&mut self, seconds: f64) {
        self.welford.push(seconds);
        if self.samples.len() < self.max_samples {
            self.samples.push(seconds);
        } else {
            // Reservoir sampling keeps percentiles unbiased under load.
            let n = self.welford.count();
            let idx = (n as usize * 2654435761) % self.welford.count() as usize;
            if idx < self.max_samples {
                self.samples[idx] = seconds;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.welford.count()
    }

    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }
}

/// A named metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    latencies: BTreeMap<&'static str, LatencyRecorder>,
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    pub fn inc(&self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &'static str, delta: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(name).or_insert(0) += delta;
    }

    pub fn set_gauge(&self, name: &'static str, value: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(name, value);
    }

    pub fn record_latency(&self, name: &'static str, seconds: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.latencies.entry(name).or_insert_with(|| LatencyRecorder::new(4096)).record(seconds);
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn latency_mean(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().latencies.get(name).map(|l| l.mean())
    }

    /// Render a human-readable snapshot.
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        if let Some(started) = self.started {
            out.push_str(&format!("uptime_s: {:.1}\n", started.elapsed().as_secs_f64()));
        }
        for (name, v) in &inner.counters {
            out.push_str(&format!("counter {name}: {v}\n"));
        }
        for (name, v) in &inner.gauges {
            out.push_str(&format!("gauge {name}: {v:.6}\n"));
        }
        for (name, l) in &inner.latencies {
            out.push_str(&format!(
                "latency {name}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n",
                l.count(),
                l.mean() * 1e3,
                l.p50() * 1e3,
                l.p95() * 1e3,
                l.p99() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_stats_aggregate() {
        let samples = [
            ResolvedSample { quality: 30.0, met: true, served: true, e2e_s: 1.0, wait_s: 0.5 },
            ResolvedSample { quality: 40.0, met: true, served: true, e2e_s: 3.0, wait_s: 1.5 },
            ResolvedSample { quality: 450.0, met: false, served: false, e2e_s: 0.0, wait_s: 0.0 },
        ];
        let stats = OutcomeStats::from_samples(&samples);
        assert_eq!(stats.count, 3);
        assert_eq!(stats.served, 2);
        assert!((stats.mean_quality - (30.0 + 40.0 + 450.0) / 3.0).abs() < 1e-12);
        assert!((stats.outage_rate - 1.0 / 3.0).abs() < 1e-12);
        assert!((stats.p50_e2e_s - 2.0).abs() < 1e-9, "p50 over served only");
        assert!((stats.mean_wait_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn outcome_stats_empty_is_zero() {
        let stats = OutcomeStats::from_samples(&[]);
        assert_eq!(stats.count, 0);
        assert_eq!(stats.mean_quality, 0.0);
        assert_eq!(stats.p99_e2e_s, 0.0);
    }

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests");
        m.add("requests", 4);
        m.set_gauge("batch_size", 12.0);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("batch_size"), Some(12.0));
    }

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new(128);
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        assert_eq!(r.count(), 100);
        assert!((r.mean() - 0.0505).abs() < 1e-9);
        assert!((r.p50() - 0.0505).abs() < 0.001);
        assert!(r.p95() > 0.09 && r.p95() <= 0.1);
    }

    #[test]
    fn reservoir_bounded() {
        let mut r = LatencyRecorder::new(64);
        for i in 0..10_000 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        assert!(r.samples.len() <= 64);
    }

    #[test]
    fn render_contains_all_series() {
        let m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        m.record_latency("lat", 0.010);
        let s = m.render();
        assert!(s.contains("counter a: 1"));
        assert!(s.contains("gauge g"));
        assert!(s.contains("latency lat"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("n");
                        m.record_latency("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }
}
