//! Lightweight serving metrics: counters, gauges and latency recorders.
//!
//! The hot path records into pre-registered slots (no allocation, no
//! locking beyond one mutex acquire); `Report::render` formats the
//! snapshot the way the examples and the server's `STATS` command print
//! it.

pub mod window;

pub use window::{ServiceWindows, WindowedSeries};

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::{percentile, Welford};

/// One resolved request as the aggregate layer sees it — the common
/// denominator of `sim::dynamic` outcomes and server-side telemetry.
#[derive(Debug, Clone, Copy)]
pub struct ResolvedSample {
    /// Charged quality (outage quality when dropped).
    pub quality: f64,
    /// Served within the deadline.
    pub met: bool,
    /// Content was actually delivered.
    pub served: bool,
    /// End-to-end delay (meaningful only when served).
    pub e2e_s: f64,
    /// Arrival → solving epoch (meaningful only when served).
    pub wait_s: f64,
}

/// Aggregates over a set of resolved requests — the standard summary a
/// serving report prints per server and fleet-wide (`sim::cluster`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutcomeStats {
    pub count: usize,
    pub served: usize,
    /// Mean charged quality (the (P0) objective; lower FID = better).
    pub mean_quality: f64,
    /// Fraction of requests not served within their deadline.
    pub outage_rate: f64,
    pub p50_e2e_s: f64,
    pub p95_e2e_s: f64,
    pub p99_e2e_s: f64,
    /// Mean queueing delay over served requests.
    pub mean_wait_s: f64,
}

impl OutcomeStats {
    /// Compute the summary. Empty input yields all-zero stats.
    pub fn from_samples(samples: &[ResolvedSample]) -> Self {
        let count = samples.len();
        if count == 0 {
            return Self {
                count: 0,
                served: 0,
                mean_quality: 0.0,
                outage_rate: 0.0,
                p50_e2e_s: 0.0,
                p95_e2e_s: 0.0,
                p99_e2e_s: 0.0,
                mean_wait_s: 0.0,
            };
        }
        let served_e2e: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.e2e_s).collect();
        let served = served_e2e.len();
        let waits: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.wait_s).collect();
        let mean_wait_s = if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        };
        Self {
            count,
            served,
            mean_quality: samples.iter().map(|s| s.quality).sum::<f64>() / count as f64,
            outage_rate: samples.iter().filter(|s| !s.met).count() as f64 / count as f64,
            p50_e2e_s: percentile(&served_e2e, 50.0),
            p95_e2e_s: percentile(&served_e2e, 95.0),
            p99_e2e_s: percentile(&served_e2e, 99.0),
            mean_wait_s,
        }
    }
}

/// One resolved request as the recovery layer sees it — the inputs
/// [`RecoveryStats`] needs, decoupled from `sim` types so the metrics
/// layer stays leaf-level.
#[derive(Debug, Clone, Copy)]
pub struct RecoverySample {
    pub arrival_s: f64,
    /// Instant the request left the system (completion or drop).
    pub resolved_s: f64,
    /// End-to-end delay (meaningful only when served).
    pub e2e_s: f64,
    /// Relative deadline τ — the censored delay charged when dropped.
    pub deadline_s: f64,
    pub served: bool,
    pub met: bool,
}

/// Post-failure recovery aggregates for a fault-injected cluster run
/// (`sim::event`): how long failures take to drain and what they cost
/// the latency tail.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryStats {
    /// Server failures that fired during the run.
    pub failures: usize,
    /// Requests successfully handed to another server.
    pub migrated: usize,
    /// Requests dropped because their server died unmigrated.
    pub lost_to_failure: usize,
    /// Mean over failures of the time until every request that was in
    /// the system at the failure instant had left it (0 when a failure
    /// found an empty system).
    pub mean_time_to_drain_s: f64,
    /// Deadline-censored p99 delay over requests a failure could have
    /// touched — in the system at the failure instant or arriving
    /// within the window after it. Served requests charge their e2e,
    /// dropped ones their deadline (the user waited at least that and
    /// got nothing) — so dropping requests can never flatter the tail.
    pub post_failure_p99_s: f64,
    /// Outage rate over the same post-failure windows.
    pub post_failure_outage_rate: f64,
    /// Requests inside any post-failure window.
    pub post_failure_count: usize,
}

impl RecoveryStats {
    /// Compute the aggregates. `window_s` bounds the post-failure
    /// observation window after each failure instant. Empty inputs
    /// yield all-zero stats.
    pub fn compute(
        failure_times: &[f64],
        window_s: f64,
        migrated: usize,
        lost_to_failure: usize,
        samples: &[RecoverySample],
    ) -> Self {
        let mut drain_sum = 0.0;
        for &f in failure_times {
            let drain = samples
                .iter()
                .filter(|s| s.arrival_s <= f && s.resolved_s > f)
                .map(|s| s.resolved_s - f)
                .fold(0.0, f64::max);
            drain_sum += drain;
        }
        let mean_time_to_drain_s =
            if failure_times.is_empty() { 0.0 } else { drain_sum / failure_times.len() as f64 };
        let post: Vec<&RecoverySample> = samples
            .iter()
            .filter(|s| {
                failure_times.iter().any(|&f| s.resolved_s >= f && s.arrival_s <= f + window_s)
            })
            .collect();
        let censored: Vec<f64> =
            post.iter().map(|s| if s.served { s.e2e_s } else { s.deadline_s }).collect();
        let post_failure_outage_rate = if post.is_empty() {
            0.0
        } else {
            post.iter().filter(|s| !s.met).count() as f64 / post.len() as f64
        };
        Self {
            failures: failure_times.len(),
            migrated,
            lost_to_failure,
            mean_time_to_drain_s,
            post_failure_p99_s: percentile(&censored, 99.0),
            post_failure_outage_rate,
            post_failure_count: post.len(),
        }
    }
}

/// A latency series: streaming moments plus a bounded sample reservoir
/// for percentiles.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    welford: Welford,
    samples: Vec<f64>,
    max_samples: usize,
}

impl LatencyRecorder {
    pub fn new(max_samples: usize) -> Self {
        Self { welford: Welford::new(), samples: Vec::new(), max_samples: max_samples.max(16) }
    }

    pub fn record(&mut self, seconds: f64) {
        self.welford.push(seconds);
        if self.samples.len() < self.max_samples {
            self.samples.push(seconds);
        } else {
            // Reservoir sampling keeps percentiles unbiased under load.
            let n = self.welford.count();
            let idx = (n as usize * 2654435761) % self.welford.count() as usize;
            if idx < self.max_samples {
                self.samples[idx] = seconds;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.welford.count()
    }

    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }
}

/// A named metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    latencies: BTreeMap<&'static str, LatencyRecorder>,
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    pub fn inc(&self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &'static str, delta: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(name).or_insert(0) += delta;
    }

    pub fn set_gauge(&self, name: &'static str, value: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(name, value);
    }

    pub fn record_latency(&self, name: &'static str, seconds: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.latencies.entry(name).or_insert_with(|| LatencyRecorder::new(4096)).record(seconds);
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn latency_mean(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().latencies.get(name).map(|l| l.mean())
    }

    /// Render a human-readable snapshot.
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        if let Some(started) = self.started {
            out.push_str(&format!("uptime_s: {:.1}\n", started.elapsed().as_secs_f64()));
        }
        for (name, v) in &inner.counters {
            out.push_str(&format!("counter {name}: {v}\n"));
        }
        for (name, v) in &inner.gauges {
            out.push_str(&format!("gauge {name}: {v:.6}\n"));
        }
        for (name, l) in &inner.latencies {
            out.push_str(&format!(
                "latency {name}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n",
                l.count(),
                l.mean() * 1e3,
                l.p50() * 1e3,
                l.p95() * 1e3,
                l.p99() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_stats_aggregate() {
        let samples = [
            ResolvedSample { quality: 30.0, met: true, served: true, e2e_s: 1.0, wait_s: 0.5 },
            ResolvedSample { quality: 40.0, met: true, served: true, e2e_s: 3.0, wait_s: 1.5 },
            ResolvedSample { quality: 450.0, met: false, served: false, e2e_s: 0.0, wait_s: 0.0 },
        ];
        let stats = OutcomeStats::from_samples(&samples);
        assert_eq!(stats.count, 3);
        assert_eq!(stats.served, 2);
        assert!((stats.mean_quality - (30.0 + 40.0 + 450.0) / 3.0).abs() < 1e-12);
        assert!((stats.outage_rate - 1.0 / 3.0).abs() < 1e-12);
        assert!((stats.p50_e2e_s - 2.0).abs() < 1e-9, "p50 over served only");
        assert!((stats.mean_wait_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn outcome_stats_empty_is_zero() {
        let stats = OutcomeStats::from_samples(&[]);
        assert_eq!(stats.count, 0);
        assert_eq!(stats.mean_quality, 0.0);
        assert_eq!(stats.p99_e2e_s, 0.0);
    }

    #[test]
    fn recovery_stats_drain_and_censored_tail() {
        let s = |arrival: f64, resolved: f64, e2e: f64, deadline: f64, served: bool| {
            RecoverySample {
                arrival_s: arrival,
                resolved_s: resolved,
                e2e_s: e2e,
                deadline_s: deadline,
                served,
                met: served,
            }
        };
        let samples = [
            s(0.0, 2.0, 2.0, 10.0, true),   // in-system at the failure, drains at 2.0
            s(0.5, 4.0, 3.5, 10.0, true),   // in-system, drains at 4.0
            s(1.5, 3.0, 1.5, 10.0, true),   // post-failure window, served fast
            s(2.0, 2.5, 0.0, 12.0, false),  // post-failure drop: charged its deadline
            s(50.0, 51.0, 1.0, 10.0, true), // far outside every window
        ];
        let stats = RecoveryStats::compute(&[1.0], 30.0, 3, 1, &samples);
        assert_eq!(stats.failures, 1);
        assert_eq!(stats.migrated, 3);
        assert_eq!(stats.lost_to_failure, 1);
        // requests 0 and 1 were in-system at t = 1.0; the last leaves at 4.0
        assert!((stats.mean_time_to_drain_s - 3.0).abs() < 1e-12);
        // the failure's window touches everything in-system at t = 1
        // or arriving before t = 31: all but the far-out last sample —
        // and the censored drop charges its 12 s deadline
        assert_eq!(stats.post_failure_count, 4);
        assert!(stats.post_failure_p99_s > 3.5 && stats.post_failure_p99_s <= 12.0);
        assert!((stats.post_failure_outage_rate - 0.25).abs() < 1e-12);
    }

    #[test]
    fn recovery_stats_empty_inputs_are_zero() {
        let stats = RecoveryStats::compute(&[], 30.0, 0, 0, &[]);
        assert_eq!(stats.failures, 0);
        assert_eq!(stats.mean_time_to_drain_s, 0.0);
        assert_eq!(stats.post_failure_p99_s, 0.0);
        assert_eq!(stats.post_failure_count, 0);
    }

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests");
        m.add("requests", 4);
        m.set_gauge("batch_size", 12.0);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("batch_size"), Some(12.0));
    }

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new(128);
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        assert_eq!(r.count(), 100);
        assert!((r.mean() - 0.0505).abs() < 1e-9);
        assert!((r.p50() - 0.0505).abs() < 0.001);
        assert!(r.p95() > 0.09 && r.p95() <= 0.1);
    }

    #[test]
    fn reservoir_bounded() {
        let mut r = LatencyRecorder::new(64);
        for i in 0..10_000 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        assert!(r.samples.len() <= 64);
    }

    #[test]
    fn render_contains_all_series() {
        let m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        m.record_latency("lat", 0.010);
        let s = m.render();
        assert!(s.contains("counter a: 1"));
        assert!(s.contains("gauge g"));
        assert!(s.contains("latency lat"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("n");
                        m.record_latency("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }
}
