//! Lightweight serving metrics: counters, gauges and latency recorders.
//!
//! The hot path records into pre-registered slots (no allocation, no
//! locking beyond one mutex acquire); `Report::render` formats the
//! snapshot the way the examples and the server's `STATS` command print
//! it.

pub mod window;

pub use window::{ServiceWindows, WindowedSeries};

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::{percentile, Welford};

/// A latency series: streaming moments plus a bounded sample reservoir
/// for percentiles.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    welford: Welford,
    samples: Vec<f64>,
    max_samples: usize,
}

impl LatencyRecorder {
    pub fn new(max_samples: usize) -> Self {
        Self { welford: Welford::new(), samples: Vec::new(), max_samples: max_samples.max(16) }
    }

    pub fn record(&mut self, seconds: f64) {
        self.welford.push(seconds);
        if self.samples.len() < self.max_samples {
            self.samples.push(seconds);
        } else {
            // Reservoir sampling keeps percentiles unbiased under load.
            let n = self.welford.count();
            let idx = (n as usize * 2654435761) % self.welford.count() as usize;
            if idx < self.max_samples {
                self.samples[idx] = seconds;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.welford.count()
    }

    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }
}

/// A named metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    latencies: BTreeMap<&'static str, LatencyRecorder>,
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    pub fn inc(&self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &'static str, delta: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(name).or_insert(0) += delta;
    }

    pub fn set_gauge(&self, name: &'static str, value: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(name, value);
    }

    pub fn record_latency(&self, name: &'static str, seconds: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.latencies.entry(name).or_insert_with(|| LatencyRecorder::new(4096)).record(seconds);
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn latency_mean(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().latencies.get(name).map(|l| l.mean())
    }

    /// Render a human-readable snapshot.
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        if let Some(started) = self.started {
            out.push_str(&format!("uptime_s: {:.1}\n", started.elapsed().as_secs_f64()));
        }
        for (name, v) in &inner.counters {
            out.push_str(&format!("counter {name}: {v}\n"));
        }
        for (name, v) in &inner.gauges {
            out.push_str(&format!("gauge {name}: {v:.6}\n"));
        }
        for (name, l) in &inner.latencies {
            out.push_str(&format!(
                "latency {name}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n",
                l.count(),
                l.mean() * 1e3,
                l.p50() * 1e3,
                l.p95() * 1e3,
                l.p99() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests");
        m.add("requests", 4);
        m.set_gauge("batch_size", 12.0);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("batch_size"), Some(12.0));
    }

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new(128);
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        assert_eq!(r.count(), 100);
        assert!((r.mean() - 0.0505).abs() < 1e-9);
        assert!((r.p50() - 0.0505).abs() < 0.001);
        assert!(r.p95() > 0.09 && r.p95() <= 0.1);
    }

    #[test]
    fn reservoir_bounded() {
        let mut r = LatencyRecorder::new(64);
        for i in 0..10_000 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        assert!(r.samples.len() <= 64);
    }

    #[test]
    fn render_contains_all_series() {
        let m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        m.record_latency("lat", 0.010);
        let s = m.render();
        assert!(s.contains("counter a: 1"));
        assert!(s.contains("gauge g"));
        assert!(s.contains("latency lat"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("n");
                        m.record_latency("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }
}
