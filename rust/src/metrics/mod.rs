//! Lightweight serving metrics: counters, gauges and latency recorders.
//!
//! The hot path records into pre-registered slots (no allocation, no
//! locking beyond one mutex acquire); `Report::render` formats the
//! snapshot the way the examples and the server's `STATS` command print
//! it.

pub mod window;

pub use window::{ServiceWindows, WindowedSeries};

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::rng::Pcg64;
use crate::util::stats::{percentile, QuantileSketch, Welford};

/// How percentile-bearing aggregates are computed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Buffer per-request samples and sort — exact percentiles. The
    /// default: golden fixtures and bit-identity guards rely on it.
    #[default]
    Exact,
    /// Constant-memory scalar sums plus a GK quantile sketch; rank
    /// error is bounded by the sketch's `eps` and memory stays flat
    /// over 10⁷-request sweeps.
    Streaming,
}

impl MetricsMode {
    pub fn name(self) -> &'static str {
        match self {
            MetricsMode::Exact => "exact",
            MetricsMode::Streaming => "streaming",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "exact" => Some(MetricsMode::Exact),
            "streaming" => Some(MetricsMode::Streaming),
            _ => None,
        }
    }
}

/// One resolved request as the aggregate layer sees it — the common
/// denominator of `sim::dynamic` outcomes and server-side telemetry.
#[derive(Debug, Clone, Copy)]
pub struct ResolvedSample {
    /// Charged quality (outage quality when dropped).
    pub quality: f64,
    /// Served within the deadline.
    pub met: bool,
    /// Content was actually delivered.
    pub served: bool,
    /// End-to-end delay (meaningful only when served).
    pub e2e_s: f64,
    /// Arrival → solving epoch (meaningful only when served).
    pub wait_s: f64,
}

/// Aggregates over a set of resolved requests — the standard summary a
/// serving report prints per server and fleet-wide (`sim::cluster`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutcomeStats {
    pub count: usize,
    pub served: usize,
    /// Mean charged quality (the (P0) objective; lower FID = better).
    pub mean_quality: f64,
    /// Fraction of requests not served within their deadline.
    pub outage_rate: f64,
    pub p50_e2e_s: f64,
    pub p95_e2e_s: f64,
    pub p99_e2e_s: f64,
    /// Mean queueing delay over served requests.
    pub mean_wait_s: f64,
}

impl OutcomeStats {
    /// Compute the summary. Empty input yields all-zero stats.
    pub fn from_samples(samples: &[ResolvedSample]) -> Self {
        let count = samples.len();
        if count == 0 {
            return Self {
                count: 0,
                served: 0,
                mean_quality: 0.0,
                outage_rate: 0.0,
                p50_e2e_s: 0.0,
                p95_e2e_s: 0.0,
                p99_e2e_s: 0.0,
                mean_wait_s: 0.0,
            };
        }
        let served_e2e: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.e2e_s).collect();
        let served = served_e2e.len();
        let waits: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.wait_s).collect();
        let mean_wait_s = if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        };
        Self {
            count,
            served,
            mean_quality: samples.iter().map(|s| s.quality).sum::<f64>() / count as f64,
            outage_rate: samples.iter().filter(|s| !s.met).count() as f64 / count as f64,
            p50_e2e_s: percentile(&served_e2e, 50.0),
            p95_e2e_s: percentile(&served_e2e, 95.0),
            p99_e2e_s: percentile(&served_e2e, 99.0),
            mean_wait_s,
        }
    }
}

/// Incremental aggregation of [`ResolvedSample`]s — the streaming
/// counterpart of [`OutcomeStats::from_samples`]. Exact mode buffers
/// the served-delay vector and reproduces `from_samples` bit-for-bit;
/// streaming mode holds only scalar sums plus a [`QuantileSketch`], so
/// memory does not grow with the request count.
#[derive(Debug, Clone)]
pub struct OutcomeAccumulator {
    count: usize,
    served: usize,
    not_met: usize,
    quality_sum: f64,
    wait_sum: f64,
    e2e: E2eAgg,
}

#[derive(Debug, Clone)]
enum E2eAgg {
    /// Served delays buffered for exact percentiles.
    Exact(Vec<f64>),
    /// One sketch per merged source (per-server in a cluster); fleet
    /// quantiles combine them without a lossy merge, so the combined
    /// rank error stays within `eps · N`.
    Sketch(Vec<QuantileSketch>),
}

impl OutcomeAccumulator {
    pub fn exact() -> Self {
        Self::with_agg(E2eAgg::Exact(Vec::new()))
    }

    pub fn streaming(eps: f64) -> Self {
        Self::with_agg(E2eAgg::Sketch(vec![QuantileSketch::new(eps)]))
    }

    pub fn for_mode(mode: MetricsMode, eps: f64) -> Self {
        match mode {
            MetricsMode::Exact => Self::exact(),
            MetricsMode::Streaming => Self::streaming(eps),
        }
    }

    fn with_agg(e2e: E2eAgg) -> Self {
        Self { count: 0, served: 0, not_met: 0, quality_sum: 0.0, wait_sum: 0.0, e2e }
    }

    pub fn is_streaming(&self) -> bool {
        matches!(self.e2e, E2eAgg::Sketch(_))
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn served(&self) -> usize {
        self.served
    }

    /// Values currently retained for percentile estimation — in
    /// streaming mode bounded by the sketch, not by the stream.
    pub fn support_len(&self) -> usize {
        match &self.e2e {
            E2eAgg::Exact(v) => v.len(),
            E2eAgg::Sketch(sketches) => sketches.iter().map(|s| s.support_len()).sum(),
        }
    }

    pub fn push(&mut self, s: ResolvedSample) {
        self.count += 1;
        self.quality_sum += s.quality;
        if !s.met {
            self.not_met += 1;
        }
        if s.served {
            self.served += 1;
            self.wait_sum += s.wait_s;
            match &mut self.e2e {
                E2eAgg::Exact(v) => v.push(s.e2e_s),
                E2eAgg::Sketch(sketches) => sketches[0].insert(s.e2e_s),
            }
        }
    }

    /// Absorb another accumulator (per-server → fleet). Both sides
    /// must share a mode.
    pub fn merge(&mut self, other: OutcomeAccumulator) {
        self.count += other.count;
        self.served += other.served;
        self.not_met += other.not_met;
        self.quality_sum += other.quality_sum;
        self.wait_sum += other.wait_sum;
        match (&mut self.e2e, other.e2e) {
            (E2eAgg::Exact(a), E2eAgg::Exact(b)) => a.extend_from_slice(&b),
            (E2eAgg::Sketch(a), E2eAgg::Sketch(b)) => a.extend(b),
            _ => panic!("cannot merge exact and streaming outcome accumulators"),
        }
    }

    /// Served end-to-end delay percentile, `p` in `[0, 100]`.
    pub fn quantile(&self, p: f64) -> f64 {
        match &self.e2e {
            E2eAgg::Exact(v) => percentile(v, p),
            E2eAgg::Sketch(sketches) => match sketches.as_slice() {
                [one] => one.quantile(p),
                many => {
                    let refs: Vec<&QuantileSketch> = many.iter().collect();
                    QuantileSketch::combined_quantile(&refs, p)
                }
            },
        }
    }

    /// The standard summary. In exact mode this is bit-identical to
    /// [`OutcomeStats::from_samples`] over the same push sequence.
    pub fn stats(&self) -> OutcomeStats {
        if self.count == 0 {
            return OutcomeStats::from_samples(&[]);
        }
        OutcomeStats {
            count: self.count,
            served: self.served,
            mean_quality: self.quality_sum / self.count as f64,
            outage_rate: self.not_met as f64 / self.count as f64,
            p50_e2e_s: self.quantile(50.0),
            p95_e2e_s: self.quantile(95.0),
            p99_e2e_s: self.quantile(99.0),
            mean_wait_s: if self.served == 0 { 0.0 } else { self.wait_sum / self.served as f64 },
        }
    }
}

/// One resolved request as the recovery layer sees it — the inputs
/// [`RecoveryStats`] needs, decoupled from `sim` types so the metrics
/// layer stays leaf-level.
#[derive(Debug, Clone, Copy)]
pub struct RecoverySample {
    pub arrival_s: f64,
    /// Instant the request left the system (completion or drop).
    pub resolved_s: f64,
    /// End-to-end delay (meaningful only when served).
    pub e2e_s: f64,
    /// Relative deadline τ — the censored delay charged when dropped.
    pub deadline_s: f64,
    pub served: bool,
    pub met: bool,
    /// Delivered via a checkpoint resume on another server after its
    /// first server died mid-batch (implies `served`).
    pub resumed: bool,
    /// Denoising steps salvaged from the dead server's partial batch
    /// (non-zero only when `resumed`).
    pub recovered_steps: u32,
}

/// Post-failure recovery aggregates for a fault-injected cluster run
/// (`sim::event`): how long failures take to drain and what they cost
/// the latency tail.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryStats {
    /// Server failures that fired during the run.
    pub failures: usize,
    /// Requests successfully handed to another server.
    pub migrated: usize,
    /// Requests dropped because their server died unmigrated.
    pub lost_to_failure: usize,
    /// Mean over failures of the time until every request that was in
    /// the system at the failure instant had left it (0 when a failure
    /// found an empty system).
    pub mean_time_to_drain_s: f64,
    /// Deadline-censored p99 delay over requests a failure could have
    /// touched — in the system at the failure instant or arriving
    /// within the window after it. Served requests charge their e2e,
    /// dropped ones their deadline (the user waited at least that and
    /// got nothing) — so dropping requests can never flatter the tail.
    pub post_failure_p99_s: f64,
    /// Outage rate over the same post-failure windows.
    pub post_failure_outage_rate: f64,
    /// Requests inside any post-failure window.
    pub post_failure_count: usize,
    /// Requests served via checkpoint resume after their server died.
    pub resumed: usize,
    /// Total denoising steps salvaged from dead servers' partial
    /// batches across all resumes.
    pub recovered_steps: u64,
}

impl RecoveryStats {
    /// Compute the aggregates. `window_s` bounds the post-failure
    /// observation window after each failure instant. Empty inputs
    /// yield all-zero stats.
    pub fn compute(
        failure_times: &[f64],
        window_s: f64,
        migrated: usize,
        lost_to_failure: usize,
        samples: &[RecoverySample],
    ) -> Self {
        let mut drain_sum = 0.0;
        for &f in failure_times {
            let drain = samples
                .iter()
                .filter(|s| s.arrival_s <= f && s.resolved_s > f)
                .map(|s| s.resolved_s - f)
                .fold(0.0, f64::max);
            drain_sum += drain;
        }
        let mean_time_to_drain_s =
            if failure_times.is_empty() { 0.0 } else { drain_sum / failure_times.len() as f64 };
        let post: Vec<&RecoverySample> = samples
            .iter()
            .filter(|s| {
                failure_times.iter().any(|&f| s.resolved_s >= f && s.arrival_s <= f + window_s)
            })
            .collect();
        let censored: Vec<f64> =
            post.iter().map(|s| if s.served { s.e2e_s } else { s.deadline_s }).collect();
        let post_failure_outage_rate = if post.is_empty() {
            0.0
        } else {
            post.iter().filter(|s| !s.met).count() as f64 / post.len() as f64
        };
        Self {
            failures: failure_times.len(),
            migrated,
            lost_to_failure,
            mean_time_to_drain_s,
            post_failure_p99_s: percentile(&censored, 99.0),
            post_failure_outage_rate,
            post_failure_count: post.len(),
            resumed: samples.iter().filter(|s| s.resumed).count(),
            recovered_steps: samples.iter().map(|s| s.recovered_steps as u64).sum(),
        }
    }
}

/// A latency series: streaming moments plus a bounded sample reservoir
/// for percentiles (Vitter's Algorithm R over a seeded PCG stream, so
/// every recorder replays deterministically).
#[derive(Debug)]
pub struct LatencyRecorder {
    welford: Welford,
    samples: Vec<f64>,
    max_samples: usize,
    rng: Pcg64,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new(4096)
    }
}

impl LatencyRecorder {
    /// Fixed reservoir seed ("LatencyR") so registries stay
    /// deterministic without callers threading seeds around.
    const DEFAULT_SEED: u64 = 0x4c61_7465_6e63_7952;

    pub fn new(max_samples: usize) -> Self {
        Self::with_seed(max_samples, Self::DEFAULT_SEED)
    }

    pub fn with_seed(max_samples: usize, seed: u64) -> Self {
        Self {
            welford: Welford::new(),
            samples: Vec::new(),
            max_samples: max_samples.max(16),
            rng: Pcg64::seeded(seed),
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.welford.push(seconds);
        if self.samples.len() < self.max_samples {
            self.samples.push(seconds);
        } else {
            // Algorithm R: the n-th value replaces a uniformly random
            // slot with probability max_samples / n, which keeps the
            // reservoir a uniform sample of the whole stream.
            let n = self.welford.count();
            let j = self.rng.below(n);
            if (j as usize) < self.max_samples {
                self.samples[j as usize] = seconds;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.welford.count()
    }

    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }
}

/// A named metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    latencies: BTreeMap<&'static str, LatencyRecorder>,
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    pub fn inc(&self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &'static str, delta: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(name).or_insert(0) += delta;
    }

    pub fn set_gauge(&self, name: &'static str, value: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(name, value);
    }

    pub fn record_latency(&self, name: &'static str, seconds: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.latencies.entry(name).or_insert_with(|| LatencyRecorder::new(4096)).record(seconds);
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn latency_mean(&self, name: &'static str) -> Option<f64> {
        self.inner.lock().unwrap().latencies.get(name).map(|l| l.mean())
    }

    /// Render a human-readable snapshot: the wall-clock `uptime_s`
    /// line (when the registry tracks a start instant) followed by
    /// [`render_body`](Self::render_body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(started) = self.started {
            out.push_str(&format!("uptime_s: {:.1}\n", started.elapsed().as_secs_f64()));
        }
        out.push_str(&self.render_body());
        out
    }

    /// The counter/gauge/latency body of [`render`](Self::render),
    /// without the wall-clock uptime line — a pure function of the
    /// registry contents, so protocol tests can assert the STATS reply
    /// byte-for-byte (`BTreeMap` iteration makes line order stable).
    pub fn render_body(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, v) in &inner.counters {
            out.push_str(&format!("counter {name}: {v}\n"));
        }
        for (name, v) in &inner.gauges {
            out.push_str(&format!("gauge {name}: {v:.6}\n"));
        }
        for (name, l) in &inner.latencies {
            out.push_str(&format!(
                "latency {name}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n",
                l.count(),
                l.mean() * 1e3,
                l.p50() * 1e3,
                l.p95() * 1e3,
                l.p99() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_stats_aggregate() {
        let samples = [
            ResolvedSample { quality: 30.0, met: true, served: true, e2e_s: 1.0, wait_s: 0.5 },
            ResolvedSample { quality: 40.0, met: true, served: true, e2e_s: 3.0, wait_s: 1.5 },
            ResolvedSample { quality: 450.0, met: false, served: false, e2e_s: 0.0, wait_s: 0.0 },
        ];
        let stats = OutcomeStats::from_samples(&samples);
        assert_eq!(stats.count, 3);
        assert_eq!(stats.served, 2);
        assert!((stats.mean_quality - (30.0 + 40.0 + 450.0) / 3.0).abs() < 1e-12);
        assert!((stats.outage_rate - 1.0 / 3.0).abs() < 1e-12);
        assert!((stats.p50_e2e_s - 2.0).abs() < 1e-9, "p50 over served only");
        assert!((stats.mean_wait_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn outcome_stats_empty_is_zero() {
        let stats = OutcomeStats::from_samples(&[]);
        assert_eq!(stats.count, 0);
        assert_eq!(stats.mean_quality, 0.0);
        assert_eq!(stats.p99_e2e_s, 0.0);
    }

    #[test]
    fn recovery_stats_drain_and_censored_tail() {
        let s = |arrival: f64, resolved: f64, e2e: f64, deadline: f64, served: bool| {
            RecoverySample {
                arrival_s: arrival,
                resolved_s: resolved,
                e2e_s: e2e,
                deadline_s: deadline,
                served,
                met: served,
                resumed: false,
                recovered_steps: 0,
            }
        };
        let samples = [
            s(0.0, 2.0, 2.0, 10.0, true),   // in-system at the failure, drains at 2.0
            s(0.5, 4.0, 3.5, 10.0, true),   // in-system, drains at 4.0
            s(1.5, 3.0, 1.5, 10.0, true),   // post-failure window, served fast
            s(2.0, 2.5, 0.0, 12.0, false),  // post-failure drop: charged its deadline
            s(50.0, 51.0, 1.0, 10.0, true), // far outside every window
        ];
        let stats = RecoveryStats::compute(&[1.0], 30.0, 3, 1, &samples);
        assert_eq!(stats.failures, 1);
        assert_eq!(stats.migrated, 3);
        assert_eq!(stats.lost_to_failure, 1);
        // requests 0 and 1 were in-system at t = 1.0; the last leaves at 4.0
        assert!((stats.mean_time_to_drain_s - 3.0).abs() < 1e-12);
        // the failure's window touches everything in-system at t = 1
        // or arriving before t = 31: all but the far-out last sample —
        // and the censored drop charges its 12 s deadline
        assert_eq!(stats.post_failure_count, 4);
        assert!(stats.post_failure_p99_s > 3.5 && stats.post_failure_p99_s <= 12.0);
        assert!((stats.post_failure_outage_rate - 0.25).abs() < 1e-12);
    }

    #[test]
    fn recovery_stats_empty_inputs_are_zero() {
        let stats = RecoveryStats::compute(&[], 30.0, 0, 0, &[]);
        assert_eq!(stats.failures, 0);
        assert_eq!(stats.mean_time_to_drain_s, 0.0);
        assert_eq!(stats.post_failure_p99_s, 0.0);
        assert_eq!(stats.post_failure_count, 0);
        assert_eq!(stats.resumed, 0);
        assert_eq!(stats.recovered_steps, 0);
    }

    #[test]
    fn recovery_stats_count_resumes_and_salvaged_steps() {
        let base = RecoverySample {
            arrival_s: 0.0,
            resolved_s: 2.0,
            e2e_s: 2.0,
            deadline_s: 10.0,
            served: true,
            met: true,
            resumed: false,
            recovered_steps: 0,
        };
        let samples = [
            RecoverySample { resumed: true, recovered_steps: 7, ..base },
            RecoverySample { resumed: true, recovered_steps: 3, ..base },
            base,
        ];
        let stats = RecoveryStats::compute(&[1.0], 30.0, 2, 0, &samples);
        assert_eq!(stats.resumed, 2);
        assert_eq!(stats.recovered_steps, 10);
    }

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests");
        m.add("requests", 4);
        m.set_gauge("batch_size", 12.0);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("batch_size"), Some(12.0));
    }

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new(128);
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        assert_eq!(r.count(), 100);
        assert!((r.mean() - 0.0505).abs() < 1e-9);
        assert!((r.p50() - 0.0505).abs() < 0.001);
        assert!(r.p95() > 0.09 && r.p95() <= 0.1);
    }

    #[test]
    fn reservoir_bounded() {
        let mut r = LatencyRecorder::new(64);
        for i in 0..10_000 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        assert!(r.samples.len() <= 64);
    }

    /// Regression for the degenerate reservoir index
    /// `(n * 2654435761) % n ≡ 0`, which only ever overwrote slot 0 and
    /// froze p50/p95/p99 at the first `max_samples` values.
    #[test]
    fn reservoir_tracks_full_stream_not_first_prefix() {
        let k = 256;
        let n = 10 * k;
        let mut r = LatencyRecorder::new(k);
        for i in 0..n {
            r.record(i as f64);
        }
        let hi = (n - 1) as f64;
        // The frozen prefix put p50 near k/2 = 128; a uniform reservoir
        // over the ramp tracks the full-stream percentiles (~0.5·n).
        assert!(r.p50() > 0.3 * hi && r.p50() < 0.7 * hi, "p50={}", r.p50());
        assert!(r.p95() > 0.8 * hi, "p95={}", r.p95());
        assert!(r.p99() > 0.85 * hi, "p99={}", r.p99());
        assert_eq!(r.samples.len(), k);
    }

    #[test]
    fn reservoir_replays_bit_identically() {
        let run = |seed: u64| {
            let mut r = LatencyRecorder::with_seed(64, seed);
            for i in 0..5000u64 {
                r.record((i * 7 % 101) as f64);
            }
            (r.p50().to_bits(), r.p95().to_bits(), r.p99().to_bits())
        };
        assert_eq!(run(9), run(9));
        assert_eq!(run(LatencyRecorder::DEFAULT_SEED), {
            let mut r = LatencyRecorder::new(64);
            for i in 0..5000u64 {
                r.record((i * 7 % 101) as f64);
            }
            (r.p50().to_bits(), r.p95().to_bits(), r.p99().to_bits())
        });
    }

    fn mixed_samples(n: usize) -> Vec<ResolvedSample> {
        let mut rng = Pcg64::seeded(77);
        (0..n)
            .map(|_| {
                let served = rng.uniform() < 0.9;
                ResolvedSample {
                    quality: rng.uniform_in(20.0, 60.0),
                    met: served && rng.uniform() < 0.95,
                    served,
                    e2e_s: if served { rng.exponential(0.5) } else { 0.0 },
                    wait_s: if served { rng.uniform_in(0.0, 2.0) } else { 0.0 },
                }
            })
            .collect()
    }

    #[test]
    fn exact_accumulator_matches_from_samples_bitwise() {
        let samples = mixed_samples(4000);
        let mut acc = OutcomeAccumulator::exact();
        for &s in &samples {
            acc.push(s);
        }
        assert_eq!(acc.stats(), OutcomeStats::from_samples(&samples));
        assert!(!acc.is_streaming());
        assert_eq!(acc.support_len(), samples.iter().filter(|s| s.served).count());
    }

    #[test]
    fn streaming_accumulator_tracks_exact_within_eps() {
        let samples = mixed_samples(20_000);
        let eps = 0.01;
        let mut acc = OutcomeAccumulator::streaming(eps);
        for &s in &samples {
            acc.push(s);
        }
        let exact = OutcomeStats::from_samples(&samples);
        let got = acc.stats();
        assert_eq!(got.count, exact.count);
        assert_eq!(got.served, exact.served);
        assert!((got.mean_quality - exact.mean_quality).abs() < 1e-12);
        assert!((got.outage_rate - exact.outage_rate).abs() < 1e-12);
        // The sketch guarantees rank error ≤ ⌈eps·n⌉ over the served
        // delays; check the returned values against the sorted stream.
        let mut served: Vec<f64> = samples.iter().filter(|s| s.served).map(|s| s.e2e_s).collect();
        served.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = served.len() as f64;
        let budget = (eps * n).ceil() as i64 + 1;
        for (p, g) in [(50.0, got.p50_e2e_s), (95.0, got.p95_e2e_s), (99.0, got.p99_e2e_s)] {
            let target = (p / 100.0 * n).ceil().max(1.0) as i64;
            let rank = served.iter().filter(|&&v| v <= g).count() as i64;
            assert!((rank - target).abs() <= budget, "p{p}: rank {rank} target {target}");
        }
        assert!(acc.support_len() < samples.len() / 4, "support {}", acc.support_len());
        assert!(acc.is_streaming());
    }

    #[test]
    fn accumulator_merge_combines_sources() {
        let samples = mixed_samples(10_000);
        let (left, right) = samples.split_at(3000);
        let mut a = OutcomeAccumulator::exact();
        let mut b = OutcomeAccumulator::exact();
        for &s in left {
            a.push(s);
        }
        for &s in right {
            b.push(s);
        }
        a.merge(b);
        let merged = a.stats();
        let whole = OutcomeStats::from_samples(&samples);
        assert_eq!(merged.count, whole.count);
        assert_eq!(merged.served, whole.served);
        // Partial sums re-associate, so scalar means match only to fp
        // tolerance; the sorted percentiles are exactly equal.
        assert!((merged.mean_quality - whole.mean_quality).abs() < 1e-9);
        assert!((merged.mean_wait_s - whole.mean_wait_s).abs() < 1e-9);
        assert_eq!(merged.p50_e2e_s.to_bits(), whole.p50_e2e_s.to_bits());
        assert_eq!(merged.p95_e2e_s.to_bits(), whole.p95_e2e_s.to_bits());
        assert_eq!(merged.p99_e2e_s.to_bits(), whole.p99_e2e_s.to_bits());
        let mut a = OutcomeAccumulator::streaming(0.01);
        let mut b = OutcomeAccumulator::streaming(0.01);
        for &s in left {
            a.push(s);
        }
        for &s in right {
            b.push(s);
        }
        a.merge(b);
        let exact = OutcomeStats::from_samples(&samples);
        let got = a.stats();
        assert_eq!(got.count, exact.count);
        assert!((got.p95_e2e_s - exact.p95_e2e_s).abs() <= 0.2 * exact.p95_e2e_s.max(0.1));
    }

    #[test]
    fn empty_accumulators_are_zero() {
        assert_eq!(OutcomeAccumulator::exact().stats(), OutcomeStats::from_samples(&[]));
        assert_eq!(OutcomeAccumulator::streaming(0.05).stats(), OutcomeStats::from_samples(&[]));
    }

    #[test]
    fn metrics_mode_names_roundtrip() {
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            assert_eq!(MetricsMode::from_name(mode.name()), Some(mode));
        }
        assert_eq!(MetricsMode::from_name("bogus"), None);
        assert_eq!(MetricsMode::default(), MetricsMode::Exact);
    }

    #[test]
    fn render_contains_all_series() {
        let m = Metrics::new();
        m.inc("a");
        m.set_gauge("g", 1.5);
        m.record_latency("lat", 0.010);
        let s = m.render();
        assert!(s.contains("counter a: 1"));
        assert!(s.contains("gauge g"));
        assert!(s.contains("latency lat"));
    }

    #[test]
    fn render_body_is_deterministic_and_uptime_separable() {
        let m = Metrics::new();
        m.inc("req");
        m.add("req", 2);
        m.set_gauge("depth", 4.0);
        m.record_latency("lat", 0.010);
        let body = m.render_body();
        assert_eq!(body, m.render_body(), "body is a pure function of the registry");
        assert!(!body.contains("uptime_s"), "wall clock stays out of the body");
        assert!(body.starts_with("counter req: 3\n"), "{body}");
        assert!(body.contains("gauge depth: 4.000000\n"), "{body}");
        let full = m.render();
        assert!(full.starts_with("uptime_s: "), "{full}");
        assert!(full.ends_with(&body), "render = uptime line + body");
        // A default registry has no start instant: render == body.
        let bare = Metrics::default();
        bare.inc("x");
        assert_eq!(bare.render(), bare.render_body());
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("n");
                        m.record_latency("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }
}
