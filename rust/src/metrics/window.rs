//! Time-windowed aggregates over event streams — the observability
//! layer for dynamic-arrival serving (`sim::dynamic`, and reusable by
//! the online server).
//!
//! A [`WindowedSeries`] holds timestamped samples and answers
//! aggregate queries (rate, mean, percentiles, max) over the trailing
//! `window_s` seconds. Timestamps are expected to be (approximately)
//! non-decreasing — the simulator and the server both emit
//! monotonically — and pruning is amortized O(1) per push.

use std::collections::VecDeque;

use crate::util::stats::{percentile, percentile_sorted};

/// A sliding-window series of `(t_s, value)` samples.
#[derive(Debug, Clone)]
pub struct WindowedSeries {
    window_s: f64,
    points: VecDeque<(f64, f64)>,
    /// Earliest timestamp ever pushed — anchors the warmup span before
    /// a full window of time has elapsed.
    origin_s: Option<f64>,
    /// Latest instant the series has seen (pushes and prunes).
    observed_s: f64,
}

impl WindowedSeries {
    pub fn new(window_s: f64) -> Self {
        assert!(window_s > 0.0, "window must be positive");
        Self { window_s, points: VecDeque::new(), origin_s: None, observed_s: f64::NEG_INFINITY }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Record `value` at time `t_s` and drop samples older than the
    /// window. Slightly out-of-order timestamps (bounded by the window)
    /// are tolerated: pruning only ever removes from the front.
    pub fn push(&mut self, t_s: f64, value: f64) {
        self.origin_s = Some(self.origin_s.map_or(t_s, |o| o.min(t_s)));
        self.points.push_back((t_s, value));
        self.prune(t_s);
    }

    /// Drop samples strictly older than `now_s - window`.
    pub fn prune(&mut self, now_s: f64) {
        self.observed_s = self.observed_s.max(now_s);
        let cutoff = now_s - self.window_s;
        while matches!(self.points.front(), Some(&(t, _)) if t < cutoff) {
            self.points.pop_front();
        }
    }

    /// Samples currently inside the window.
    pub fn count(&self) -> usize {
        self.points.len()
    }

    /// Events per second over the window (e.g. arrival rate when every
    /// event is pushed once). Before a full window of time has elapsed
    /// the divisor is the elapsed span, not `window_s` — dividing a
    /// warmup burst by the whole window underreported load to the
    /// routing telemetry. A single-instant series (zero span) falls
    /// back to the window divisor rather than reading infinite.
    pub fn rate_hz(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        let span = match self.origin_s {
            Some(origin) => (self.observed_s - origin).min(self.window_s),
            None => self.window_s,
        };
        let divisor = if span > 0.0 { span } else { self.window_s };
        self.points.len() as f64 / divisor
    }

    /// Sum of the windowed values; 0.0 when empty.
    pub fn sum(&self) -> f64 {
        self.points.iter().map(|&(_, v)| v).sum()
    }

    /// Mean of the windowed values; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|&(_, v)| v).sum::<f64>() / self.points.len() as f64
    }

    /// Linear-interpolated percentile of the windowed values (`p` in
    /// [0, 100]); 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        let vals: Vec<f64> = self.points.iter().map(|&(_, v)| v).collect();
        percentile(&vals, p)
    }

    /// Several percentiles in one pass: collects and sorts the window
    /// once instead of once per query. Bit-identical to calling
    /// [`WindowedSeries::percentile`] per entry (same sort, same
    /// interpolation) — the per-epoch p50/p95/p99 reports rely on that.
    pub fn percentiles<const N: usize>(&self, ps: [f64; N]) -> [f64; N] {
        let mut vals: Vec<f64> = self.points.iter().map(|&(_, v)| v).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.map(|p| percentile_sorted(&vals, p))
    }

    /// Maximum of the windowed values; 0.0 when empty, like the
    /// sibling aggregates (an empty window must stay representable in
    /// JSON reports, and `-inf` is not).
    pub fn max(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Most recent value, if any.
    pub fn last(&self) -> Option<f64> {
        self.points.back().map(|&(_, v)| v)
    }
}

/// The standard window set a dynamic-serving front-end tracks; one
/// place so the simulator, the CLI and (future) server telemetry agree
/// on definitions.
#[derive(Debug, Clone)]
pub struct ServiceWindows {
    /// One event per arrival (value unused).
    pub arrivals: WindowedSeries,
    /// End-to-end delay of served requests, seconds.
    pub e2e_s: WindowedSeries,
    /// Charged quality per resolved request (served or dropped).
    pub quality: WindowedSeries,
    /// 1.0 for an outage (dropped or deadline missed), 0.0 otherwise,
    /// per resolved request.
    pub outages: WindowedSeries,
    /// Solve latency charged per epoch solve, seconds.
    pub solve_total_s: WindowedSeries,
    /// Portion of each solve hidden behind GPU execution, seconds.
    pub solve_hidden_s: WindowedSeries,
}

impl ServiceWindows {
    pub fn new(window_s: f64) -> Self {
        Self {
            arrivals: WindowedSeries::new(window_s),
            e2e_s: WindowedSeries::new(window_s),
            quality: WindowedSeries::new(window_s),
            outages: WindowedSeries::new(window_s),
            solve_total_s: WindowedSeries::new(window_s),
            solve_hidden_s: WindowedSeries::new(window_s),
        }
    }

    pub fn record_arrival(&mut self, t_s: f64) {
        self.arrivals.push(t_s, 1.0);
    }

    pub fn record_served(&mut self, t_s: f64, e2e_s: f64, quality: f64, met: bool) {
        self.e2e_s.push(t_s, e2e_s);
        self.quality.push(t_s, quality);
        self.outages.push(t_s, if met { 0.0 } else { 1.0 });
    }

    pub fn record_dropped(&mut self, t_s: f64, outage_quality: f64) {
        self.quality.push(t_s, outage_quality);
        self.outages.push(t_s, 1.0);
    }

    /// Record one epoch solve: its charged CPU latency and the part of
    /// it that overlapped GPU execution (the pipeline's hidden time).
    pub fn record_solve(&mut self, t_s: f64, total_s: f64, hidden_s: f64) {
        debug_assert!((0.0..=total_s).contains(&hidden_s) || total_s == 0.0);
        self.solve_total_s.push(t_s, total_s);
        self.solve_hidden_s.push(t_s, hidden_s);
    }

    /// Solve-overlap gauge: time the solve was hidden behind GPU
    /// execution / total solve time, over the trailing window. 0 when
    /// no solve cost was charged (e.g. `solve_latency_s = 0`).
    pub fn solve_overlap_fraction(&self) -> f64 {
        let total = self.solve_total_s.sum();
        if total <= 0.0 {
            0.0
        } else {
            self.solve_hidden_s.sum() / total
        }
    }

    /// Fraction of resolved requests in the window that were outages.
    pub fn outage_rate(&self) -> f64 {
        self.outages.mean()
    }

    /// Advance every series to `now_s`, dropping stale samples. Call
    /// before *reading* aggregates at an instant later than the last
    /// push — pushes prune automatically, reads do not.
    pub fn prune(&mut self, now_s: f64) {
        self.arrivals.prune(now_s);
        self.e2e_s.prune(now_s);
        self.quality.prune(now_s);
        self.outages.prune(now_s);
        self.solve_total_s.prune(now_s);
        self.solve_hidden_s.prune(now_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prunes_to_window() {
        let mut w = WindowedSeries::new(10.0);
        for t in 0..25 {
            w.push(t as f64, t as f64);
        }
        // window at t=24 keeps t in [14, 24]
        assert_eq!(w.count(), 11);
        assert!((w.mean() - 19.0).abs() < 1e-12);
        assert_eq!(w.last(), Some(24.0));
        assert_eq!(w.max(), 24.0);
    }

    #[test]
    fn rate_counts_events_per_second() {
        let mut w = WindowedSeries::new(5.0);
        for i in 0..20 {
            w.push(10.0 + i as f64 * 0.25, 1.0); // 4 Hz for 5 s
        }
        assert!((w.rate_hz() - 4.0).abs() < 0.5, "rate {}", w.rate_hz());
    }

    /// Regression: during warmup the rate divided by the full window,
    /// so 5 arrivals in the first second of a 10 s window read 0.5 Hz
    /// instead of 5 Hz — starving the live-state router of load signal.
    #[test]
    fn rate_uses_elapsed_span_during_warmup() {
        let mut w = WindowedSeries::new(10.0);
        for i in 0..5 {
            w.push(i as f64 * 0.25, 1.0); // 5 events over the first 1 s
        }
        assert!((w.rate_hz() - 5.0).abs() < 1e-9, "warmup rate {}", w.rate_hz());
        // A single instant has zero span: stay finite, fall back to
        // the window divisor.
        let mut one = WindowedSeries::new(10.0);
        one.push(0.0, 1.0);
        assert!((one.rate_hz() - 0.1).abs() < 1e-12);
        // Once a full window has elapsed, the divisor is the window
        // again — steady-state readings are unchanged by the fix.
        let mut steady = WindowedSeries::new(5.0);
        for i in 0..80 {
            steady.push(i as f64 * 0.25, 1.0); // 4 Hz for 20 s
        }
        assert!((steady.rate_hz() - 21.0 / 5.0).abs() < 1e-9, "steady rate {}", steady.rate_hz());
    }

    #[test]
    fn percentiles_over_window_only() {
        let mut w = WindowedSeries::new(4.0);
        w.push(0.0, 1000.0); // will fall out
        for t in 10..14 {
            w.push(t as f64, (t - 9) as f64);
        }
        assert_eq!(w.count(), 4);
        assert!((w.percentile(50.0) - 2.5).abs() < 1e-9);
        assert!((w.percentile(100.0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn tolerates_slightly_out_of_order_pushes() {
        let mut w = WindowedSeries::new(10.0);
        w.push(5.0, 1.0);
        w.push(4.5, 2.0); // earlier than previous — must not panic/lose
        w.push(6.0, 3.0);
        assert_eq!(w.count(), 3);
    }

    #[test]
    fn empty_series_is_zeroish() {
        let w = WindowedSeries::new(1.0);
        assert_eq!(w.count(), 0);
        assert_eq!(w.sum(), 0.0);
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.percentile(99.0), 0.0);
        // Regression: `max` used to return -inf on an empty window,
        // which poisoned downstream reports and is unrepresentable in
        // JSON. All aggregates agree on 0.0 now.
        assert_eq!(w.max(), 0.0);
        assert_eq!(w.percentiles([50.0, 95.0, 99.0]), [0.0, 0.0, 0.0]);
        assert_eq!(w.last(), None);
    }

    #[test]
    fn batched_percentiles_match_single_queries_bitwise() {
        let mut w = WindowedSeries::new(100.0);
        let mut rng = crate::util::rng::Pcg64::seeded(13);
        for t in 0..500 {
            w.push(t as f64 * 0.1, rng.exponential(1.5));
        }
        let [p50, p95, p99] = w.percentiles([50.0, 95.0, 99.0]);
        assert_eq!(p50.to_bits(), w.percentile(50.0).to_bits());
        assert_eq!(p95.to_bits(), w.percentile(95.0).to_bits());
        assert_eq!(p99.to_bits(), w.percentile(99.0).to_bits());
    }

    #[test]
    fn service_windows_outage_rate() {
        let mut s = ServiceWindows::new(100.0);
        s.record_arrival(0.0);
        s.record_arrival(1.0);
        s.record_arrival(2.0);
        s.record_served(3.0, 1.5, 30.0, true);
        s.record_served(3.5, 2.0, 40.0, true);
        s.record_dropped(4.0, 450.0);
        assert_eq!(s.arrivals.count(), 3);
        assert!((s.outage_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert!((s.quality.mean() - (30.0 + 40.0 + 450.0) / 3.0).abs() < 1e-12);
        assert!((s.e2e_s.percentile(100.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn solve_overlap_fraction_tracks_hidden_over_total() {
        let mut s = ServiceWindows::new(100.0);
        assert_eq!(s.solve_overlap_fraction(), 0.0, "no solves yet");
        s.record_solve(1.0, 0.5, 0.5); // fully hidden
        s.record_solve(2.0, 0.5, 0.0); // fully exposed
        assert!((s.solve_overlap_fraction() - 0.5).abs() < 1e-12);
        s.record_solve(3.0, 1.0, 0.25);
        assert!((s.solve_overlap_fraction() - 0.75 / 2.0).abs() < 1e-12);
        // zero-latency solves contribute nothing and never divide by 0
        let mut z = ServiceWindows::new(100.0);
        z.record_solve(1.0, 0.0, 0.0);
        assert_eq!(z.solve_overlap_fraction(), 0.0);
    }

    #[test]
    fn two_epoch_pipelined_schedule_pins_overlap_fraction() {
        use crate::coordinator::{SolveMode, SolveTiming};
        // Hand-built two-epoch pipeline. Epoch 0 freezes at 1.0 on an
        // idle GPU (nothing to hide behind) and executes until 4.0;
        // epoch 1 freezes at 2.0, so its whole 0.5 s solve hides behind
        // epoch 0's batch.
        let e0 = SolveTiming::compute(1.0, 0.0, 0.5, SolveMode::Pipelined);
        assert_eq!(e0.hidden_s, 0.0);
        let gpu_free = 4.0; // epoch 0's batch ends here
        let e1 = SolveTiming::compute(2.0, gpu_free, 0.5, SolveMode::Pipelined);
        assert_eq!(e1.hidden_s, 0.5);
        assert_eq!(e1.batch_start_s, gpu_free, "fully hidden solve never delays the batch");
        let mut s = ServiceWindows::new(100.0);
        s.record_solve(e0.solve_end_s, 0.5, e0.hidden_s);
        s.record_solve(e1.solve_end_s, 0.5, e1.hidden_s);
        // 0.5 hidden out of 1.0 charged — pinned, not approximate.
        assert_eq!(s.solve_overlap_fraction(), 0.5);
        // Single-sample edge: only the hidden epoch in the window.
        let mut one = ServiceWindows::new(100.0);
        one.record_solve(e1.solve_end_s, 0.5, e1.hidden_s);
        assert_eq!(one.solve_overlap_fraction(), 1.0);
    }

    #[test]
    fn solve_overlap_is_windowed() {
        let mut s = ServiceWindows::new(10.0);
        s.record_solve(0.0, 1.0, 1.0);
        assert_eq!(s.solve_overlap_fraction(), 1.0);
        s.record_solve(50.0, 1.0, 0.0); // pushes the old sample out
        assert_eq!(s.solve_overlap_fraction(), 0.0, "stale hidden time must age out");
    }

    #[test]
    fn prune_on_read_drops_stale_samples() {
        let mut s = ServiceWindows::new(10.0);
        for t in 0..5 {
            s.record_arrival(t as f64);
        }
        assert_eq!(s.arrivals.count(), 5);
        // Reading much later without new pushes must not report the
        // old burst as current load.
        s.prune(100.0);
        assert_eq!(s.arrivals.count(), 0);
        assert_eq!(s.arrivals.rate_hz(), 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_window_rejected() {
        WindowedSeries::new(0.0);
    }
}
