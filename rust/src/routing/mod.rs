//! Request routing across a fleet of edge servers — the dispatch layer
//! of `sim::cluster`.
//!
//! The paper solves (P0) for a single server; serving heavy traffic
//! needs N servers and an answer to *which* server denoises each
//! request. Collaborative distributed diffusion (arXiv:2304.03446) and
//! 6G MEC offloading (arXiv:2312.06203) both find that this dispatch
//! decision dominates end-to-end quality under load, so the routing
//! policy is a first-class, pluggable component here:
//!
//! * [`RoundRobinRouter`] — classic cyclic dispatch, skipping failed
//!   servers (the fairness baseline);
//! * [`JoinShortestQueueRouter`] — route to the server with the least
//!   *outstanding denoising work* in seconds (not request count: a
//!   2×-slow GPU with 3 queued requests is "longer" than a fast GPU
//!   with 4);
//! * [`QualityAwareRouter`] — route to the server whose marginal (P0)
//!   relaxation predicts the most denoising steps (= best FID, since
//!   quality is monotone in steps) within the request's residual
//!   deadline, accounting for per-server GPU speed, estimated queue
//!   wait and a queue-shared transmission estimate;
//! * [`LiveStateRouter`] — route on the *true* per-server state (real
//!   queue depth + the exact instant the GPU frees) instead of the
//!   virtual-queue estimate. Du et al. (arXiv:2301.03220) motivate
//!   dispatching on live server state; `bench::fig_pipeline` measures
//!   the stale-vs-live gap.
//! * [`CacheAwareRouter`] — placement-aware dispatch for marked
//!   (Zipf-popular) workloads: prefer the server whose generation
//!   cache likely already holds the `(model, prompt)` key, then
//!   servers with the model resident, then the plain marginal-(P0)
//!   estimate; `bench::fig_cache` measures the affinity win.
//!
//! Routers see the fleet through [`ServerState`]s — lightweight virtual
//! queues the splitter advances between arrivals. The event engine
//! (`sim::event`) additionally publishes a [`LiveView`] per server at
//! every dispatch instant; outside it the live view is absent and the
//! live router falls back to the virtual estimate. Every policy is
//! deterministic: identical traces and fleet configs replay to
//! bit-identical assignments (asserted by `tests/routing_properties.rs`).
//!
//! # Indexed dispatch and the bound-and-prune contract
//!
//! Every policy also implements [`Router::route_indexed`] against a
//! [`FleetIndex`] (an ordered index over the same virtual queues, kept
//! current by `route_trace` and `sim::event` at every state-mutation
//! site), with one hard contract: **the indexed decision is the same
//! server the O(N) scan would pick, on every fleet, every time** —
//! not approximately, bit-for-bit (`tests/routing_index.rs` is the
//! forall suite; `benches/fig_fleet.rs` gates it at fleet sizes up to
//! 4096). The scan paths stay as the executable specification.
//!
//! How each policy meets it:
//!
//! * JSQ / live-state: the index splits idle from busy. Any idle
//!   server holds the global minimum (exactly `+0.0` outstanding), so
//!   the lowest-id idle entry wins outright; otherwise the busy side
//!   is walked in `(busy_until, id)` order — which is the outstanding-
//!   work order only *non-strictly* (distinct `busy_until` values can
//!   round to equal outstanding work), so the walk covers the whole
//!   equal-minimum prefix with the scan's exact comparator before
//!   stopping. O(log N + ties) amortized.
//! * Quality-aware (and the cache-aware fallback): bound-and-prune.
//!   Candidates are visited in ascending outstanding-work order, the
//!   exact tie-break order of the scan, so the first candidate
//!   reaching a score is the scan winner among equals and a candidate
//!   is only skipped when an *admissible* upper bound on its score —
//!   `predict_steps` with the transmission term dropped and the
//!   fleet-minimum scaled step cost `min_s g(1)/speed` in the
//!   denominator, both of which only overestimate through monotone
//!   float ops — cannot beat the incumbent strictly. Idle servers
//!   (exactly zero wait, empty queue) score as a monotone function of
//!   speed alone, so their winner falls to an O(log N) binary search
//!   over the index's static speed ladder.
//! * Cache-aware: the hit/residency pools come from inverted
//!   mark→servers and model→servers indexes maintained on every
//!   shadow insert/evict, replacing the per-route O(N) `contains`
//!   scan; pool scoring reuses a scratch buffer, so the route hot
//!   path allocates nothing (`tests/hotpath_alloc.rs`).

use std::collections::VecDeque;

use std::collections::HashMap;

use crate::cache::{CacheSettings, ServerCache};
use crate::delay::BatchDelayModel;
use crate::trace::{Arrival, ArrivalTrace, PromptMark};

pub mod index;

pub use index::{FleetIndex, IndexStats};

/// Which routing policy a cluster runs. Lives here (not in `config`) so
/// the policy set and its names stay next to the implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    /// Join-shortest-queue by outstanding denoising work.
    JoinShortestQueue,
    /// Marginal-(P0) quality prediction.
    QualityAware,
    /// Dispatch on the true per-server queue depth and `gpu_free`
    /// published by the event engine ([`LiveView`]); degenerates to
    /// the virtual-queue JSQ estimate where no live view exists.
    LiveState,
    /// Cache-affinity dispatch: shadow generation caches predict which
    /// server already holds the arrival's `(model, prompt)` key.
    CacheAware,
}

impl RouterKind {
    /// Parse the CLI/TOML name. Accepts the short aliases the README
    /// documents; the error lists the valid names.
    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "round-robin" | "rr" => Ok(Self::RoundRobin),
            "jsq" | "shortest-queue" => Ok(Self::JoinShortestQueue),
            "quality" | "quality-aware" => Ok(Self::QualityAware),
            "live" | "live-state" => Ok(Self::LiveState),
            "cache" | "cache-aware" => Ok(Self::CacheAware),
            other => anyhow::bail!(
                "unknown router '{other}' (valid: round-robin|rr, jsq|shortest-queue, quality|quality-aware, live|live-state, cache|cache-aware)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::JoinShortestQueue => "jsq",
            Self::QualityAware => "quality-aware",
            Self::LiveState => "live",
            Self::CacheAware => "cache-aware",
        }
    }

    /// The virtual-view policies, in the order the figure sweeps
    /// compare them. [`Self::LiveState`] is deliberately excluded:
    /// these three behave bit-identically across every engine (the
    /// equivalence suites iterate this set), whereas the live router
    /// reads event-engine state that the sequential cluster cannot
    /// provide. Use [`Self::with_live`] to sweep all four.
    /// [`Self::CacheAware`] is excluded from both sets — on unmarked
    /// traces it matches [`Self::QualityAware`] decision-for-decision,
    /// and it only means something with `[cache]` settings attached
    /// (`bench::fig_cache` sweeps it explicitly).
    pub fn all() -> [Self; 3] {
        [Self::RoundRobin, Self::JoinShortestQueue, Self::QualityAware]
    }

    /// Every policy including the live-state router.
    pub fn with_live() -> [Self; 4] {
        [Self::RoundRobin, Self::JoinShortestQueue, Self::QualityAware, Self::LiveState]
    }

    /// Instantiate the policy with default (disabled) cache settings.
    /// The delay model parameterizes the quality-aware marginal
    /// estimate and the live router's per-step cost (and the shared
    /// per-request service estimate all policies charge to a server's
    /// virtual queue).
    pub fn build(&self, delay: BatchDelayModel) -> Box<dyn Router> {
        self.build_with_cache(delay, CacheSettings::default())
    }

    /// Instantiate the policy with the cluster's `[cache]` settings.
    /// Only the cache-aware router reads them (its shadow caches
    /// mirror the engine caches' capacity/eviction/seed); every other
    /// policy ignores the parameter, so for them this is exactly
    /// [`Self::build`].
    pub fn build_with_cache(
        &self,
        delay: BatchDelayModel,
        cache: CacheSettings,
    ) -> Box<dyn Router> {
        match self {
            Self::RoundRobin => Box::new(RoundRobinRouter::default()),
            Self::JoinShortestQueue => Box::new(JoinShortestQueueRouter),
            Self::QualityAware => Box::new(QualityAwareRouter::new(delay)),
            Self::LiveState => Box::new(LiveStateRouter::new(delay)),
            Self::CacheAware => Box::new(CacheAwareRouter::new(delay, cache)),
        }
    }
}

/// The true, engine-observed state of one server at a dispatch
/// instant — what the event engine knows and the virtual queue only
/// estimates. Published by `sim::event` before every routing decision;
/// absent everywhere else.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LiveView {
    /// Requests actually waiting on the server (open/frozen epoch
    /// queue plus backlog), excluding the batch already on the GPU.
    pub queue_depth: usize,
    /// Exact instant the GPU frees from the batch it is executing.
    pub gpu_free_s: f64,
}

/// One server as the router sees it: a deterministic virtual queue.
///
/// The estimator is deliberately simple — a single-server FIFO drain:
/// routing a request at time `t` with service estimate `s` pushes
/// `busy_until = max(busy_until, t) + s`; outstanding work at `t` is
/// `max(0, busy_until − t)`. It is *not* the exact simulator state (the
/// simulator batches and re-solves per epoch) but it is consistent,
/// causal, and cheap — the standard virtual-queue trick load balancers
/// use when the backend's true state is unobservable at dispatch time.
#[derive(Debug, Clone)]
pub struct ServerState {
    pub id: usize,
    /// GPU speed factor relative to the reference delay model
    /// (2.0 = denoises twice as fast).
    pub speed: f64,
    /// A failed server must never be routed to.
    pub alive: bool,
    /// Total requests ever routed here.
    pub routed: usize,
    /// The engine-published true state at the current dispatch instant
    /// (`sim::event` refreshes this before every routing decision;
    /// `None` outside the event engine). Virtual-view policies ignore
    /// it, so publishing it never perturbs their decisions.
    pub live: Option<LiveView>,
    busy_until_s: f64,
    /// Estimated completion instant of each in-flight request, FIFO.
    pending: VecDeque<f64>,
}

impl ServerState {
    pub fn new(id: usize, speed: f64) -> Self {
        assert!(speed > 0.0 && speed.is_finite(), "server speed must be positive");
        Self {
            id,
            speed,
            alive: true,
            routed: 0,
            live: None,
            busy_until_s: 0.0,
            pending: VecDeque::new(),
        }
    }

    /// Build a fleet from per-server speed factors.
    pub fn fleet(speeds: &[f64]) -> Vec<Self> {
        speeds.iter().enumerate().map(|(i, &s)| Self::new(i, s)).collect()
    }

    /// Drop requests whose estimated completion has passed.
    pub fn advance(&mut self, now_s: f64) {
        while matches!(self.pending.front(), Some(&done) if done <= now_s) {
            self.pending.pop_front();
        }
    }

    /// Estimated outstanding denoising work at `now_s`, in seconds.
    pub fn outstanding_work_s(&self, now_s: f64) -> f64 {
        (self.busy_until_s - now_s).max(0.0)
    }

    /// Requests estimated still queued or running at the last `advance`.
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }

    /// Requests estimated still queued or running at `now_s`, without
    /// mutating the queue — exactly what [`Self::queue_len`] would
    /// return right after `advance(now_s)`. `pending` is sorted
    /// ascending (each `assign` pushes a strictly larger completion
    /// instant), so the drained prefix is a partition point. Lets the
    /// route hot path skip the per-arrival advance-every-server loop.
    pub fn queue_len_at(&self, now_s: f64) -> usize {
        self.pending.len() - self.pending.partition_point(|&done| done <= now_s)
    }

    /// Bit pattern of the virtual-queue drain instant — the
    /// [`FleetIndex`] key (non-negative, so bit order = float order).
    fn busy_until_bits(&self) -> u64 {
        self.busy_until_s.to_bits()
    }

    /// Charge a routed request to the virtual queue.
    pub fn assign(&mut self, now_s: f64, service_est_s: f64) {
        self.busy_until_s = self.busy_until_s.max(now_s) + service_est_s;
        self.pending.push_back(self.busy_until_s);
        self.routed += 1;
    }
}

/// Shared scenario constants a routing decision may consult.
#[derive(Debug, Clone, Copy)]
pub struct RouteContext {
    pub total_bandwidth_hz: f64,
    pub content_bits: f64,
}

/// A routing policy: pick the destination server for one arrival.
///
/// Contract (asserted by `tests/routing_properties.rs`):
/// * the returned index is a server with `alive == true`;
/// * the decision is a pure function of the visible state — identical
///   replays produce identical assignments;
/// * implementations may keep internal state (e.g. the round-robin
///   cursor), hence `&mut self`.
pub trait Router {
    fn name(&self) -> &'static str;

    /// Choose a server for `arrival` at its arrival instant. `servers`
    /// have been advanced to `arrival.t_s`. Panics if no server is
    /// alive — the cluster layer guarantees at least one.
    fn route(&mut self, arrival: &Arrival, servers: &[ServerState], ctx: &RouteContext) -> usize;

    /// Choose a server for a *resumed* partial request carrying
    /// `done_steps` already-completed denoising steps (checkpoint
    /// migration). The default ignores the credit and delegates to
    /// [`Router::route`] — with `done_steps == 0` every policy must
    /// behave exactly like a fresh dispatch, so zero-fault runs stay
    /// bit-identical. Policies that score quality (the marginal-(P0)
    /// router) override this to credit the finished steps.
    fn route_resume(
        &mut self,
        arrival: &Arrival,
        done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
    ) -> usize {
        let _ = done_steps;
        self.route(arrival, servers, ctx)
    }

    /// [`Router::route`] answered through a [`FleetIndex`] kept current
    /// by the caller. Contract: returns **exactly** the server
    /// [`Router::route`] would return on the same state (the module
    /// docs spell out how each policy guarantees it). The default
    /// ignores the index and runs the scan, so external policies stay
    /// correct without opting in.
    fn route_indexed(
        &mut self,
        arrival: &Arrival,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        let _ = index;
        self.route(arrival, servers, ctx)
    }

    /// [`Router::route_resume`] answered through a [`FleetIndex`];
    /// same decision-identity contract as [`Router::route_indexed`].
    fn route_resume_indexed(
        &mut self,
        arrival: &Arrival,
        done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        let _ = index;
        self.route_resume(arrival, done_steps, servers, ctx)
    }
}

fn assert_some_alive(servers: &[ServerState]) {
    assert!(servers.iter().any(|s| s.alive), "routing with every server failed");
}

/// Exact `(outstanding_work_s, id)` argmin through the index — the
/// JSQ scan decision, bit for bit. Any idle server wins outright
/// (outstanding exactly `+0.0`, lowest id first). Among busy servers
/// the index orders by `busy_until`, which orders outstanding work
/// only *non-strictly* (distinct `busy_until` can round to equal
/// outstanding work), so the equal-minimum prefix is scanned for the
/// lowest id instead of taking the head entry on faith — O(log N +
/// |prefix|), and the prefix is length 1 outside rounding collisions.
fn indexed_jsq_argmin(now: f64, index: &mut FleetIndex) -> Option<usize> {
    index.settle(now);
    index.stats.queries += 1;
    if let Some(id) = index.first_idle() {
        index.stats.examined += 1;
        return Some(id);
    }
    let mut examined: u64 = 0;
    let mut best: Option<(f64, usize)> = None;
    for (busy_until, id) in index.busy_entries() {
        let out = (busy_until - now).max(0.0);
        match best {
            Some((best_out, best_id)) => {
                // `out` is non-decreasing along the iteration; past
                // the equal-minimum prefix nothing can win.
                if out > best_out {
                    break;
                }
                examined += 1;
                if id < best_id {
                    best = Some((out, id));
                }
            }
            None => {
                examined += 1;
                best = Some((out, id));
            }
        }
    }
    index.stats.examined += examined;
    best.map(|(_, id)| id)
}

/// Cyclic dispatch over alive servers.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobinRouter {
    cursor: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _arrival: &Arrival, servers: &[ServerState], _ctx: &RouteContext) -> usize {
        assert_some_alive(servers);
        let n = servers.len();
        for probe in 0..n {
            let idx = (self.cursor + probe) % n;
            if servers[idx].alive {
                self.cursor = (idx + 1) % n;
                return idx;
            }
        }
        unreachable!("assert_some_alive guarantees an alive server");
    }
}

/// Route to the alive server with the least outstanding denoising work
/// (seconds, so slow GPUs count for what their queue actually costs).
/// Ties break toward the lowest id for determinism.
#[derive(Debug, Clone, Copy, Default)]
pub struct JoinShortestQueueRouter;

impl Router for JoinShortestQueueRouter {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(&mut self, arrival: &Arrival, servers: &[ServerState], _ctx: &RouteContext) -> usize {
        assert_some_alive(servers);
        let now = arrival.t_s;
        servers
            .iter()
            .filter(|s| s.alive)
            .min_by(|a, b| {
                a.outstanding_work_s(now)
                    .total_cmp(&b.outstanding_work_s(now))
                    .then(a.id.cmp(&b.id))
            })
            .unwrap()
            .id
    }

    /// O(log N + |equal-minimum prefix|) via [`indexed_jsq_argmin`]:
    /// any idle server (outstanding exactly `+0.0`) wins outright;
    /// otherwise the equal-outstanding busy prefix is scanned for the
    /// lowest id, reproducing the scan decision bit for bit even when
    /// distinct `busy_until` values round to equal outstanding work.
    fn route_indexed(
        &mut self,
        arrival: &Arrival,
        _servers: &[ServerState],
        _ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        indexed_jsq_argmin(arrival.t_s, index).expect("routing with every server failed")
    }

    fn route_resume_indexed(
        &mut self,
        arrival: &Arrival,
        _done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        self.route_indexed(arrival, servers, ctx, index)
    }
}

/// Marginal-(P0) routing: predict, per server, how many denoising steps
/// the request could receive within its residual deadline, and route to
/// the best prediction.
///
/// The prediction is the single-request relaxation of (P0): after an
/// estimated queue wait of `outstanding_work_s` and a transmission of
/// `S / (η · B / (q+1))` (the band shared with the `q` requests already
/// queued there), the remaining budget buys
/// `floor(budget / g_s(1))` singleton denoising steps on a GPU whose
/// scaled delay is `g_s(X) = g(X) / speed`. Batching amortization makes
/// the simulator's real outcome strictly better, so the estimate is a
/// conservative, monotone proxy — and since FID is monotone decreasing
/// in steps, maximizing predicted steps maximizes predicted admitted
/// quality. Ties break toward less outstanding work, then lower id.
#[derive(Debug, Clone)]
pub struct QualityAwareRouter {
    delay: BatchDelayModel,
    /// Cap on the step prediction (matches the schedulers' default
    /// `max_steps`; past it extra steps buy ~no quality).
    pub max_steps: u32,
    /// Fleet-wide minimum scaled singleton step cost `min_s g(1)/speed`
    /// — the admissible denominator of the bound-and-prune upper
    /// bound. Computed once from the first indexed fleet (speeds are
    /// static for a router's lifetime).
    g1_floor: Option<f64>,
}

impl QualityAwareRouter {
    pub fn new(delay: BatchDelayModel) -> Self {
        Self { delay, max_steps: 1000, g1_floor: None }
    }

    /// Predicted denoising steps for `arrival` on `server` (0 means a
    /// predicted outage).
    pub fn predict_steps(
        &self,
        arrival: &Arrival,
        server: &ServerState,
        ctx: &RouteContext,
    ) -> u32 {
        let now = arrival.t_s;
        let wait = server.outstanding_work_s(now);
        let share = ctx.total_bandwidth_hz / (server.queue_len_at(now) + 1) as f64;
        let tx = arrival.link.tx_delay(ctx.content_bits, share);
        let budget = arrival.deadline_s - wait - tx;
        let scaled = BatchDelayModel::new(self.delay.a / server.speed, self.delay.b / server.speed);
        if budget < scaled.g(1) {
            return 0;
        }
        // Singleton steps: T · g_s(1) ≤ budget.
        ((budget / scaled.g(1)).floor() as u32).min(self.max_steps)
    }

    /// [`Self::predict_steps`] specialised to a settled server: zero
    /// outstanding work and an empty virtual queue, so the prediction
    /// depends on the GPU speed alone — and is monotone non-decreasing
    /// in it (every op below is monotone under IEEE rounding). Mirrors
    /// `predict_steps` operation for operation so the result is
    /// bit-identical to scoring an actual idle server of this speed.
    fn idle_steps(&self, arrival: &Arrival, speed: f64, ctx: &RouteContext) -> u32 {
        let share = ctx.total_bandwidth_hz / (0 + 1) as f64;
        let tx = arrival.link.tx_delay(ctx.content_bits, share);
        let budget = arrival.deadline_s - 0.0 - tx;
        let scaled = BatchDelayModel::new(self.delay.a / speed, self.delay.b / speed);
        if budget < scaled.g(1) {
            return 0;
        }
        ((budget / scaled.g(1)).floor() as u32).min(self.max_steps)
    }

    /// The cached fleet-wide minimum of the scaled singleton step cost.
    /// Taken over *all* servers (dead included), so it lower-bounds
    /// every alive candidate's denominator — admissible under faults.
    fn fleet_g1_floor(&mut self, servers: &[ServerState]) -> f64 {
        match self.g1_floor {
            Some(v) => v,
            None => {
                let v = servers
                    .iter()
                    .map(|s| {
                        BatchDelayModel::new(self.delay.a / s.speed, self.delay.b / s.speed).g(1)
                    })
                    .fold(f64::INFINITY, f64::min);
                self.g1_floor = Some(v);
                v
            }
        }
    }

    /// Bound-and-prune argmax of `(score, −outstanding, −id)`, where
    /// `score = min(predict_steps + done, max_steps)` — the exact scan
    /// comparator of [`Self::route`] / [`Self::route_resume`].
    ///
    /// Idle servers first: all tie at zero wait, so the scan winner
    /// among them is the lowest id inside the top-score speed class —
    /// found by binary search over the index's static speed ladder
    /// (scores are monotone in speed) plus a min-id range query.
    /// Then busy servers in ascending `(busy_until, id)` index order.
    /// That orders `wait` only *non-strictly* (distinct `busy_until`
    /// can round to equal waits), so the incumbent is tracked as the
    /// full scan key `(score, wait, id)` and a candidate replaces it
    /// exactly when the scan comparator says so: higher score, or
    /// equal score and smaller wait, or both equal and lower id.
    /// The loop stops once the admissible upper bound
    /// `min(⌊(deadline − wait)/g1_floor⌋ + done, max_steps)`
    /// (transmission dropped, fastest-GPU step cost) strictly loses to
    /// the incumbent — `ub < best_score`, or `ub == best_score` with
    /// `wait > best_wait`: `wait` is non-decreasing along the
    /// iteration, so every later candidate loses the same comparison.
    fn indexed_argmax(
        &mut self,
        arrival: &Arrival,
        done: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        let now = arrival.t_s;
        index.settle(now);
        index.stats.queries += 1;
        let g1_floor = self.fleet_g1_floor(servers);
        let max_steps = self.max_steps;
        let score = |steps: u32| (steps + done).min(max_steps);
        let mut examined: u64 = 0;
        // Incumbent as the scan's full argmax key: (score, wait, id).
        let mut best: Option<(u32, f64, usize)> = None;
        if let Some(top_pos) = index.last_idle_pos() {
            let top = score(self.idle_steps(arrival, index.speed_at(top_pos), ctx));
            examined += 1;
            // Least ladder position whose (static) speed reaches the
            // top score; every idle position at or above it scores
            // exactly `top`, every one below scores strictly less.
            let (mut lo, mut hi) = (0usize, top_pos);
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                examined += 1;
                if score(self.idle_steps(arrival, index.speed_at(mid), ctx)) < top {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            let id = index.min_idle_id_from(lo).expect("idle class is non-empty");
            best = Some((top, 0.0, id));
        }
        for (busy_until, id) in index.busy_entries() {
            let wait = (busy_until - now).max(0.0);
            if let Some((best_score, best_wait, _)) = best {
                let head = (arrival.deadline_s - wait) / g1_floor;
                let ub_steps =
                    if head >= max_steps as f64 { max_steps } else { head.floor() as u32 };
                let ub = score(ub_steps);
                if ub < best_score || (ub == best_score && wait > best_wait) {
                    break;
                }
            }
            examined += 1;
            let s = score(self.predict_steps(arrival, &servers[id], ctx));
            let better = match best {
                None => true,
                Some((best_score, best_wait, best_id)) => s
                    .cmp(&best_score)
                    .then(best_wait.total_cmp(&wait))
                    .then(best_id.cmp(&id))
                    .is_gt(),
            };
            if better {
                best = Some((s, wait, id));
            }
        }
        index.stats.examined += examined;
        best.expect("routing with every server failed").2
    }
}

impl Router for QualityAwareRouter {
    fn name(&self) -> &'static str {
        "quality-aware"
    }

    fn route(&mut self, arrival: &Arrival, servers: &[ServerState], ctx: &RouteContext) -> usize {
        assert_some_alive(servers);
        let now = arrival.t_s;
        servers
            .iter()
            .filter(|s| s.alive)
            .max_by(|a, b| {
                let sa = self.predict_steps(arrival, a, ctx);
                let sb = self.predict_steps(arrival, b, ctx);
                sa.cmp(&sb)
                    // more steps wins; on equal steps prefer the *less*
                    // loaded server, then the lower id (max_by keeps the
                    // later element on Equal, so order comparisons to
                    // favour `a` strictly).
                    .then_with(|| {
                        b.outstanding_work_s(now).total_cmp(&a.outstanding_work_s(now))
                    })
                    .then(b.id.cmp(&a.id))
            })
            .unwrap()
            .id
    }

    fn route_indexed(
        &mut self,
        arrival: &Arrival,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        // `score` with done = 0 is `predict_steps` itself (already
        // capped at `max_steps`), so this is exactly the fresh scan.
        self.indexed_argmax(arrival, 0, servers, ctx, index)
    }

    /// Resume-aware marginal-(P0) dispatch: the request already owns
    /// `done_steps` of denoising, so each server is scored by the
    /// *total* steps `min(done + predicted, max_steps)` it would end
    /// with. Past the quality cap extra predicted steps buy nothing, so
    /// a nearly-finished request prefers the less-loaded server over
    /// the fastest one. With `done_steps == 0` the score reduces to
    /// `predict_steps` (already capped) — identical to [`Self::route`].
    fn route_resume(
        &mut self,
        arrival: &Arrival,
        done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
    ) -> usize {
        assert_some_alive(servers);
        let now = arrival.t_s;
        servers
            .iter()
            .filter(|s| s.alive)
            .max_by(|a, b| {
                let sa = (self.predict_steps(arrival, a, ctx) + done_steps).min(self.max_steps);
                let sb = (self.predict_steps(arrival, b, ctx) + done_steps).min(self.max_steps);
                sa.cmp(&sb)
                    .then_with(|| {
                        b.outstanding_work_s(now).total_cmp(&a.outstanding_work_s(now))
                    })
                    .then(b.id.cmp(&a.id))
            })
            .unwrap()
            .id
    }

    fn route_resume_indexed(
        &mut self,
        arrival: &Arrival,
        done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        self.indexed_argmax(arrival, done_steps, servers, ctx, index)
    }
}

/// Route on the *true* per-server state at dispatch time: the exact
/// residual GPU busy time plus a per-queued-request singleton-step
/// estimate on the server's scaled delay model.
///
/// The virtual-queue routers charge a fixed `g(1)/speed` per routed
/// request and drain it on a FIFO clock — causal, but stale: a slow
/// server whose epochs defer work looks emptier than it is. The live
/// router reads the engine's [`LiveView`] (real queue depth, real
/// `gpu_free`) instead, so pile-ups are visible the moment they form.
/// Where no live view is published (the sequential cluster's
/// `route_trace`), it falls back to the virtual outstanding-work
/// estimate — i.e. it degenerates to [`JoinShortestQueueRouter`].
/// Ties break toward the lowest id for determinism.
/// The queue term of [`LiveStateRouter::backlog_s`]: one scaled
/// singleton step per actually-queued request. A free function so the
/// event engine keys the [`FleetIndex`] live half with the *same*
/// expression the router scores with — bit-identical by construction,
/// not by parallel maintenance.
pub fn live_queue_cost_s(delay: &BatchDelayModel, queue_depth: usize, speed: f64) -> f64 {
    queue_depth as f64 * delay.g(1) / speed
}

#[derive(Debug, Clone)]
pub struct LiveStateRouter {
    delay: BatchDelayModel,
}

impl LiveStateRouter {
    pub fn new(delay: BatchDelayModel) -> Self {
        Self { delay }
    }

    /// Estimated time until `server` could start denoising one more
    /// request at `now_s`: true residual GPU busy time plus one
    /// singleton step per actually-queued request.
    pub fn backlog_s(&self, server: &ServerState, now_s: f64) -> f64 {
        match server.live {
            Some(view) => {
                let busy = (view.gpu_free_s - now_s).max(0.0);
                busy + live_queue_cost_s(&self.delay, view.queue_depth, server.speed)
            }
            None => server.outstanding_work_s(now_s),
        }
    }
}

impl Router for LiveStateRouter {
    fn name(&self) -> &'static str {
        "live"
    }

    fn route(&mut self, arrival: &Arrival, servers: &[ServerState], _ctx: &RouteContext) -> usize {
        assert_some_alive(servers);
        let now = arrival.t_s;
        servers
            .iter()
            .filter(|s| s.alive)
            .min_by(|a, b| {
                self.backlog_s(a, now).total_cmp(&self.backlog_s(b, now)).then(a.id.cmp(&b.id))
            })
            .unwrap()
            .id
    }

    /// Backlog argmin through the index's live half. Settled-GPU
    /// servers are keyed by their queue cost — exactly their backlog
    /// (the busy term is a hard `+0.0`) — so the first entry is their
    /// winner; busy-GPU servers are visited in ascending `gpu_free`
    /// order, whose `(gpu_free − now).max(0.0)` lower-bounds their
    /// backlog (the queue cost only adds on, through a monotone
    /// rounding), so iteration stops once that bound alone exceeds
    /// the incumbent. Exact backlogs come from [`Self::backlog_s`] on
    /// the published views, i.e. the scan's own numbers. Without a
    /// published live half (no event engine) every view is `None` and
    /// the scan degenerates to the virtual JSQ argmin —
    /// [`indexed_jsq_argmin`] on the work half of the index.
    fn route_indexed(
        &mut self,
        arrival: &Arrival,
        servers: &[ServerState],
        _ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        let now = arrival.t_s;
        if !index.live_active() {
            debug_assert!(
                servers.iter().all(|s| s.live.is_none()),
                "live views published without FleetIndex::publish_live"
            );
            return indexed_jsq_argmin(now, index).expect("routing with every server failed");
        }
        index.stats.queries += 1;
        index.settle_live(now);
        let mut examined: u64 = 0;
        let mut best: Option<(f64, usize)> = index.live_idle_first();
        if best.is_some() {
            examined += 1;
        }
        for (gpu_free, id) in index.live_busy_entries() {
            if let Some((incumbent, _)) = best {
                if (gpu_free - now).max(0.0) > incumbent {
                    break;
                }
            }
            examined += 1;
            let backlog = self.backlog_s(&servers[id], now);
            let better = match best {
                None => true,
                Some((incumbent, incumbent_id)) => {
                    backlog.total_cmp(&incumbent).then(id.cmp(&incumbent_id)).is_lt()
                }
            };
            if better {
                best = Some((backlog, id));
            }
        }
        index.stats.examined += examined;
        best.expect("routing with every server failed").1
    }

    fn route_resume_indexed(
        &mut self,
        arrival: &Arrival,
        _done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        // The scan's `route_resume` default ignores the credit and
        // delegates to `route`; mirror that exactly.
        self.route_indexed(arrival, servers, ctx, index)
    }
}

/// Placement-aware dispatch for marked (cached) workloads: prefer the
/// server whose generation cache most likely already holds the
/// arrival's `(model, prompt)` key, then servers where the model is at
/// least resident (no swap delay), and only then the plain
/// marginal-(P0) estimate over the whole fleet.
///
/// The router cannot see the engines' real caches at dispatch time (the
/// same observability gap [`LiveStateRouter`] closes for queues), so it
/// maintains *shadow* per-server caches fed by its own decisions:
/// routing a marked request to server `s` inserts the key into `s`'s
/// shadow — mirroring what the engine's cache does when the request is
/// served — using the same capacity/eviction/seed as the engine caches
/// so the prediction tracks the real contents on stable assignments.
/// Unmarked arrivals delegate to [`QualityAwareRouter`] untouched, so
/// on a trace without prompt marks this router is decision-for-decision
/// identical to quality-aware. Deterministic: shadow state is a pure
/// function of the routing history.
#[derive(Debug, Clone)]
pub struct CacheAwareRouter {
    inner: QualityAwareRouter,
    settings: CacheSettings,
    shadow: Vec<ServerCache>,
    /// Inverted index: which servers' shadow caches hold each key.
    /// Maintained on every shadow insert/evict ([`Self::note_dispatch`])
    /// so membership always equals `shadow[i].cache.contains(mark)` —
    /// the hit pool without the O(N) contains scan. Owner lists stay
    /// sorted ascending, matching the scan's candidate order.
    mark_owners: HashMap<PromptMark, Vec<usize>>,
    /// Inverted index: which servers' shadow catalogs hold each model
    /// resident — the residency pool without the O(N) scan.
    model_owners: HashMap<u32, Vec<usize>>,
    /// Reusable candidate buffer: the route hot path allocates nothing
    /// once warm (`tests/hotpath_alloc.rs`).
    scratch: Vec<usize>,
}

/// Insert into / remove from a sorted owner list (owner lists are tiny
/// — bounded by the fleet servers actually holding the key).
fn add_owner(list: &mut Vec<usize>, id: usize) {
    if let Err(pos) = list.binary_search(&id) {
        list.insert(pos, id);
    }
}

fn remove_owner(list: &mut Vec<usize>, id: usize) {
    if let Ok(pos) = list.binary_search(&id) {
        list.remove(pos);
    }
}

impl CacheAwareRouter {
    pub fn new(delay: BatchDelayModel, settings: CacheSettings) -> Self {
        Self {
            inner: QualityAwareRouter::new(delay),
            settings,
            shadow: Vec::new(),
            mark_owners: HashMap::new(),
            model_owners: HashMap::new(),
            scratch: Vec::new(),
        }
    }

    /// Lazily size the shadow fleet to the routed fleet (the router
    /// learns the server count from its first dispatch). Boot-resident
    /// models enter the inverted model index here.
    fn sync_fleet(&mut self, n: usize) {
        while self.shadow.len() < n {
            let id = self.shadow.len();
            let cache = ServerCache::new(&self.settings);
            for &model in cache.catalog.resident_models() {
                add_owner(self.model_owners.entry(model).or_default(), id);
            }
            self.shadow.push(cache);
        }
    }

    /// Mirror what the engine-side cache will do for the routed
    /// request — shared by the scan and indexed paths so both evolve
    /// the shadow state (and the inverted indexes over it)
    /// identically: a hit refreshes the entry's second-chance bit; a
    /// miss loads the model and inserts the generated result,
    /// reporting any eviction back into the owner lists.
    fn note_dispatch(
        &mut self,
        arrival: &Arrival,
        servers: &[ServerState],
        ctx: &RouteContext,
        choice: usize,
    ) {
        let mark = arrival.mark;
        let predicted = self.inner.predict_steps(arrival, &servers[choice], ctx).max(1);
        let shadow = &mut self.shadow[choice];
        if shadow.lookup(mark).is_some() {
            return;
        }
        let (_, evicted_model) = shadow.ensure_resident_reporting(mark.model);
        if let Some(evicted) = shadow.insert(mark, predicted) {
            remove_owner(self.mark_owners.entry(evicted).or_default(), choice);
        }
        if let Some(model) = evicted_model {
            remove_owner(self.model_owners.entry(model).or_default(), choice);
        }
        add_owner(self.model_owners.entry(mark.model).or_default(), choice);
        add_owner(self.mark_owners.entry(mark).or_default(), choice);
    }

    /// Marginal-(P0) argmax restricted to the candidate subset `ids`
    /// (all alive, ascending) — the [`QualityAwareRouter`] comparator
    /// over a pool.
    fn best_among(
        &self,
        arrival: &Arrival,
        servers: &[ServerState],
        ctx: &RouteContext,
        ids: &[usize],
    ) -> usize {
        let now = arrival.t_s;
        *ids.iter()
            .max_by(|&&a, &&b| {
                let (a, b) = (&servers[a], &servers[b]);
                let sa = self.inner.predict_steps(arrival, a, ctx);
                let sb = self.inner.predict_steps(arrival, b, ctx);
                sa.cmp(&sb)
                    .then_with(|| {
                        b.outstanding_work_s(now).total_cmp(&a.outstanding_work_s(now))
                    })
                    .then(b.id.cmp(&a.id))
            })
            .expect("best_among needs a non-empty candidate pool")
    }
}

impl Router for CacheAwareRouter {
    fn name(&self) -> &'static str {
        "cache-aware"
    }

    fn route(&mut self, arrival: &Arrival, servers: &[ServerState], ctx: &RouteContext) -> usize {
        assert_some_alive(servers);
        if arrival.mark.is_zero() {
            return self.inner.route(arrival, servers, ctx);
        }
        self.sync_fleet(servers.len());
        let mark = arrival.mark;
        let alive: Vec<usize> = servers.iter().filter(|s| s.alive).map(|s| s.id).collect();
        let hits: Vec<usize> =
            alive.iter().copied().filter(|&i| self.shadow[i].cache.contains(mark)).collect();
        let resident: Vec<usize> = alive
            .iter()
            .copied()
            .filter(|&i| self.shadow[i].catalog.is_resident(mark.model))
            .collect();
        // A predicted hit bypasses the epoch batch entirely in the
        // engines (transmission only), so hit affinity outranks load;
        // a resident model at least avoids the swap delay.
        let pool = if !hits.is_empty() {
            &hits
        } else if !resident.is_empty() {
            &resident
        } else {
            &alive
        };
        let choice = self.best_among(arrival, servers, ctx, pool);
        self.note_dispatch(arrival, servers, ctx, choice);
        choice
    }

    /// Resumes delegate to the quality-aware scorer: a checkpointed
    /// partial generation cannot be served from cache (its identity is
    /// the in-flight denoising state, not the prompt), so cache
    /// affinity does not apply and the done-step credit dominates.
    fn route_resume(
        &mut self,
        arrival: &Arrival,
        done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
    ) -> usize {
        self.inner.route_resume(arrival, done_steps, servers, ctx)
    }

    /// The scan's hit/residency pools, rebuilt from the inverted
    /// owner indexes instead of an O(N) shadow scan: owner lists are
    /// sorted ascending and membership equals the contains/is_resident
    /// predicates exactly (every shadow mutation goes through
    /// [`Self::note_dispatch`]), so filtering them by liveness yields
    /// the scan's candidate vectors element for element — into a
    /// reused scratch buffer. Empty pools fall through to the
    /// quality-aware bound-and-prune over the whole alive fleet.
    fn route_indexed(
        &mut self,
        arrival: &Arrival,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        if arrival.mark.is_zero() {
            return self.inner.route_indexed(arrival, servers, ctx, index);
        }
        self.sync_fleet(servers.len());
        let mark = arrival.mark;
        let mut pool = std::mem::take(&mut self.scratch);
        pool.clear();
        if let Some(owners) = self.mark_owners.get(&mark) {
            pool.extend(owners.iter().copied().filter(|&i| servers[i].alive));
        }
        if pool.is_empty() {
            if let Some(owners) = self.model_owners.get(&mark.model) {
                pool.extend(owners.iter().copied().filter(|&i| servers[i].alive));
            }
        }
        let choice = if pool.is_empty() {
            self.inner.route_indexed(arrival, servers, ctx, index)
        } else {
            index.stats.queries += 1;
            index.stats.examined += pool.len() as u64;
            self.best_among(arrival, servers, ctx, &pool)
        };
        self.scratch = pool;
        self.note_dispatch(arrival, servers, ctx, choice);
        choice
    }

    fn route_resume_indexed(
        &mut self,
        arrival: &Arrival,
        done_steps: u32,
        servers: &[ServerState],
        ctx: &RouteContext,
        index: &mut FleetIndex,
    ) -> usize {
        self.inner.route_resume_indexed(arrival, done_steps, servers, ctx, index)
    }
}

/// Route every arrival of `trace` in time order through a
/// [`FleetIndex`], maintained incrementally: only the chosen server is
/// touched per arrival, so the whole pass is O(arrivals · log N)
/// instead of the scan's O(arrivals · N). Returns the per-arrival
/// server assignment (indexed by arrival id) — **bit-identical** to
/// [`route_trace_scan`] for every policy (`benches/fig_fleet.rs`
/// gates it). Each routed request charges the destination's virtual
/// queue with the singleton-step service estimate `g(1) / speed` —
/// the same estimate for every policy, so comparisons across routers
/// differ only in the dispatch rule.
pub fn route_trace(
    trace: &ArrivalTrace,
    servers: &mut [ServerState],
    router: &mut dyn Router,
    delay: &BatchDelayModel,
) -> Vec<usize> {
    let ctx = RouteContext {
        total_bandwidth_hz: trace.total_bandwidth_hz,
        content_bits: trace.content_bits,
    };
    let mut index = FleetIndex::new(servers);
    let mut assignment = Vec::with_capacity(trace.len());
    route_arrivals(&trace.arrivals, servers, router, delay, &ctx, &mut index, &mut assignment);
    assignment
}

/// The incremental core of [`route_trace`]: route a batch of arrivals
/// (ascending `t_s`, continuing from whatever the fleet and `index`
/// already hold) and append the choices to `assignment`. Allocation-
/// free once the fleet, index and output buffer are warm
/// (`tests/hotpath_alloc.rs` holds it to that). The only per-arrival
/// fleet mutation is the chosen server: `advance` there is lazy
/// garbage collection of its drained virtual queue (decisions read
/// [`ServerState::queue_len_at`], which never needs it), and `touch`
/// re-indexes it after the charge.
pub fn route_arrivals(
    arrivals: &[Arrival],
    servers: &mut [ServerState],
    router: &mut dyn Router,
    delay: &BatchDelayModel,
    ctx: &RouteContext,
    index: &mut FleetIndex,
    assignment: &mut Vec<usize>,
) {
    for arrival in arrivals {
        let choice = router.route_indexed(arrival, servers, ctx, index);
        assert!(servers[choice].alive, "router {} picked failed server {choice}", router.name());
        servers[choice].advance(arrival.t_s);
        let service_est_s = delay.g(1) / servers[choice].speed;
        servers[choice].assign(arrival.t_s, service_est_s);
        index.touch(&servers[choice]);
        assignment.push(choice);
    }
}

/// The O(arrivals · N) reference implementation of [`route_trace`]:
/// advance every server, run the full-fleet scan, charge the choice.
/// Kept verbatim as the executable specification the indexed path is
/// gated against (`benches/fig_fleet.rs`, `tests/routing_index.rs`).
pub fn route_trace_scan(
    trace: &ArrivalTrace,
    servers: &mut [ServerState],
    router: &mut dyn Router,
    delay: &BatchDelayModel,
) -> Vec<usize> {
    let ctx = RouteContext {
        total_bandwidth_hz: trace.total_bandwidth_hz,
        content_bits: trace.content_bits,
    };
    let mut assignment = Vec::with_capacity(trace.len());
    for arrival in &trace.arrivals {
        for s in servers.iter_mut() {
            s.advance(arrival.t_s);
        }
        let choice = router.route(arrival, servers, &ctx);
        assert!(servers[choice].alive, "router {} picked failed server {choice}", router.name());
        let service_est_s = delay.g(1) / servers[choice].speed;
        servers[choice].assign(arrival.t_s, service_est_s);
        assignment.push(choice);
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Link;
    use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
    use crate::trace::PromptMark;

    fn arrival(id: usize, t_s: f64, deadline_s: f64) -> Arrival {
        Arrival { id, t_s, deadline_s, link: Link::new(7.0), mark: PromptMark::ZERO }
    }

    fn marked(id: usize, t_s: f64, deadline_s: f64, model: u32, prompt: u32) -> Arrival {
        Arrival { id, t_s, deadline_s, link: Link::new(7.0), mark: PromptMark { model, prompt } }
    }

    fn ctx() -> RouteContext {
        RouteContext { total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 }
    }

    fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 1,
            zipf_s: 1.0,
            models: 1,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    #[test]
    fn round_robin_cycles_and_skips_failed() {
        let mut servers = ServerState::fleet(&[1.0, 1.0, 1.0]);
        servers[1].alive = false;
        let mut rr = RoundRobinRouter::default();
        let picks: Vec<usize> =
            (0..6).map(|i| rr.route(&arrival(i, i as f64, 10.0), &servers, &ctx())).collect();
        assert_eq!(picks, vec![0, 2, 0, 2, 0, 2]);
    }

    #[test]
    fn jsq_picks_least_outstanding_work() {
        let mut servers = ServerState::fleet(&[1.0, 1.0]);
        servers[0].assign(0.0, 5.0); // server 0 busy for 5 s
        let mut jsq = JoinShortestQueueRouter;
        assert_eq!(jsq.route(&arrival(0, 1.0, 10.0), &servers, &ctx()), 1);
        // after the work drains, ties break to the lowest id
        assert_eq!(jsq.route(&arrival(1, 9.0, 10.0), &servers, &ctx()), 0);
    }

    #[test]
    fn queue_len_at_matches_queue_len_after_advance() {
        let mut s = ServerState::new(0, 1.0);
        for i in 0..6 {
            s.assign(i as f64 * 0.5, 2.0);
        }
        for &t in &[0.0, 1.9, 2.0, 2.1, 5.0, 40.0] {
            let predicted = s.queue_len_at(t);
            let mut advanced = s.clone();
            advanced.advance(t);
            assert_eq!(predicted, advanced.queue_len(), "t={t}");
        }
    }

    #[test]
    fn indexed_route_trace_matches_scan_for_every_kind() {
        let t = trace(5.0, 60.0, 11);
        let delay = BatchDelayModel::paper();
        for kind in RouterKind::with_live() {
            let mut scan_fleet = ServerState::fleet(&[0.5, 1.0, 1.5, 2.0]);
            let mut indexed_fleet = scan_fleet.clone();
            let scan = route_trace_scan(&t, &mut scan_fleet, kind.build(delay).as_mut(), &delay);
            let indexed = route_trace(&t, &mut indexed_fleet, kind.build(delay).as_mut(), &delay);
            assert_eq!(scan, indexed, "{}: indexed dispatch must match the scan", kind.name());
        }
        // and the cache-aware router on a genuinely marked trace
        let mt = marked_trace(11);
        let mut scan_fleet = ServerState::fleet(&[0.5, 1.0, 1.5, 2.0]);
        let mut indexed_fleet = scan_fleet.clone();
        let mut scan_router = CacheAwareRouter::new(delay, cache_settings());
        let mut indexed_router = CacheAwareRouter::new(delay, cache_settings());
        let scan = route_trace_scan(&mt, &mut scan_fleet, &mut scan_router, &delay);
        let indexed = route_trace(&mt, &mut indexed_fleet, &mut indexed_router, &delay);
        assert_eq!(scan, indexed, "cache-aware: indexed dispatch must match the scan");
    }

    #[test]
    fn virtual_queue_drains_over_time() {
        let mut s = ServerState::new(0, 1.0);
        s.assign(0.0, 2.0);
        s.assign(0.0, 2.0);
        assert_eq!(s.queue_len(), 2);
        assert!((s.outstanding_work_s(1.0) - 3.0).abs() < 1e-12);
        s.advance(2.5);
        assert_eq!(s.queue_len(), 1, "first request completes at t=2");
        s.advance(4.0);
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.outstanding_work_s(5.0), 0.0);
    }

    #[test]
    fn quality_aware_prefers_fast_idle_server() {
        let servers = ServerState::fleet(&[0.5, 2.0]);
        let mut qa = QualityAwareRouter::new(BatchDelayModel::paper());
        let a = arrival(0, 0.0, 8.0);
        let fast = qa.predict_steps(&a, &servers[1], &ctx());
        let slow = qa.predict_steps(&a, &servers[0], &ctx());
        assert!(fast > slow, "fast {fast} vs slow {slow}");
        assert_eq!(qa.route(&a, &servers, &ctx()), 1);
    }

    #[test]
    fn quality_aware_avoids_backlogged_fast_server() {
        let mut servers = ServerState::fleet(&[1.0, 2.0]);
        // Fast server is buried: 20 s of queued work vs a 8 s deadline.
        servers[1].assign(0.0, 20.0);
        let mut qa = QualityAwareRouter::new(BatchDelayModel::paper());
        assert_eq!(qa.route(&arrival(0, 1.0, 8.0), &servers, &ctx()), 0);
    }

    #[test]
    fn quality_aware_predicts_outage_past_deadline() {
        let mut s = ServerState::new(0, 1.0);
        s.assign(0.0, 50.0);
        let qa = QualityAwareRouter::new(BatchDelayModel::paper());
        assert_eq!(qa.predict_steps(&arrival(0, 0.0, 5.0), &s, &ctx()), 0);
    }

    #[test]
    fn route_resume_with_zero_credit_matches_route() {
        let t = trace(5.0, 60.0, 11);
        let delay = BatchDelayModel::paper();
        for kind in RouterKind::with_live() {
            let mut servers = ServerState::fleet(&[0.5, 1.0, 1.5]);
            servers[2].assign(0.0, 4.0);
            let mut a = kind.build(delay);
            let mut b = kind.build(delay);
            let ctx = ctx();
            for arrival in t.arrivals.iter().take(40) {
                for s in servers.iter_mut() {
                    s.advance(arrival.t_s);
                }
                let fresh = a.route(arrival, &servers, &ctx);
                let resumed = b.route_resume(arrival, 0, &servers, &ctx);
                assert_eq!(fresh, resumed, "{}: zero-credit resume must match", kind.name());
                servers[fresh].assign(arrival.t_s, delay.g(1) / servers[fresh].speed);
            }
        }
    }

    #[test]
    fn quality_aware_resume_credits_done_steps() {
        let servers = ServerState::fleet(&[1.0, 2.0]);
        let mut qa = QualityAwareRouter::new(BatchDelayModel::paper());
        qa.max_steps = 30;
        let a = arrival(0, 0.0, 8.0);
        // Fresh dispatch: the fast server predicts more steps.
        assert_eq!(qa.route(&a, &servers, &ctx()), 1);
        assert_eq!(qa.route_resume(&a, 0, &servers, &ctx()), 1);
        // A request already near the quality cap saturates both
        // predictions; the tie then breaks away from raw speed (equal
        // load here, so to the lower id) — the done-step credit
        // changed the decision.
        let slow_pred = qa.predict_steps(&a, &servers[0], &ctx());
        assert!(slow_pred >= 15, "precondition: slow server saturates with credit 15");
        assert_eq!(qa.route_resume(&a, 15, &servers, &ctx()), 0);
    }

    #[test]
    fn route_trace_assigns_everyone_deterministically() {
        let t = trace(5.0, 60.0, 11);
        for kind in RouterKind::all() {
            let mut fleet_a = ServerState::fleet(&[0.5, 1.0, 1.5]);
            let mut fleet_b = ServerState::fleet(&[0.5, 1.0, 1.5]);
            let delay = BatchDelayModel::paper();
            let a = route_trace(&t, &mut fleet_a, kind.build(delay).as_mut(), &delay);
            let b = route_trace(&t, &mut fleet_b, kind.build(delay).as_mut(), &delay);
            assert_eq!(a.len(), t.len(), "{}: every arrival routed", kind.name());
            assert_eq!(a, b, "{}: replay must be identical", kind.name());
            let total: usize = fleet_a.iter().map(|s| s.routed).sum();
            assert_eq!(total, t.len(), "{}: conservation", kind.name());
        }
    }

    #[test]
    fn router_kind_names_round_trip() {
        for kind in RouterKind::with_live() {
            assert_eq!(RouterKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(RouterKind::from_name("rr").unwrap(), RouterKind::RoundRobin);
        assert_eq!(RouterKind::from_name("shortest-queue").unwrap(), RouterKind::JoinShortestQueue);
        assert_eq!(RouterKind::from_name("quality").unwrap(), RouterKind::QualityAware);
        assert_eq!(RouterKind::from_name("live-state").unwrap(), RouterKind::LiveState);
        assert_eq!(RouterKind::from_name("cache").unwrap(), RouterKind::CacheAware);
        assert_eq!(RouterKind::from_name("cache-aware").unwrap(), RouterKind::CacheAware);
        assert_eq!(RouterKind::CacheAware.name(), "cache-aware");
        let err = RouterKind::from_name("bogus").unwrap_err().to_string();
        assert!(err.contains("round-robin") && err.contains("jsq"), "{err}");
        assert!(err.contains("quality-aware") && err.contains("live"), "{err}");
        assert!(err.contains("cache-aware"), "{err}");
    }

    #[test]
    fn live_router_reads_the_published_view_over_the_virtual_queue() {
        let mut servers = ServerState::fleet(&[1.0, 1.0]);
        // Virtual queues say server 0 is empty and server 1 is buried…
        servers[1].assign(0.0, 50.0);
        // …but the live views say the opposite: 0 has a deep real
        // queue and a busy GPU, 1 is idle.
        servers[0].live = Some(LiveView { queue_depth: 12, gpu_free_s: 9.0 });
        servers[1].live = Some(LiveView { queue_depth: 0, gpu_free_s: 0.0 });
        let mut live = LiveStateRouter::new(BatchDelayModel::paper());
        assert_eq!(live.route(&arrival(0, 1.0, 10.0), &servers, &ctx()), 1);
        // JSQ, blind to the live view, still trusts the stale estimate
        let mut jsq = JoinShortestQueueRouter;
        assert_eq!(jsq.route(&arrival(0, 1.0, 10.0), &servers, &ctx()), 0);
    }

    #[test]
    fn live_router_without_views_degenerates_to_virtual_jsq() {
        let t = trace(5.0, 60.0, 11);
        let delay = BatchDelayModel::paper();
        let mut live_fleet = ServerState::fleet(&[0.5, 1.0, 1.5]);
        let mut jsq_fleet = ServerState::fleet(&[0.5, 1.0, 1.5]);
        let live = route_trace(&t, &mut live_fleet, &mut LiveStateRouter::new(delay), &delay);
        let jsq = route_trace(&t, &mut jsq_fleet, &mut JoinShortestQueueRouter, &delay);
        assert_eq!(live, jsq, "no live views published: identical dispatch");
    }

    #[test]
    fn live_router_skips_failed_servers() {
        let mut servers = ServerState::fleet(&[1.0, 1.0]);
        servers[0].live = Some(LiveView { queue_depth: 0, gpu_free_s: 0.0 });
        servers[1].live = Some(LiveView { queue_depth: 5, gpu_free_s: 4.0 });
        servers[0].alive = false;
        let mut live = LiveStateRouter::new(BatchDelayModel::paper());
        assert_eq!(live.route(&arrival(0, 1.0, 10.0), &servers, &ctx()), 1);
    }

    fn cache_settings() -> CacheSettings {
        CacheSettings { enabled: true, capacity: 8, ..CacheSettings::default() }
    }

    fn marked_trace(seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: 5.0,
            burst_rate_hz: 5.0,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: 60.0,
            max_requests: 0,
            prompt_universe: 20,
            zipf_s: 1.4,
            models: 3,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    #[test]
    fn cache_aware_on_unmarked_trace_matches_quality_aware() {
        let t = trace(5.0, 60.0, 11);
        assert!(!t.is_marked());
        let delay = BatchDelayModel::paper();
        let mut fleet_a = ServerState::fleet(&[0.5, 1.0, 1.5]);
        let mut fleet_b = ServerState::fleet(&[0.5, 1.0, 1.5]);
        let mut ca = CacheAwareRouter::new(delay, cache_settings());
        let a = route_trace(&t, &mut fleet_a, &mut ca, &delay);
        let b = route_trace(&t, &mut fleet_b, &mut QualityAwareRouter::new(delay), &delay);
        assert_eq!(a, b, "no prompt marks: identical dispatch");
    }

    #[test]
    fn cache_aware_prefers_the_shadow_hit_server_even_under_load() {
        let mut servers = ServerState::fleet(&[1.0, 1.0]);
        let mut ca = CacheAwareRouter::new(BatchDelayModel::paper(), cache_settings());
        // First dispatch of (model 0, prompt 7): no shadow hit anywhere,
        // equal idle fleet → ties to server 0, which now shadows the key.
        assert_eq!(ca.route(&marked(0, 0.0, 10.0, 0, 7), &servers, &ctx()), 0);
        // Bury server 0: quality-aware would now route to server 1 …
        servers[0].assign(0.0, 50.0);
        assert_eq!(ca.inner.route(&marked(1, 1.0, 10.0, 0, 7), &servers, &ctx()), 1);
        // … but a cached generation bypasses the queue entirely, so the
        // repeat prompt sticks to server 0.
        assert_eq!(ca.route(&marked(1, 1.0, 10.0, 0, 7), &servers, &ctx()), 0);
        // A fresh prompt has no hit; model 0 is resident on both boot
        // catalogs, so it falls back to quality-aware and picks idle 1.
        assert_eq!(ca.route(&marked(2, 1.0, 10.0, 0, 9), &servers, &ctx()), 1);
    }

    #[test]
    fn cache_aware_piles_fresh_prompts_onto_the_model_resident_server() {
        let mut servers = ServerState::fleet(&[1.0, 1.0]);
        let mut ca = CacheAwareRouter::new(BatchDelayModel::paper(), cache_settings());
        // (model 3, prompt 1) swaps model 3 onto server 0's shadow
        // catalog (single slot: model 0 is evicted).
        assert_eq!(ca.route(&marked(0, 0.0, 10.0, 3, 1), &servers, &ctx()), 0);
        // Nudge server 0 busier so plain quality-aware would prefer the
        // idle server 1 for the next request …
        servers[0].assign(0.0, 1.0);
        let fresh = marked(1, 0.5, 10.0, 3, 2);
        let s0 = ca.inner.predict_steps(&fresh, &servers[0], &ctx());
        let s1 = ca.inner.predict_steps(&fresh, &servers[1], &ctx());
        assert!(s1 > s0, "precondition: quality-aware prefers idle ({s1} vs {s0})");
        assert_eq!(ca.inner.route(&fresh, &servers, &ctx()), 1);
        // … but only server 0 holds model 3: placement affinity keeps
        // model-3 prompts where the weights already live.
        assert_eq!(ca.route(&fresh, &servers, &ctx()), 0);
    }

    #[test]
    fn cache_aware_routes_marked_traces_deterministically() {
        let t = marked_trace(11);
        assert!(t.is_marked(), "universe 20 × 3 models must mark the trace");
        let delay = BatchDelayModel::paper();
        let run = || {
            let mut fleet = ServerState::fleet(&[0.5, 1.0, 1.5]);
            let mut r = CacheAwareRouter::new(delay, cache_settings());
            route_trace(&t, &mut fleet, &mut r, &delay)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.len(), t.len(), "every arrival routed");
        assert_eq!(a, b, "replay must be identical");
    }

    #[test]
    fn cache_aware_skips_failed_servers_for_hits_and_residency() {
        let mut servers = ServerState::fleet(&[1.0, 1.0]);
        let mut ca = CacheAwareRouter::new(BatchDelayModel::paper(), cache_settings());
        assert_eq!(ca.route(&marked(0, 0.0, 10.0, 2, 5), &servers, &ctx()), 0);
        servers[0].alive = false;
        // The shadow hit (and the resident model) live on the dead
        // server; the repeat must reroute to an alive one.
        assert_eq!(ca.route(&marked(1, 1.0, 10.0, 2, 5), &servers, &ctx()), 1);
    }

    #[test]
    #[should_panic(expected = "every server failed")]
    fn all_failed_fleet_panics() {
        let mut servers = ServerState::fleet(&[1.0]);
        servers[0].alive = false;
        RoundRobinRouter::default().route(&arrival(0, 0.0, 5.0), &servers, &ctx());
    }
}
