//! `FleetIndex` — a deterministic ordered index over the fleet's
//! virtual queues, so routers can find their argmin/argmax without an
//! O(N) scan per arrival.
//!
//! Same trick as the event engine's lazy server-event heap (PR 9):
//! every key is a non-negative finite `f64`, whose IEEE-754 bit
//! pattern orders exactly like the value, so a
//! `BTreeSet<(u64, usize)>` keyed `(value.to_bits(), id)` is a
//! deterministic total order with the same lowest-id tie-break the
//! scan comparators use.
//!
//! Three coordinated structures:
//!
//! * **idle/busy split over `busy_until`.** A server whose
//!   `busy_until ≤ now` has exactly zero outstanding work (the
//!   subtraction in `outstanding_work_s` clamps at `+0.0`), so the
//!   idle side needs no float key at all and orders by id. The busy
//!   side orders by `busy_until`, which orders like
//!   `outstanding_work_s(now)` for every `now ≤ busy_until`:
//!   subtracting the same float from two floats is monotone under IEEE
//!   rounding — non-strictly, though: two *distinct* `busy_until`
//!   values can round to the *same* outstanding work, so the routers
//!   scan the whole equal-outstanding prefix (ascending id does not in
//!   general agree with ascending `busy_until` inside it) instead of
//!   blindly taking the first entry.
//!   [`FleetIndex::settle`] migrates entries busy→idle
//!   as `now` advances; each assignment re-inserts at most one busy
//!   entry, so settling is amortized O(log N) per touch.
//! * **speed ladder.** A static position order sorted by GPU speed,
//!   with a min-id segment tree over the *idle* positions. An idle
//!   server's quality prediction depends on its speed alone and is
//!   monotone non-decreasing in it, so `QualityAwareRouter`
//!   binary-searches the ladder for the slowest speed still reaching
//!   the top score and takes the min-id idle server at or above that
//!   position — the exact scan winner among idle servers, O(log N).
//! * **live half.** The event engine publishes each server's true
//!   `gpu_free` and queue cost (computed by the shared
//!   [`super::live_queue_cost_s`], so the key is bit-identical to the
//!   term `LiveStateRouter::backlog_s` adds); the same idle/busy
//!   split over `gpu_free` gives the live router its backlog argmin
//!   with a lower-bound prune.
//!
//! Contract: query times are non-decreasing, and every mutation of a
//! server's `busy_until`/`alive` (assign, kill, revive) is reported
//! through [`FleetIndex::touch`] / [`FleetIndex::remove`] before the
//! next query. `route_trace` and `sim::event` maintain exactly that.

use std::collections::BTreeSet;

use super::ServerState;

/// Deterministic operation counters — the currency of the fleet-size
/// bench. `benches/fig_fleet.rs` gates sub-linear growth on these, not
/// on wall clock (CI runners are too noisy to gate time).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IndexStats {
    /// Routing decisions answered through the index.
    pub queries: u64,
    /// Candidate evaluations across all queries: exact scores, speed
    /// ladder probes, and candidate-pool members examined.
    pub examined: u64,
    /// Busy→idle migrations performed by settle passes.
    pub settles: u64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Slot {
    /// Dead or never inserted — in neither set.
    Out,
    Idle,
    Busy(u64),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum LiveSlot {
    Out,
    /// GPU free at the settle watermark; keyed by published queue cost.
    Idle { cost: u64 },
    Busy { free: u64, cost: u64 },
}

/// Ordered index over a fleet's virtual queues (and, in the event
/// engine, the published live views). See the module docs for the
/// ordering and maintenance contract.
#[derive(Debug, Clone)]
pub struct FleetIndex {
    slots: Vec<Slot>,
    /// Alive servers with zero outstanding work, by id (the id *is*
    /// the JSQ tie-break once outstanding work ties at exactly 0).
    idle: BTreeSet<usize>,
    /// Alive servers with outstanding work: `(busy_until bits, id)`.
    busy: BTreeSet<(u64, usize)>,
    /// Monotone settle watermark, as bits of the last settle time.
    now_bits: u64,
    /// Server ids sorted by `(speed, id)` ascending — static.
    ladder: Vec<usize>,
    /// Speed at each ladder position — static.
    ladder_speed: Vec<f64>,
    /// Ladder position of each server id — static.
    pos_of: Vec<usize>,
    /// Ladder positions of the idle servers.
    idle_pos: BTreeSet<usize>,
    /// Min-id segment tree over idle ladder positions
    /// (`usize::MAX` = no idle server in that range).
    seg: Vec<usize>,
    seg_base: usize,
    live_slots: Vec<LiveSlot>,
    /// Published-view servers whose GPU is already free, keyed
    /// `(queue-cost bits, id)` — the cost *is* their backlog.
    live_idle: BTreeSet<(u64, usize)>,
    /// Published-view servers whose GPU is still busy, keyed
    /// `(gpu_free bits, id)`.
    live_busy: BTreeSet<(u64, usize)>,
    live_active: bool,
    pub stats: IndexStats,
}

impl FleetIndex {
    /// Build the index over `servers` (dead servers are left out; the
    /// speed ladder still covers them so a revived server re-enters
    /// with its position intact).
    pub fn new(servers: &[ServerState]) -> Self {
        let n = servers.len();
        let mut ladder: Vec<usize> = (0..n).collect();
        ladder.sort_by(|&a, &b| servers[a].speed.total_cmp(&servers[b].speed).then(a.cmp(&b)));
        let mut pos_of = vec![0usize; n];
        for (p, &id) in ladder.iter().enumerate() {
            pos_of[id] = p;
        }
        let ladder_speed: Vec<f64> = ladder.iter().map(|&id| servers[id].speed).collect();
        let seg_base = n.next_power_of_two().max(1);
        let mut index = Self {
            slots: vec![Slot::Out; n],
            idle: BTreeSet::new(),
            busy: BTreeSet::new(),
            now_bits: 0,
            ladder,
            ladder_speed,
            pos_of,
            idle_pos: BTreeSet::new(),
            seg: vec![usize::MAX; 2 * seg_base],
            seg_base,
            live_slots: vec![LiveSlot::Out; n],
            live_idle: BTreeSet::new(),
            live_busy: BTreeSet::new(),
            live_active: false,
            stats: IndexStats::default(),
        };
        for s in servers {
            index.touch(s);
        }
        index
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn reset_stats(&mut self) {
        self.stats = IndexStats::default();
    }

    fn seg_set(&mut self, pos: usize, val: usize) {
        let mut i = self.seg_base + pos;
        self.seg[i] = val;
        while i > 1 {
            i /= 2;
            self.seg[i] = self.seg[2 * i].min(self.seg[2 * i + 1]);
        }
    }

    /// Minimum id over idle ladder positions in `[pos_lo, n)`.
    pub fn min_idle_id_from(&self, pos_lo: usize) -> Option<usize> {
        let mut best = usize::MAX;
        let (mut l, mut r) = (self.seg_base + pos_lo, self.seg_base + self.len());
        while l < r {
            if l & 1 == 1 {
                best = best.min(self.seg[l]);
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                best = best.min(self.seg[r]);
            }
            l /= 2;
            r /= 2;
        }
        (best != usize::MAX).then_some(best)
    }

    fn set_idle(&mut self, id: usize) {
        self.idle.insert(id);
        let pos = self.pos_of[id];
        self.idle_pos.insert(pos);
        self.seg_set(pos, id);
        self.slots[id] = Slot::Idle;
    }

    fn clear_main(&mut self, id: usize) {
        match self.slots[id] {
            Slot::Out => {}
            Slot::Idle => {
                self.idle.remove(&id);
                let pos = self.pos_of[id];
                self.idle_pos.remove(&pos);
                self.seg_set(pos, usize::MAX);
            }
            Slot::Busy(bits) => {
                self.busy.remove(&(bits, id));
            }
        }
        self.slots[id] = Slot::Out;
    }

    fn clear_live(&mut self, id: usize) {
        match self.live_slots[id] {
            LiveSlot::Out => {}
            LiveSlot::Idle { cost } => {
                self.live_idle.remove(&(cost, id));
            }
            LiveSlot::Busy { free, .. } => {
                self.live_busy.remove(&(free, id));
            }
        }
        self.live_slots[id] = LiveSlot::Out;
    }

    /// Re-index one server after its virtual queue or liveness changed
    /// (call right after `assign`, and on revive).
    pub fn touch(&mut self, s: &ServerState) {
        let id = s.id;
        self.clear_main(id);
        if !s.alive {
            return;
        }
        let bits = s.busy_until_bits();
        if bits <= self.now_bits {
            self.set_idle(id);
        } else {
            self.busy.insert((bits, id));
            self.slots[id] = Slot::Busy(bits);
        }
    }

    /// Drop a server from every set (server death).
    pub fn remove(&mut self, id: usize) {
        self.clear_main(id);
        self.clear_live(id);
    }

    /// Advance the watermark to `now_s` (non-negative, non-decreasing
    /// across calls) and migrate every busy entry whose `busy_until`
    /// has passed to the idle side. Amortized O(log N) per `touch`:
    /// each busy entry settles at most once.
    pub fn settle(&mut self, now_s: f64) {
        self.now_bits = self.now_bits.max(now_s.to_bits());
        while let Some(&(bits, id)) = self.busy.first() {
            if bits > self.now_bits {
                break;
            }
            self.busy.remove(&(bits, id));
            self.set_idle(id);
            self.stats.settles += 1;
        }
    }

    /// Lowest-id alive server with zero outstanding work at the
    /// settled watermark — the JSQ argmin whenever any server is idle
    /// (idle servers all hold exactly `+0.0`, the global minimum, and
    /// the scan breaks that tie by id).
    pub fn first_idle(&self) -> Option<usize> {
        self.idle.first().copied()
    }

    /// Lowest-id idle server, else the least-`busy_until` busy server.
    /// A cheap head probe — note the busy fallback is *not* in general
    /// the exact JSQ argmin: distinct `busy_until` values can round to
    /// equal outstanding work, where the scan tie-breaks by id. The
    /// routers scan the equal-outstanding busy prefix instead
    /// (`super::indexed_jsq_argmin`). `None` iff every server is dead.
    pub fn first(&self) -> Option<usize> {
        self.idle.first().copied().or_else(|| self.busy.first().map(|&(_, id)| id))
    }

    /// Highest idle ladder position (fastest idle server), if any.
    pub fn last_idle_pos(&self) -> Option<usize> {
        self.idle_pos.last().copied()
    }

    /// Static speed at a ladder position (positions order by speed
    /// ascending, ties by id).
    pub fn speed_at(&self, pos: usize) -> f64 {
        self.ladder_speed[pos]
    }

    /// Busy servers in ascending `(busy_until, id)` order — equivalently
    /// ascending `(outstanding_work_s(now), id)` for the settled `now`.
    pub fn busy_entries(&self) -> impl Iterator<Item = (f64, usize)> + '_ {
        self.busy.iter().map(|&(bits, id)| (f64::from_bits(bits), id))
    }

    /// Whether the event engine has ever published a live view here.
    pub fn live_active(&self) -> bool {
        self.live_active
    }

    /// Publish one server's live view (event engine only). `cost_s`
    /// must be computed with [`super::live_queue_cost_s`] so it is
    /// bit-identical to the queue term of `LiveStateRouter::backlog_s`.
    pub fn publish_live(&mut self, id: usize, alive: bool, gpu_free_s: f64, cost_s: f64) {
        self.live_active = true;
        self.clear_live(id);
        if !alive {
            return;
        }
        let cost = cost_s.to_bits();
        let free = gpu_free_s.to_bits();
        if free <= self.now_bits {
            self.live_idle.insert((cost, id));
            self.live_slots[id] = LiveSlot::Idle { cost };
        } else {
            self.live_busy.insert((free, id));
            self.live_slots[id] = LiveSlot::Busy { free, cost };
        }
    }

    /// Advance the watermark and migrate live entries whose GPU has
    /// freed. Mirrors [`Self::settle`] on the live half.
    pub fn settle_live(&mut self, now_s: f64) {
        self.now_bits = self.now_bits.max(now_s.to_bits());
        while let Some(&(free, id)) = self.live_busy.first() {
            if free > self.now_bits {
                break;
            }
            self.live_busy.remove(&(free, id));
            let cost = match self.live_slots[id] {
                LiveSlot::Busy { cost, .. } => cost,
                state => unreachable!("live busy entry {id} in state {state:?}"),
            };
            self.live_idle.insert((cost, id));
            self.live_slots[id] = LiveSlot::Idle { cost };
            self.stats.settles += 1;
        }
    }

    /// The settled-GPU server with the least published backlog (its
    /// backlog is exactly its queue cost), lowest id on ties.
    pub fn live_idle_first(&self) -> Option<(f64, usize)> {
        self.live_idle.first().map(|&(cost, id)| (f64::from_bits(cost), id))
    }

    /// Busy-GPU servers in ascending `(gpu_free, id)` order. For each,
    /// `(gpu_free − now).max(0.0)` lower-bounds its true backlog.
    pub fn live_busy_entries(&self) -> impl Iterator<Item = (f64, usize)> + '_ {
        self.live_busy.iter().map(|&(free, id)| (f64::from_bits(free), id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(speeds: &[f64]) -> Vec<ServerState> {
        ServerState::fleet(speeds)
    }

    #[test]
    fn fresh_fleet_is_all_idle_and_first_is_lowest_id() {
        let servers = fleet(&[1.0, 2.0, 0.5]);
        let ix = FleetIndex::new(&servers);
        assert_eq!(ix.first(), Some(0));
        assert_eq!(ix.min_idle_id_from(0), Some(0));
    }

    #[test]
    fn busy_orders_by_busy_until_and_settles_back() {
        let mut servers = fleet(&[1.0, 1.0, 1.0]);
        let mut ix = FleetIndex::new(&servers);
        servers[0].assign(0.0, 5.0);
        ix.touch(&servers[0]);
        servers[2].assign(0.0, 2.0);
        ix.touch(&servers[2]);
        servers[1].assign(0.0, 9.0);
        ix.touch(&servers[1]);
        ix.settle(1.0);
        // everyone busy: least busy_until first
        assert_eq!(ix.first(), Some(2));
        let order: Vec<usize> = ix.busy_entries().map(|(_, id)| id).collect();
        assert_eq!(order, vec![2, 0, 1]);
        // t=6: servers 2 and 0 settle; lowest idle id wins
        ix.settle(6.0);
        assert_eq!(ix.first(), Some(0));
        assert_eq!(ix.stats.settles, 2);
    }

    #[test]
    fn speed_ladder_min_id_query_tracks_idle_membership() {
        // speeds: id 0 → 0.5 (pos 0), id 1 → 1.0 (pos 1), id 2 → 1.0
        // (pos 2, id tie-break), id 3 → 2.0 (pos 3)
        let mut servers = fleet(&[0.5, 1.0, 1.0, 2.0]);
        let mut ix = FleetIndex::new(&servers);
        assert_eq!(ix.last_idle_pos(), Some(3));
        assert_eq!(ix.speed_at(3), 2.0);
        assert_eq!(ix.min_idle_id_from(1), Some(1));
        // bury id 1: the min id at positions ≥ 1 becomes 2
        servers[1].assign(0.0, 4.0);
        ix.touch(&servers[1]);
        assert_eq!(ix.min_idle_id_from(1), Some(2));
        // kill id 3: fastest idle position drops to id 2's
        servers[3].alive = false;
        ix.remove(3);
        assert_eq!(ix.last_idle_pos(), Some(2));
        assert_eq!(ix.min_idle_id_from(3), None);
    }

    #[test]
    fn dead_servers_leave_every_set_and_revive_reenters() {
        let mut servers = fleet(&[1.0, 1.0]);
        let mut ix = FleetIndex::new(&servers);
        servers[0].alive = false;
        ix.remove(0);
        assert_eq!(ix.first(), Some(1));
        servers[0].alive = true;
        ix.touch(&servers[0]);
        assert_eq!(ix.first(), Some(0));
    }

    #[test]
    fn live_half_splits_on_gpu_free_and_settles() {
        let servers = fleet(&[1.0, 1.0, 1.0]);
        let mut ix = FleetIndex::new(&servers);
        assert!(!ix.live_active());
        ix.settle(1.0);
        ix.publish_live(0, true, 0.5, 3.0); // free ≤ watermark → idle, backlog 3
        ix.publish_live(1, true, 4.0, 0.25); // still busy until 4
        ix.publish_live(2, true, 9.0, 0.0);
        assert!(ix.live_active());
        assert_eq!(ix.live_idle_first(), Some((3.0, 0)));
        let busy: Vec<usize> = ix.live_busy_entries().map(|(_, id)| id).collect();
        assert_eq!(busy, vec![1, 2]);
        // GPU 1 frees at t=4: its published cost keys the idle side,
        // undercutting server 0's backlog.
        ix.settle_live(4.5);
        assert_eq!(ix.live_idle_first(), Some((0.25, 1)));
        // death removes the live entry too
        ix.publish_live(1, false, 4.0, 0.25);
        assert_eq!(ix.live_idle_first(), Some((3.0, 0)));
    }
}
