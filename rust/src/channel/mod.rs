//! Wireless downlink model — Section II-B of the paper.
//!
//! Frequency non-selective channels, constant during a transmission:
//!   spectral efficiency  η_k = log2(1 + p̄·h_k / N₀)     (Eq. 8)
//!   rate                 r_k = B_k · η_k
//!   transmission delay   D^ct_k = S / r_k                 (Eq. 11)
//!
//! The simulation section of the paper draws η_k uniformly in
//! [5, 10] bit/s/Hz; [`ChannelGenerator`] supports both that direct draw
//! and a physical Rayleigh-fading draw through Eq. (8).

use crate::util::Pcg64;

/// Per-device downlink state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Spectral efficiency η_k in bit/s/Hz.
    pub spectral_efficiency: f64,
}

impl Link {
    pub fn new(spectral_efficiency: f64) -> Self {
        assert!(spectral_efficiency > 0.0);
        Self { spectral_efficiency }
    }

    /// Transmission rate in bit/s for an allocated bandwidth in Hz (Eq. 8).
    #[inline]
    pub fn rate(&self, bandwidth_hz: f64) -> f64 {
        bandwidth_hz * self.spectral_efficiency
    }

    /// Transmission delay in seconds for `content_bits` over `bandwidth_hz`
    /// (Eq. 11). Infinite for zero bandwidth.
    #[inline]
    pub fn tx_delay(&self, content_bits: f64, bandwidth_hz: f64) -> f64 {
        if bandwidth_hz <= 0.0 {
            return f64::INFINITY;
        }
        content_bits / self.rate(bandwidth_hz)
    }

    /// Minimum bandwidth needed to deliver `content_bits` within
    /// `deadline_s` seconds.
    pub fn min_bandwidth(&self, content_bits: f64, deadline_s: f64) -> f64 {
        assert!(deadline_s > 0.0);
        content_bits / (self.spectral_efficiency * deadline_s)
    }
}

/// Spectral efficiency from the physical SNR (Eq. 8):
/// η = log2(1 + p̄·h/N₀).
pub fn spectral_efficiency(tx_power_per_hz: f64, channel_gain: f64, noise_psd: f64) -> f64 {
    assert!(noise_psd > 0.0);
    (1.0 + tx_power_per_hz * channel_gain / noise_psd).log2()
}

/// How the generator draws per-device links.
#[derive(Debug, Clone, Copy)]
pub enum FadingModel {
    /// Draw η_k ~ U[lo, hi] directly — the paper's simulation setting
    /// (η ∈ [5, 10] bit/s/Hz).
    UniformEfficiency { lo: f64, hi: f64 },
    /// Rayleigh fading: gain h = |g|², g ~ CN(0, mean_gain), pushed
    /// through Eq. (8). Produces a long-tailed η distribution.
    Rayleigh { tx_power_per_hz: f64, mean_gain: f64, noise_psd: f64 },
}

/// Seeded generator of per-device [`Link`]s.
#[derive(Debug, Clone)]
pub struct ChannelGenerator {
    pub model: FadingModel,
    rng: Pcg64,
}

impl ChannelGenerator {
    pub fn new(model: FadingModel, seed: u64) -> Self {
        Self { model, rng: Pcg64::new(seed, 0xC4A17) }
    }

    /// The paper's simulation draw: η ~ U[5, 10].
    pub fn paper(seed: u64) -> Self {
        Self::new(FadingModel::UniformEfficiency { lo: 5.0, hi: 10.0 }, seed)
    }

    pub fn draw(&mut self) -> Link {
        match self.model {
            FadingModel::UniformEfficiency { lo, hi } => Link::new(self.rng.uniform_in(lo, hi)),
            FadingModel::Rayleigh { tx_power_per_hz, mean_gain, noise_psd } => {
                // |CN(0, σ²)|² is exponential with mean σ².
                let h = self.rng.exponential(1.0 / mean_gain);
                // Clamp so a deep fade cannot produce η = 0 (the paper's
                // model keeps all links usable).
                let eta = spectral_efficiency(tx_power_per_hz, h, noise_psd).max(0.1);
                Link::new(eta)
            }
        }
    }

    pub fn draw_n(&mut self, n: usize) -> Vec<Link> {
        (0..n).map(|_| self.draw()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn rate_and_delay() {
        let link = Link::new(8.0); // bit/s/Hz
        // 2 kHz * 8 b/s/Hz = 16 kb/s
        assert!(approx_eq(link.rate(2_000.0), 16_000.0, 1e-9));
        // 24 kbit over 16 kb/s = 1.5 s
        assert!(approx_eq(link.tx_delay(24_000.0, 2_000.0), 1.5, 1e-9));
    }

    #[test]
    fn zero_bandwidth_is_infinite_delay() {
        let link = Link::new(5.0);
        assert!(link.tx_delay(1000.0, 0.0).is_infinite());
    }

    #[test]
    fn min_bandwidth_inverts_tx_delay() {
        let link = Link::new(6.5);
        let bits = 24_000.0;
        let deadline = 2.0;
        let bw = link.min_bandwidth(bits, deadline);
        assert!(approx_eq(link.tx_delay(bits, bw), deadline, 1e-9));
    }

    #[test]
    fn spectral_efficiency_formula() {
        // log2(1 + 1*1/1) = 1
        assert!(approx_eq(spectral_efficiency(1.0, 1.0, 1.0), 1.0, 1e-12));
        // log2(1 + 3) = 2
        assert!(approx_eq(spectral_efficiency(3.0, 1.0, 1.0), 2.0, 1e-12));
        // monotone in gain
        assert!(
            spectral_efficiency(1.0, 10.0, 1.0) > spectral_efficiency(1.0, 1.0, 1.0)
        );
    }

    #[test]
    fn paper_draw_in_range() {
        let mut gen = ChannelGenerator::paper(123);
        for _ in 0..1000 {
            let link = gen.draw();
            assert!(
                (5.0..10.0).contains(&link.spectral_efficiency),
                "eta={}",
                link.spectral_efficiency
            );
        }
    }

    #[test]
    fn paper_draw_deterministic() {
        let a: Vec<f64> =
            ChannelGenerator::paper(7).draw_n(10).iter().map(|l| l.spectral_efficiency).collect();
        let b: Vec<f64> =
            ChannelGenerator::paper(7).draw_n(10).iter().map(|l| l.spectral_efficiency).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn rayleigh_mean_efficiency_reasonable() {
        let mut gen = ChannelGenerator::new(
            FadingModel::Rayleigh { tx_power_per_hz: 100.0, mean_gain: 1.0, noise_psd: 1.0 },
            42,
        );
        let links = gen.draw_n(4000);
        let mean: f64 =
            links.iter().map(|l| l.spectral_efficiency).sum::<f64>() / links.len() as f64;
        // E[log2(1+100h)], h~Exp(1): around log2(100) ≈ 6.6 minus Jensen gap
        assert!(mean > 4.0 && mean < 8.0, "mean eta = {mean}");
        assert!(links.iter().all(|l| l.spectral_efficiency >= 0.1));
    }
}
