//! Content-quality models: FID (lower = better) as a function of the
//! number of denoising steps `T_k` — the objective of problem (P0).
//!
//! Two implementations:
//! * [`PowerLawQuality`] — the paper's fitted form `q(T) = c·T^(−d) + e`
//!   (Fig. 1b). The `paper` preset uses constants in the regime the
//!   paper reports for DDIM/CIFAR-10; the `measured` preset is re-fitted
//!   by `python/compile/calibrate.py` on the build-time model.
//! * [`TableQuality`] — piecewise-linear interpolation of the *measured*
//!   curve from `artifacts/quality.json`; no functional form assumed
//!   (the STACKING algorithm is agnostic to it, which this implementation
//!   exercises).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};
use crate::util::PowerLawFit;

/// A quality model maps a step count to an FID-like score (lower = better).
pub trait QualityModel: Send + Sync {
    /// Quality after `steps` denoising steps. `steps == 0` must return
    /// the outage quality.
    fn quality(&self, steps: u32) -> f64;

    /// Quality charged to a service that never completes (deadline
    /// violated with zero steps, or dropped).
    fn outage(&self) -> f64 {
        self.quality(0)
    }
}

/// The paper's power-law model.
#[derive(Debug, Clone)]
pub struct PowerLawQuality {
    pub c: f64,
    pub d: f64,
    pub e: f64,
    /// Multiplier over q(1) charged for outages (paper counts outages as
    /// sharply degraded mean FID; q(0) itself is unbounded).
    pub outage_factor: f64,
}

impl PowerLawQuality {
    pub fn new(c: f64, d: f64, e: f64) -> Self {
        Self { c, d, e, outage_factor: 1.5 }
    }

    /// Constants in the DDIM-on-CIFAR-10 regime of the paper's Fig. 1b:
    /// FID ≈ 306 at T=1 falling to ≈ 13 by T≈50, power-law decay.
    pub fn paper() -> Self {
        Self::new(293.0, 1.1, 13.0)
    }

    /// From the power-law fit the build-time calibration produced.
    pub fn from_fit(fit: &PowerLawFit) -> Self {
        Self::new(fit.c, fit.d, fit.e)
    }

    /// Load the `power_law` section of `artifacts/quality.json`.
    pub fn from_quality_json(path: &Path) -> Result<Self> {
        let doc = load_quality_json(path)?;
        let pl = doc.required("power_law")?;
        Ok(Self::new(
            pl.required("c")?.as_f64().context("c")?,
            pl.required("d")?.as_f64().context("d")?,
            pl.required("e")?.as_f64().context("e")?,
        ))
    }
}

impl QualityModel for PowerLawQuality {
    fn quality(&self, steps: u32) -> f64 {
        if steps == 0 {
            return self.outage();
        }
        self.c * (steps as f64).powf(-self.d) + self.e
    }

    fn outage(&self) -> f64 {
        self.outage_factor * (self.c + self.e)
    }
}

/// Piecewise-linear interpolation of a measured (steps, quality) curve.
#[derive(Debug, Clone)]
pub struct TableQuality {
    /// Sorted by steps, strictly increasing step values.
    points: Vec<(u32, f64)>,
    outage: f64,
}

impl TableQuality {
    /// Build from measured points; `outage` is the score charged at T=0.
    pub fn new(mut points: Vec<(u32, f64)>, outage: f64) -> Self {
        assert!(!points.is_empty(), "empty quality table");
        points.sort_by_key(|p| p.0);
        points.dedup_by_key(|p| p.0);
        assert!(points[0].0 >= 1, "table must start at steps >= 1");
        Self { points, outage }
    }

    /// Load the measured curve from `artifacts/quality.json`.
    pub fn from_quality_json(path: &Path) -> Result<Self> {
        let doc = load_quality_json(path)?;
        let curve = doc.required("curve")?.as_arr().context("curve not an array")?;
        let mut points = Vec::with_capacity(curve.len());
        for p in curve {
            let steps = p.required("steps")?.as_usize().context("steps")? as u32;
            let fd = p.required("fd")?.as_f64().context("fd")?;
            points.push((steps, fd));
        }
        // Outage: worst measured quality, scaled (see PowerLawQuality).
        let worst = points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
        Ok(Self::new(points, 1.5 * worst))
    }

    pub fn points(&self) -> &[(u32, f64)] {
        &self.points
    }
}

impl QualityModel for TableQuality {
    fn quality(&self, steps: u32) -> f64 {
        if steps == 0 {
            return self.outage;
        }
        let pts = &self.points;
        if steps <= pts[0].0 {
            // Below the measured range: connect linearly from (0, outage).
            let (s0, q0) = pts[0];
            if steps == s0 {
                return q0;
            }
            let w = steps as f64 / s0 as f64;
            return self.outage * (1.0 - w) + q0 * w;
        }
        if steps >= pts[pts.len() - 1].0 {
            // Beyond the measured range quality has flattened (Fig. 1b).
            return pts[pts.len() - 1].1;
        }
        let idx = pts.partition_point(|p| p.0 <= steps);
        let (s_lo, q_lo) = pts[idx - 1];
        let (s_hi, q_hi) = pts[idx];
        if steps == s_lo {
            return q_lo;
        }
        let w = (steps - s_lo) as f64 / (s_hi - s_lo) as f64;
        q_lo * (1.0 - w) + q_hi * w
    }

    fn outage(&self) -> f64 {
        self.outage
    }
}

fn load_quality_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn power_law_monotone_decreasing() {
        let q = PowerLawQuality::paper();
        let mut prev = q.quality(1);
        for t in 2..=100 {
            let cur = q.quality(t);
            assert!(cur < prev, "q not decreasing at T={t}");
            prev = cur;
        }
    }

    #[test]
    fn power_law_paper_regime() {
        let q = PowerLawQuality::paper();
        assert!(q.quality(1) > 250.0 && q.quality(1) < 350.0);
        assert!(q.quality(50) < 25.0);
        assert!(q.outage() > q.quality(1));
    }

    #[test]
    fn power_law_zero_steps_is_outage() {
        let q = PowerLawQuality::paper();
        assert_eq!(q.quality(0), q.outage());
    }

    #[test]
    fn table_interpolates_exactly_at_knots() {
        let t = TableQuality::new(vec![(1, 100.0), (4, 40.0), (16, 10.0)], 200.0);
        assert!(approx_eq(t.quality(1), 100.0, 1e-12));
        assert!(approx_eq(t.quality(4), 40.0, 1e-12));
        assert!(approx_eq(t.quality(16), 10.0, 1e-12));
    }

    #[test]
    fn table_interpolates_between_knots() {
        let t = TableQuality::new(vec![(1, 100.0), (3, 40.0)], 200.0);
        assert!(approx_eq(t.quality(2), 70.0, 1e-12));
    }

    #[test]
    fn table_flat_beyond_range_and_outage_below() {
        let t = TableQuality::new(vec![(2, 50.0), (8, 10.0)], 111.0);
        assert_eq!(t.quality(100), 10.0);
        assert_eq!(t.quality(0), 111.0);
        // steps=1 is between (0, outage) and (2, 50): midpoint
        assert!(approx_eq(t.quality(1), (111.0 + 50.0) / 2.0, 1e-12));
    }

    #[test]
    fn table_unsorted_input_ok() {
        let t = TableQuality::new(vec![(8, 10.0), (2, 50.0)], 99.0);
        assert_eq!(t.quality(2), 50.0);
        assert_eq!(t.quality(8), 10.0);
    }

    #[test]
    #[should_panic]
    fn table_rejects_empty() {
        TableQuality::new(vec![], 1.0);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/quality.json");
        if !path.exists() {
            return; // artifacts not built in this checkout
        }
        let pl = PowerLawQuality::from_quality_json(&path).unwrap();
        let tb = TableQuality::from_quality_json(&path).unwrap();
        // Both models must agree reasonably on the measured range.
        for t in [1u32, 2, 4, 8, 16, 32] {
            let a = pl.quality(t);
            let b = tb.quality(t);
            assert!((a - b).abs() / b < 0.35, "T={t}: power={a} table={b}");
        }
        assert!(pl.d > 0.0);
    }
}
