//! Chrome-trace-event JSON exporter: load the output in Perfetto
//! (ui.perfetto.dev) or `chrome://tracing`.
//!
//! Layout: one process per server. Thread 0 carries the epoch spans
//! with their batch slices nested inside, thread 1 the (P0) solve
//! spans (so pipelined solves visibly overlap the previous epoch's
//! execution), thread 2 zero-duration per-request anchors joined by
//! flow arrows route → admit → deliver — a request's hops across
//! servers (checkpoint migration) show up as arrows between tracks.
//!
//! Timestamps are sim-clock seconds scaled to microseconds. The export
//! is a pure function of the event stream, so a deterministic trace
//! exports bit-identically across runs (asserted in
//! `benches/obs_overhead.rs`).

use std::collections::{BTreeMap, BTreeSet};

use crate::obs::{EventKind, TraceEvent, NO_REQUEST};

/// Sim seconds → trace microseconds.
const US: f64 = 1e6;

fn x_line(pid: usize, tid: usize, ts: f64, dur: f64, name: &str) -> String {
    format!(
        "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{},\"dur\":{},\"name\":\"{name}\"}}",
        ts * US,
        dur * US
    )
}

fn flow_line(ph: char, pid: usize, tid: usize, ts: f64, id: usize, last: bool) -> String {
    let bp = if last { ",\"bp\":\"e\"" } else { "" };
    format!(
        "{{\"ph\":\"{ph}\",\"pid\":{pid},\"tid\":{tid},\"ts\":{},\"id\":{id},\
         \"cat\":\"req\",\"name\":\"r{id}\"{bp}}}",
        ts * US
    )
}

fn meta_process(pid: usize) -> String {
    format!(
        "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\
         \"args\":{{\"name\":\"server {pid}\"}}}}"
    )
}

fn meta_thread(pid: usize, tid: usize, name: &str) -> String {
    format!(
        "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
         \"args\":{{\"name\":\"{name}\"}}}}"
    )
}

/// Render a flight-recorder stream as Chrome trace-event JSON.
pub fn export(events: &[TraceEvent]) -> String {
    let mut evs: Vec<TraceEvent> = events.to_vec();
    evs.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap());

    let mut servers: BTreeSet<usize> = BTreeSet::new();
    // (server, epoch) -> [frozen, solve_start, solve_done, drained]
    let mut epochs: BTreeMap<(usize, usize), [Option<f64>; 4]> = BTreeMap::new();
    let mut batches: BTreeMap<usize, Vec<(f64, usize, usize)>> = BTreeMap::new();
    let mut drains: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let mut requests: BTreeMap<usize, Vec<TraceEvent>> = BTreeMap::new();

    for ev in &evs {
        servers.insert(ev.server);
        if let EventKind::Routed { server, .. } = ev.kind {
            servers.insert(server);
        }
        if ev.request != NO_REQUEST {
            requests.entry(ev.request).or_default().push(*ev);
            continue;
        }
        match ev.kind {
            EventKind::EpochFrozen { epoch } => {
                epochs.entry((ev.server, epoch)).or_default()[0] = Some(ev.t_s);
            }
            EventKind::SolveStart { epoch } => {
                epochs.entry((ev.server, epoch)).or_default()[1] = Some(ev.t_s);
            }
            EventKind::SolveDone { epoch } => {
                epochs.entry((ev.server, epoch)).or_default()[2] = Some(ev.t_s);
            }
            EventKind::EpochDone { epoch } => {
                epochs.entry((ev.server, epoch)).or_default()[3] = Some(ev.t_s);
                drains.entry(ev.server).or_default().push(ev.t_s);
            }
            EventKind::BatchStart { bucket, steps } => {
                batches.entry(ev.server).or_default().push((ev.t_s, bucket, steps));
            }
            _ => {}
        }
    }

    let mut lines: Vec<String> = Vec::new();
    for &s in &servers {
        lines.push(meta_process(s));
        lines.push(meta_thread(s, 0, "epochs"));
        lines.push(meta_thread(s, 1, "solve"));
        lines.push(meta_thread(s, 2, "requests"));
    }
    for (&(s, e), marks) in &epochs {
        if let (Some(frozen), Some(done)) = (marks[0], marks[3]) {
            lines.push(x_line(s, 0, frozen, done - frozen, &format!("epoch {e}")));
        }
        if let (Some(start), Some(done)) = (marks[1], marks[2]) {
            lines.push(x_line(s, 1, start, done - start, &format!("solve {e}")));
        }
    }
    for (&s, list) in &batches {
        let empty = Vec::new();
        let server_drains = drains.get(&s).unwrap_or(&empty);
        for (i, &(t, bucket, steps)) in list.iter().enumerate() {
            let next_batch = list.get(i + 1).map(|&(nt, _, _)| nt).unwrap_or(f64::INFINITY);
            let next_drain =
                server_drains.iter().copied().find(|&d| d >= t).unwrap_or(f64::INFINITY);
            let end = next_batch.min(next_drain);
            let dur = if end.is_finite() { end - t } else { 0.0 };
            lines.push(x_line(s, 0, t, dur, &format!("batch b{bucket} {steps} steps")));
        }
    }
    for (&r, list) in &requests {
        let last = list.len() - 1;
        for (i, ev) in list.iter().enumerate() {
            let name = format!("{} r{r}", ev.kind.name());
            lines.push(x_line(ev.server, 2, ev.t_s, 0.0, &name));
            if list.len() >= 2 {
                let ph = match i {
                    0 => 's',
                    _ if i == last => 'f',
                    _ => 't',
                };
                lines.push(flow_line(ph, ev.server, 2, ev.t_s, r, i == last));
            }
        }
    }

    let mut out = String::from("{\"traceEvents\":[\n");
    out.push_str(&lines.join(",\n"));
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn ev(t_s: f64, server: usize, request: usize, kind: EventKind) -> TraceEvent {
        TraceEvent { t_s, server, request, kind }
    }

    fn epoch_ev(t_s: f64, server: usize, kind: EventKind) -> TraceEvent {
        TraceEvent { t_s, server, request: NO_REQUEST, kind }
    }

    fn sample() -> Vec<TraceEvent> {
        vec![
            ev(0.0, 1, 0, EventKind::Arrived),
            ev(0.0, 1, 0, EventKind::Routed { server: 1, score: 0.25 }),
            epoch_ev(0.5, 1, EventKind::EpochFrozen { epoch: 0 }),
            epoch_ev(0.5, 1, EventKind::SolveStart { epoch: 0 }),
            epoch_ev(0.6, 1, EventKind::SolveDone { epoch: 0 }),
            ev(0.6, 1, 0, EventKind::Admitted { epoch: 0 }),
            epoch_ev(0.6, 1, EventKind::BatchStart { bucket: 1, steps: 8 }),
            epoch_ev(1.4, 1, EventKind::EpochDone { epoch: 0 }),
            ev(1.8, 1, 0, EventKind::Delivered { steps: 8 }),
        ]
    }

    #[test]
    fn export_is_valid_json() {
        let text = export(&sample());
        let doc = json::parse(&text).expect("perfetto export must parse as JSON");
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        assert!(evs.len() > 8, "expected metadata + slices, got {}", evs.len());
        // Every entry has a phase tag.
        for e in evs {
            assert!(e.get("ph").and_then(|p| p.as_str()).is_some(), "{e:?}");
        }
    }

    #[test]
    fn export_is_deterministic_and_scaled() {
        let a = export(&sample());
        let b = export(&sample());
        assert_eq!(a, b);
        // 0.6 s SolveDone ⇒ 600000 µs appears as a number.
        assert!(a.contains("600000"), "{a}");
        assert!(a.contains("\"name\":\"epoch 0\""), "{a}");
        assert!(a.contains("\"name\":\"solve 0\""), "{a}");
        assert!(a.contains("batch b1 8 steps"), "{a}");
    }

    #[test]
    fn flow_arrows_span_route_to_delivery() {
        let text = export(&sample());
        assert!(text.contains("\"ph\":\"s\""), "flow start missing: {text}");
        assert!(text.contains("\"ph\":\"t\""), "flow step missing: {text}");
        assert!(text.contains("\"ph\":\"f\""), "flow finish missing: {text}");
        assert!(text.contains("\"name\":\"r0\""), "{text}");
    }

    #[test]
    fn empty_trace_exports_empty_array() {
        let text = export(&[]);
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("traceEvents").and_then(|v| v.as_arr()).map(|a| a.len()), Some(0));
    }
}
