//! Lifecycle-DFA validator for flight-recorder traces.
//!
//! A trace is a claim about what the engines did; this module checks
//! the claim against the service model's invariants:
//!
//! * per request, timestamps are monotone non-decreasing in emission
//!   order — except `Lost`, whose stamp is the request's absolute
//!   deadline and may be *backdated*: a request parked during a fleet
//!   outage expires at its deadline, but the engine only discovers
//!   that at the next recovery or at drain, after later events for the
//!   same id were already emitted;
//! * a request's first event is `Arrived`, exactly once;
//! * admission (and delivery) happen only after arrival; a `CacheHit`
//!   counts as the admission decision (the request bypasses the epoch
//!   batch, so no `Admitted` follows it);
//! * exactly one terminal disposition (`Delivered` / `Rejected` /
//!   `Expired` / `Lost`) per request, and nothing after it;
//! * `Resumed` only after `RetractedByDeath` (with the checkpoint
//!   `TransferStart` in between), and retraction only of admitted
//!   (in-flight) requests;
//! * per server, epochs freeze in order and each epoch's
//!   freeze ≤ solve start ≤ solve done ≤ drain;
//! * conservation of ids — every traced request reaches a terminal,
//!   and (when the expected population is known) the ids are exactly
//!   `0..n`.
//!
//! `tests/obs_audit.rs` drives this over random traces × routers ×
//! fault scripts × migration policies on both engines, which is what
//! makes the recorder itself trustworthy.

use std::collections::BTreeMap;

use crate::obs::{EventKind, TraceEvent, NO_REQUEST};

/// Outcome of an audit pass. `violations` is empty iff the trace
/// satisfies every lifecycle invariant.
#[derive(Debug, Clone, Default)]
pub struct AuditReport {
    /// Events inspected.
    pub events: usize,
    /// Distinct request ids observed.
    pub requests: usize,
    /// Human-readable invariant breaches, in discovery order.
    pub violations: Vec<String>,
}

impl AuditReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Multi-line summary for the CLI (`aigc-edge trace`).
    pub fn render(&self) -> String {
        let mut out = format!(
            "audit: {} events, {} requests, {} violation(s)\n",
            self.events,
            self.requests,
            self.violations.len()
        );
        for v in &self.violations {
            out.push_str("  violation: ");
            out.push_str(v);
            out.push('\n');
        }
        out
    }
}

#[derive(Debug, Default)]
struct ReqState {
    arrived: bool,
    admitted: bool,
    terminal: Option<&'static str>,
    /// Retraction seen, resume still outstanding.
    retracted: bool,
    /// Checkpoint transfer underway (retracted and shipped).
    in_transfer: bool,
    last_t: f64,
}

#[derive(Debug, Default, Clone, Copy)]
struct EpochMarks {
    frozen: Option<f64>,
    solve_start: Option<f64>,
    solve_done: Option<f64>,
    done: Option<f64>,
}

/// Validate a trace; ids are not required to be dense.
pub fn audit(events: &[TraceEvent]) -> AuditReport {
    audit_impl(events, None)
}

/// Validate a trace that should cover exactly the requests `0..n`.
pub fn audit_expecting(events: &[TraceEvent], n: usize) -> AuditReport {
    audit_impl(events, Some(n))
}

fn audit_impl(events: &[TraceEvent], expect_n: Option<usize>) -> AuditReport {
    let mut report = AuditReport { events: events.len(), ..Default::default() };
    let mut reqs: BTreeMap<usize, ReqState> = BTreeMap::new();
    let mut epochs: BTreeMap<(usize, usize), EpochMarks> = BTreeMap::new();

    for ev in events {
        if !ev.t_s.is_finite() {
            report.violations.push(format!(
                "non-finite timestamp {} on {} (request {})",
                ev.t_s,
                ev.kind.name(),
                ev.request
            ));
            continue;
        }
        if ev.request == NO_REQUEST {
            audit_epoch_event(ev, &mut epochs, &mut report.violations);
            continue;
        }
        let id = ev.request;
        let first = !reqs.contains_key(&id);
        let st = reqs.entry(id).or_default();
        if first {
            st.last_t = ev.t_s;
            if ev.kind != EventKind::Arrived {
                report.violations.push(format!(
                    "request {id}: first event is {}, not arrived",
                    ev.kind.name()
                ));
            }
        }
        // `Lost` mirrors the engine's resolution instant, which is the
        // request's absolute deadline and may precede already-emitted
        // events (see the module doc) — exempt it from monotonicity.
        if ev.t_s < st.last_t && ev.kind != EventKind::Lost {
            report.violations.push(format!(
                "request {id}: timestamps not monotone ({} at {} after {})",
                ev.kind.name(),
                ev.t_s,
                st.last_t
            ));
        }
        st.last_t = st.last_t.max(ev.t_s);
        if let Some(term) = st.terminal {
            report.violations.push(format!(
                "request {id}: {} after terminal {term}",
                ev.kind.name()
            ));
            continue;
        }
        match ev.kind {
            EventKind::Arrived => {
                if st.arrived {
                    report.violations.push(format!("request {id}: duplicate arrival"));
                }
                st.arrived = true;
            }
            EventKind::Routed { .. } => {
                if !st.arrived {
                    report.violations.push(format!("request {id}: routed before arrival"));
                }
            }
            EventKind::Admitted { .. } => {
                if !st.arrived {
                    report.violations.push(format!("request {id}: admitted before arrival"));
                }
                st.admitted = true;
            }
            EventKind::RetractedByDeath { .. } => {
                if !st.admitted {
                    report.violations.push(format!("request {id}: retracted but never admitted"));
                }
                if st.retracted {
                    report.violations.push(format!("request {id}: double retraction"));
                }
                st.retracted = true;
                st.in_transfer = false;
            }
            EventKind::TransferStart => {
                if !st.retracted {
                    report.violations.push(format!("request {id}: transfer without retraction"));
                }
                if st.in_transfer {
                    report.violations.push(format!("request {id}: double transfer start"));
                }
                st.in_transfer = true;
            }
            EventKind::Resumed { .. } => {
                if !st.retracted {
                    report.violations.push(format!("request {id}: resumed without retraction"));
                }
                st.retracted = false;
                st.in_transfer = false;
            }
            EventKind::CacheHit { .. } => {
                if !st.arrived {
                    report.violations.push(format!("request {id}: cache hit before arrival"));
                }
                // A hit bypasses the epoch batch, so no `Admitted` will
                // ever come — the hit itself is the admission decision
                // and licenses the eventual `Delivered`.
                st.admitted = true;
            }
            EventKind::Delivered { .. } => {
                if !st.admitted {
                    report.violations.push(format!("request {id}: delivered but never admitted"));
                }
                st.terminal = Some("delivered");
            }
            EventKind::Rejected => st.terminal = Some("rejected"),
            EventKind::Expired => st.terminal = Some("expired"),
            EventKind::Lost => st.terminal = Some("lost"),
            EventKind::EpochFrozen { .. }
            | EventKind::SolveStart { .. }
            | EventKind::SolveDone { .. }
            | EventKind::BatchStart { .. }
            | EventKind::EpochDone { .. } => {
                report.violations.push(format!(
                    "request {id}: epoch-scope event {} carries a request id",
                    ev.kind.name()
                ));
            }
        }
    }

    report.requests = reqs.len();
    for (id, st) in &reqs {
        if st.terminal.is_none() {
            report.violations.push(format!("request {id}: no terminal disposition"));
        }
    }
    if let Some(n) = expect_n {
        if reqs.len() != n {
            report.violations.push(format!(
                "id conservation: expected {n} requests, traced {}",
                reqs.len()
            ));
        }
        if let Some((&max_id, _)) = reqs.iter().next_back() {
            if max_id >= n {
                report.violations.push(format!(
                    "id conservation: request id {max_id} outside expected 0..{n}"
                ));
            }
        }
    }
    audit_epoch_order(&epochs, &mut report.violations);
    report
}

fn audit_epoch_event(
    ev: &TraceEvent,
    epochs: &mut BTreeMap<(usize, usize), EpochMarks>,
    violations: &mut Vec<String>,
) {
    let (epoch, which) = match ev.kind {
        EventKind::EpochFrozen { epoch } => (epoch, "epoch_frozen"),
        EventKind::SolveStart { epoch } => (epoch, "solve_start"),
        EventKind::SolveDone { epoch } => (epoch, "solve_done"),
        EventKind::EpochDone { epoch } => (epoch, "epoch_done"),
        // Batch slices carry no epoch id; their containment is visible
        // in the perfetto view but not re-derivable here.
        EventKind::BatchStart { .. } => return,
        _ => {
            violations.push(format!(
                "{} carries the epoch sentinel but is a request event",
                ev.kind.name()
            ));
            return;
        }
    };
    let m = epochs.entry((ev.server, epoch)).or_default();
    let slot = match which {
        "epoch_frozen" => &mut m.frozen,
        "solve_start" => &mut m.solve_start,
        "solve_done" => &mut m.solve_done,
        _ => &mut m.done,
    };
    if slot.replace(ev.t_s).is_some() {
        violations.push(format!("server {} epoch {epoch}: duplicate {which}", ev.server));
    }
}

fn audit_epoch_order(epochs: &BTreeMap<(usize, usize), EpochMarks>, violations: &mut Vec<String>) {
    let mut prev: Option<(usize, f64)> = None; // (server, last frozen t)
    for (&(server, epoch), m) in epochs {
        if let (Some(f), Some(s)) = (m.frozen, m.solve_start) {
            if s < f {
                violations.push(format!(
                    "server {server} epoch {epoch}: solve starts before freeze"
                ));
            }
        }
        if let (Some(s), Some(d)) = (m.solve_start, m.solve_done) {
            if d < s {
                violations.push(format!("server {server} epoch {epoch}: solve done before start"));
            }
        }
        if let (Some(d), Some(e)) = (m.solve_done, m.done) {
            if e < d {
                violations.push(format!(
                    "server {server} epoch {epoch}: drained before solve done"
                ));
            }
        }
        if let Some(f) = m.frozen {
            if let Some((ps, pf)) = prev {
                if ps == server && f < pf {
                    violations.push(format!(
                        "server {server} epoch {epoch}: freezes out of order ({f} after {pf})"
                    ));
                }
            }
            prev = Some((server, f));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_s: f64, request: usize, kind: EventKind) -> TraceEvent {
        TraceEvent { t_s, server: 0, request, kind }
    }

    fn epoch_ev(t_s: f64, kind: EventKind) -> TraceEvent {
        TraceEvent { t_s, server: 0, request: NO_REQUEST, kind }
    }

    fn good_trace() -> Vec<TraceEvent> {
        vec![
            ev(0.0, 0, EventKind::Arrived),
            ev(0.0, 0, EventKind::Routed { server: 0, score: 1.0 }),
            ev(0.4, 1, EventKind::Arrived),
            epoch_ev(1.0, EventKind::EpochFrozen { epoch: 0 }),
            epoch_ev(1.0, EventKind::SolveStart { epoch: 0 }),
            epoch_ev(1.2, EventKind::SolveDone { epoch: 0 }),
            ev(1.2, 0, EventKind::Admitted { epoch: 0 }),
            ev(1.2, 1, EventKind::Rejected),
            epoch_ev(1.2, EventKind::BatchStart { bucket: 1, steps: 10 }),
            epoch_ev(2.0, EventKind::EpochDone { epoch: 0 }),
            ev(2.5, 0, EventKind::Delivered { steps: 10 }),
        ]
    }

    #[test]
    fn clean_lifecycle_passes() {
        let report = audit(&good_trace());
        assert!(report.is_clean(), "{:?}", report.violations);
        assert_eq!(report.requests, 2);
        assert!(audit_expecting(&good_trace(), 2).is_clean());
    }

    #[test]
    fn checkpoint_lifecycle_passes() {
        let trace = vec![
            ev(0.0, 0, EventKind::Arrived),
            ev(0.0, 0, EventKind::Routed { server: 1, score: 0.0 }),
            ev(1.0, 0, EventKind::Admitted { epoch: 0 }),
            ev(1.5, 0, EventKind::RetractedByDeath { done_steps: 3 }),
            ev(1.5, 0, EventKind::TransferStart),
            ev(2.0, 0, EventKind::Resumed { server: 0 }),
            ev(2.0, 0, EventKind::Routed { server: 0, score: 0.0 }),
            ev(2.5, 0, EventKind::Admitted { epoch: 1 }),
            ev(3.0, 0, EventKind::Delivered { steps: 10 }),
        ];
        let report = audit(&trace);
        assert!(report.is_clean(), "{:?}", report.violations);
    }

    #[test]
    fn cache_hit_lifecycle_passes_and_requires_arrival() {
        let trace = vec![
            ev(0.0, 0, EventKind::Arrived),
            ev(0.0, 0, EventKind::Routed { server: 0, score: 0.0 }),
            ev(0.0, 0, EventKind::CacheHit { steps: 40 }),
            ev(0.6, 0, EventKind::Delivered { steps: 40 }),
        ];
        let report = audit(&trace);
        assert!(report.is_clean(), "{:?}", report.violations);
        let bad = vec![
            ev(0.0, 0, EventKind::CacheHit { steps: 40 }),
            ev(0.6, 0, EventKind::Delivered { steps: 40 }),
        ];
        let report = audit(&bad);
        assert!(report.violations.iter().any(|v| v.contains("first event")), "{report:?}");
    }

    #[test]
    fn flags_missing_arrival() {
        let trace = vec![
            ev(1.0, 4, EventKind::Admitted { epoch: 0 }),
            ev(2.0, 4, EventKind::Delivered { steps: 1 }),
        ];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("first event")), "{report:?}");
    }

    #[test]
    fn flags_double_terminal_and_events_after() {
        let trace = vec![
            ev(0.0, 0, EventKind::Arrived),
            ev(1.0, 0, EventKind::Admitted { epoch: 0 }),
            ev(2.0, 0, EventKind::Delivered { steps: 5 }),
            ev(3.0, 0, EventKind::Expired),
        ];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("after terminal")), "{report:?}");
    }

    #[test]
    fn flags_resume_without_retraction() {
        let trace = vec![
            ev(0.0, 0, EventKind::Arrived),
            ev(1.0, 0, EventKind::Resumed { server: 1 }),
            ev(2.0, 0, EventKind::Lost),
        ];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("resumed without")), "{report:?}");
    }

    #[test]
    fn backdated_lost_is_exempt_from_monotonicity() {
        // A parked request expires at its deadline (3.0) but the engine
        // only discovers it at the next recovery (5.0), after having
        // re-routed it — the Lost stamp legally precedes the Routed one.
        let trace = vec![
            ev(0.0, 0, EventKind::Arrived),
            ev(1.0, 0, EventKind::Admitted { epoch: 0 }),
            ev(2.0, 0, EventKind::RetractedByDeath { done_steps: 0 }),
            ev(5.0, 0, EventKind::Routed { server: 1, score: 0.0 }),
            ev(3.0, 0, EventKind::Lost),
        ];
        let report = audit(&trace);
        assert!(report.is_clean(), "{:?}", report.violations);
    }

    #[test]
    fn flags_non_monotone_timestamps() {
        let trace = vec![
            ev(5.0, 0, EventKind::Arrived),
            ev(4.0, 0, EventKind::Admitted { epoch: 0 }),
            ev(6.0, 0, EventKind::Delivered { steps: 1 }),
        ];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("monotone")), "{report:?}");
    }

    #[test]
    fn flags_missing_terminal_and_id_conservation() {
        let trace = vec![ev(0.0, 0, EventKind::Arrived)];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("no terminal")), "{report:?}");
        let report = audit_expecting(&good_trace(), 3);
        assert!(report.violations.iter().any(|v| v.contains("id conservation")), "{report:?}");
    }

    #[test]
    fn flags_epoch_order_breaches() {
        let trace = vec![
            epoch_ev(2.0, EventKind::EpochFrozen { epoch: 0 }),
            epoch_ev(1.0, EventKind::SolveStart { epoch: 0 }),
            epoch_ev(3.0, EventKind::SolveDone { epoch: 0 }),
        ];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("before freeze")), "{report:?}");
        let trace = vec![
            epoch_ev(2.0, EventKind::EpochFrozen { epoch: 0 }),
            epoch_ev(1.0, EventKind::EpochFrozen { epoch: 1 }),
        ];
        let report = audit(&trace);
        assert!(report.violations.iter().any(|v| v.contains("out of order")), "{report:?}");
    }

    #[test]
    fn render_mentions_counts() {
        let report = audit(&good_trace());
        let text = report.render();
        assert!(text.contains("2 requests"), "{text}");
        assert!(text.contains("0 violation"), "{text}");
    }
}
