//! Flight recorder: deterministic lifecycle tracing for all three
//! engines (`sim::dynamic`, `sim::cluster`, `sim::event`).
//!
//! Every state transition a request goes through — arrival, routing,
//! epoch freeze, admission or drop, solve, batch execution, delivery,
//! fault retraction, checkpoint transfer, resume — is emitted as a
//! typed, sim-clock-stamped [`TraceEvent`] into a [`TraceSink`]. The
//! default sink is [`NullSink`], a no-op: engines call it with values
//! they already computed, so the traced and untraced paths execute the
//! same float operations in the same order and outputs stay bitwise
//! identical (gated by `benches/obs_overhead.rs`).
//!
//! On top of the raw stream:
//! * [`span`] — a compact columnar binary span format (same framing
//!   discipline as `trace::columnar`), written by `--trace-spans`;
//! * [`perfetto`] — a Chrome-trace-event JSON exporter (servers as
//!   tracks, epochs as nested spans, per-request flow arrows);
//! * [`audit`] — a lifecycle-DFA validator doubling as a correctness
//!   harness (`tests/obs_audit.rs` drives it over random traces ×
//!   routers × fault scripts × migration policies);
//! * [`telemetry`] — derived per-server time series (queue depth,
//!   GPU-busy, solve overlap, bandwidth share) over
//!   `metrics::window::WindowedSeries`.

pub mod audit;
pub mod perfetto;
pub mod span;
pub mod telemetry;

/// Sentinel request id for epoch-scope events (freeze, solve, batch):
/// they belong to a server timeline, not to any single request.
pub const NO_REQUEST: usize = usize::MAX;

/// What happened. Payload fields carry only values the engine had
/// already computed at the emission site — recording must never force
/// extra work on the hot path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Request entered the system (trace timestamp `t_s`).
    Arrived,
    /// Router picked a server; `score` is the router's figure of merit
    /// for the choice (0 for routers that don't score, e.g. RR).
    Routed { server: usize, score: f64 },
    /// Request made it into a frozen epoch's admitted set.
    Admitted { epoch: usize },
    /// Dropped at admission: residual deadline below the service floor.
    Rejected,
    /// Dropped at admission: deadline already passed while queued.
    Expired,
    /// Epoch closed its arrival window and handed off to the solver.
    EpochFrozen { epoch: usize },
    /// Joint (P0) solve for the epoch began.
    SolveStart { epoch: usize },
    /// Joint (P0) solve for the epoch finished.
    SolveDone { epoch: usize },
    /// A batch bucket started executing on the GPU.
    BatchStart { bucket: usize, steps: usize },
    /// Epoch's GPU execution drained (the instant `gpu_free` advances to).
    EpochDone { epoch: usize },
    /// Request delivered to the user (end of transmission).
    Delivered { steps: usize },
    /// Request lost to a failure with no recovery path.
    Lost,
    /// In-flight request pulled back from a dying server's executing
    /// batch; `done_steps` were salvaged at the last step boundary.
    RetractedByDeath { done_steps: usize },
    /// Checkpoint latent transfer to a new server began.
    TransferStart,
    /// Checkpointed request re-entered service on `server`.
    Resumed { server: usize },
    /// Generation-cache hit at admission: the request bypasses the
    /// epoch batch and pays only transmission; `steps` is the cached
    /// entry's step count (what the delivered quality is charged at).
    CacheHit { steps: usize },
}

impl EventKind {
    /// Stable wire code for the span format. Append-only: codes are
    /// persisted in span files and must never be renumbered.
    pub fn code(self) -> u32 {
        match self {
            EventKind::Arrived => 0,
            EventKind::Routed { .. } => 1,
            EventKind::Admitted { .. } => 2,
            EventKind::Rejected => 3,
            EventKind::Expired => 4,
            EventKind::EpochFrozen { .. } => 5,
            EventKind::SolveStart { .. } => 6,
            EventKind::SolveDone { .. } => 7,
            EventKind::BatchStart { .. } => 8,
            EventKind::EpochDone { .. } => 9,
            EventKind::Delivered { .. } => 10,
            EventKind::Lost => 11,
            EventKind::RetractedByDeath { .. } => 12,
            EventKind::TransferStart => 13,
            EventKind::Resumed { .. } => 14,
            EventKind::CacheHit { .. } => 15,
        }
    }

    /// Human-readable tag (span summaries, audit messages).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Arrived => "arrived",
            EventKind::Routed { .. } => "routed",
            EventKind::Admitted { .. } => "admitted",
            EventKind::Rejected => "rejected",
            EventKind::Expired => "expired",
            EventKind::EpochFrozen { .. } => "epoch_frozen",
            EventKind::SolveStart { .. } => "solve_start",
            EventKind::SolveDone { .. } => "solve_done",
            EventKind::BatchStart { .. } => "batch_start",
            EventKind::EpochDone { .. } => "epoch_done",
            EventKind::Delivered { .. } => "delivered",
            EventKind::Lost => "lost",
            EventKind::RetractedByDeath { .. } => "retracted_by_death",
            EventKind::TransferStart => "transfer_start",
            EventKind::Resumed { .. } => "resumed",
            EventKind::CacheHit { .. } => "cache_hit",
        }
    }

    /// Terminal dispositions: after one of these a request id must
    /// never appear again (audited).
    pub fn is_terminal(self) -> bool {
        match self {
            EventKind::Delivered { .. } => true,
            EventKind::Rejected | EventKind::Expired | EventKind::Lost => true,
            _ => false,
        }
    }
}

/// One lifecycle event. `t_s` is the sim clock (never wall clock), so
/// traces replay bit-identically across runs. `server` is the fleet
/// index (0 for the single-server dynamic engine until a cluster merge
/// remaps it); `request` is the global request id, or [`NO_REQUEST`]
/// for epoch-scope events.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t_s: f64,
    pub server: usize,
    pub request: usize,
    pub kind: EventKind,
}

/// Receiver for lifecycle events. Implementations only observe — they
/// must never influence the serving loop (same contract as
/// `sim::dynamic::OutcomeSink`).
pub trait TraceSink {
    fn record(&mut self, ev: TraceEvent);

    /// `false` for [`NullSink`]: lets emission sites skip loops whose
    /// only purpose is building events (e.g. per-batch coalescing).
    /// Single-event sites call [`emit`](Self::emit) unconditionally —
    /// the payloads are values the engine already had.
    fn enabled(&self) -> bool {
        true
    }

    /// Build and record an event in one call — the form every engine
    /// emission site uses.
    fn emit(&mut self, t_s: f64, server: usize, request: usize, kind: EventKind) {
        self.record(TraceEvent { t_s, server, request, kind });
    }
}

/// The default sink: discards everything. With this sink the traced
/// entry points are observationally identical to the untraced ones.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline]
    fn record(&mut self, _ev: TraceEvent) {}

    #[inline]
    fn enabled(&self) -> bool {
        false
    }
}

/// In-memory capture, in emission order.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    pub events: Vec<TraceEvent>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stable time sort. Emission order is deterministic but not
    /// globally time-sorted (an epoch's `Delivered` stamps lie in the
    /// future of the commit instant; per-server streams interleave) —
    /// exporters sort first so timelines read left-to-right. Stability
    /// preserves the deterministic emission order within a tie.
    pub fn sort_by_time(&mut self) {
        self.events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap());
    }
}

impl TraceSink for Recorder {
    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
}

/// Rewrite a per-server capture into fleet coordinates: `server`
/// replaces the placeholder index and request ids map through
/// `id_map` (sub-trace id → global id). Epoch-scope events keep
/// [`NO_REQUEST`]. Used by the cluster engine's merge.
pub fn remap(events: &mut [TraceEvent], server: usize, id_map: &[usize]) {
    for ev in events.iter_mut() {
        ev.server = server;
        if ev.request != NO_REQUEST {
            ev.request = id_map[ev.request];
        }
        if let EventKind::Routed { server: s, .. } = &mut ev.kind {
            *s = server;
        }
        if let EventKind::Resumed { server: s } = &mut ev.kind {
            *s = server;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_reports_disabled() {
        let mut s = NullSink;
        assert!(!s.enabled());
        s.record(TraceEvent { t_s: 1.0, server: 0, request: 0, kind: EventKind::Arrived });
    }

    #[test]
    fn recorder_captures_in_order() {
        let mut r = Recorder::new();
        for i in 0..4 {
            r.record(TraceEvent {
                t_s: 4.0 - i as f64,
                server: 0,
                request: i,
                kind: EventKind::Arrived,
            });
        }
        assert_eq!(r.events.len(), 4);
        assert_eq!(r.events[0].t_s, 4.0);
        r.sort_by_time();
        assert_eq!(r.events[0].t_s, 1.0);
        assert_eq!(r.events[3].t_s, 4.0);
    }

    #[test]
    fn codes_are_distinct_and_stable() {
        let kinds = [
            EventKind::Arrived,
            EventKind::Routed { server: 0, score: 0.0 },
            EventKind::Admitted { epoch: 0 },
            EventKind::Rejected,
            EventKind::Expired,
            EventKind::EpochFrozen { epoch: 0 },
            EventKind::SolveStart { epoch: 0 },
            EventKind::SolveDone { epoch: 0 },
            EventKind::BatchStart { bucket: 0, steps: 0 },
            EventKind::EpochDone { epoch: 0 },
            EventKind::Delivered { steps: 0 },
            EventKind::Lost,
            EventKind::RetractedByDeath { done_steps: 0 },
            EventKind::TransferStart,
            EventKind::Resumed { server: 0 },
            EventKind::CacheHit { steps: 0 },
        ];
        let codes: Vec<u32> = kinds.iter().map(|k| k.code()).collect();
        let mut sorted = codes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), kinds.len(), "codes must be unique");
        assert_eq!(codes, (0..kinds.len() as u32).collect::<Vec<_>>(), "codes are dense");
    }

    #[test]
    fn remap_rewrites_ids_and_server() {
        let mut events = vec![
            TraceEvent { t_s: 0.0, server: 0, request: 0, kind: EventKind::Arrived },
            TraceEvent {
                t_s: 0.0,
                server: 0,
                request: 1,
                kind: EventKind::Routed { server: 0, score: 2.5 },
            },
            TraceEvent {
                t_s: 1.0,
                server: 0,
                request: NO_REQUEST,
                kind: EventKind::EpochFrozen { epoch: 0 },
            },
        ];
        remap(&mut events, 3, &[7, 9]);
        assert_eq!(events[0].request, 7);
        assert_eq!(events[0].server, 3);
        assert_eq!(events[1].request, 9);
        assert_eq!(events[1].kind, EventKind::Routed { server: 3, score: 2.5 });
        assert_eq!(events[2].request, NO_REQUEST, "epoch events keep the sentinel");
        assert_eq!(events[2].server, 3);
    }
}
