//! Derived live telemetry: replay a flight-recorder stream into
//! per-server sliding-window time series.
//!
//! Nothing here touches the engines — the series are derived entirely
//! from the [`TraceEvent`] stream, sampled at event boundaries:
//!
//! * **queue depth** — one sample per enqueue/dequeue transition;
//! * **GPU-busy fraction** — one sample per epoch drain (busy time
//!   accumulated from batch slices over the inter-drain span);
//! * **solve overlap** — per solve, the portion of the solve span that
//!   ran while the GPU was executing batches (the pipeline's hidden
//!   time), as total/hidden series like
//!   `metrics::window::ServiceWindows`;
//! * **bandwidth share** — per-server delivered-request counts, so a
//!   server's share of the fleet's transmission work is a windowed
//!   ratio.
//!
//! The CLI (`--trace-spans`, `aigc-edge trace`) and the TCP server's
//! STATS reply both surface [`FleetTelemetry::summary`].

use std::collections::BTreeMap;

use crate::metrics::window::WindowedSeries;
use crate::obs::{EventKind, TraceEvent, NO_REQUEST};

/// Windowed series for one server's timeline.
#[derive(Debug, Clone)]
pub struct ServerTelemetry {
    /// Queue depth sampled at every enqueue/dequeue boundary.
    pub queue_depth: WindowedSeries,
    /// Busy fraction sampled at each epoch drain.
    pub gpu_busy: WindowedSeries,
    /// Solve latency charged per solve, seconds.
    pub solve_total_s: WindowedSeries,
    /// Portion of each solve hidden behind batch execution, seconds.
    pub solve_hidden_s: WindowedSeries,
    /// One sample per delivered request.
    pub delivered: WindowedSeries,
}

impl ServerTelemetry {
    fn new(window_s: f64) -> Self {
        Self {
            queue_depth: WindowedSeries::new(window_s),
            gpu_busy: WindowedSeries::new(window_s),
            solve_total_s: WindowedSeries::new(window_s),
            solve_hidden_s: WindowedSeries::new(window_s),
            delivered: WindowedSeries::new(window_s),
        }
    }

    /// Hidden solve time / total solve time over the window (same
    /// definition as `ServiceWindows::solve_overlap_fraction`).
    pub fn solve_overlap_fraction(&self) -> f64 {
        let total = self.solve_total_s.sum();
        if total <= 0.0 {
            0.0
        } else {
            self.solve_hidden_s.sum() / total
        }
    }
}

/// Per-replay scratch state for one server.
#[derive(Debug, Default)]
struct Replay {
    depth: usize,
    /// Closed batch-execution intervals not yet aged past all solves.
    busy: Vec<(f64, f64)>,
    /// Start of the batch currently executing, if any.
    open_batch: Option<f64>,
    /// Busy seconds accumulated in the current inter-drain span.
    busy_in_span: f64,
    /// Start of the current inter-drain span.
    span_start: Option<f64>,
    /// Start of the in-flight solve, if any.
    open_solve: Option<f64>,
}

impl Replay {
    fn close_batch(&mut self, t: f64) {
        if let Some(a) = self.open_batch.take() {
            self.busy.push((a, t));
            self.busy_in_span += t - a;
        }
    }

    fn hidden_overlap(&self, s: f64, d: f64) -> f64 {
        let mut h = 0.0;
        for &(a, b) in &self.busy {
            h += (b.min(d) - a.max(s)).max(0.0);
        }
        if let Some(a) = self.open_batch {
            h += (d - a.max(s)).max(0.0);
        }
        h
    }
}

/// Move a request between server queues (or out of them entirely),
/// pushing a depth sample for every queue whose depth changed.
fn move_queued(
    queued: &mut BTreeMap<usize, usize>,
    replay: &mut [Replay],
    servers: &mut [ServerTelemetry],
    id: usize,
    dest: Option<usize>,
    t: f64,
) {
    let prev = match dest {
        Some(s) => queued.insert(id, s),
        None => queued.remove(&id),
    };
    if prev == dest {
        return;
    }
    if let Some(old) = prev {
        replay[old].depth = replay[old].depth.saturating_sub(1);
        servers[old].queue_depth.push(t, replay[old].depth as f64);
    }
    if let Some(new) = dest {
        replay[new].depth += 1;
        servers[new].queue_depth.push(t, replay[new].depth as f64);
    }
}

/// Fleet-wide derived telemetry.
#[derive(Debug, Clone)]
pub struct FleetTelemetry {
    pub window_s: f64,
    pub servers: Vec<ServerTelemetry>,
}

impl FleetTelemetry {
    /// Replay a trace into windowed series. Events are sorted by sim
    /// time first (emission order stamps deliveries ahead of the
    /// commit instant). The fleet size is inferred from the largest
    /// server index observed, including routing destinations.
    pub fn from_events(events: &[TraceEvent], window_s: f64) -> Self {
        let mut evs: Vec<TraceEvent> = events.to_vec();
        evs.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap());
        let n = evs
            .iter()
            .map(|e| {
                let dest = match e.kind {
                    EventKind::Routed { server, .. } => server,
                    EventKind::Resumed { server } => server,
                    _ => 0,
                };
                e.server.max(dest) + 1
            })
            .max()
            .unwrap_or(0);
        let mut servers: Vec<ServerTelemetry> =
            (0..n).map(|_| ServerTelemetry::new(window_s)).collect();
        let mut replay: Vec<Replay> = (0..n).map(|_| Replay::default()).collect();
        // Request id -> server whose queue currently holds it.
        let mut queued: BTreeMap<usize, usize> = BTreeMap::new();

        for ev in &evs {
            let s = ev.server;
            if replay[s].span_start.is_none() {
                replay[s].span_start = Some(ev.t_s);
            }
            match ev.kind {
                EventKind::Arrived => {
                    move_queued(
                        &mut queued,
                        &mut replay,
                        &mut servers,
                        ev.request,
                        Some(s),
                        ev.t_s,
                    );
                }
                EventKind::Routed { server: dest, .. } => {
                    move_queued(
                        &mut queued,
                        &mut replay,
                        &mut servers,
                        ev.request,
                        Some(dest),
                        ev.t_s,
                    );
                }
                EventKind::Admitted { .. }
                | EventKind::Rejected
                | EventKind::Expired
                | EventKind::Lost => {
                    move_queued(&mut queued, &mut replay, &mut servers, ev.request, None, ev.t_s);
                }
                EventKind::SolveStart { .. } => replay[s].open_solve = Some(ev.t_s),
                EventKind::SolveDone { .. } => {
                    if let Some(start) = replay[s].open_solve.take() {
                        let total = ev.t_s - start;
                        let hidden = replay[s].hidden_overlap(start, ev.t_s);
                        servers[s].solve_total_s.push(ev.t_s, total);
                        servers[s].solve_hidden_s.push(ev.t_s, hidden.min(total));
                        replay[s].busy.retain(|&(_, b)| b > start);
                    }
                }
                EventKind::BatchStart { .. } => {
                    replay[s].close_batch(ev.t_s);
                    replay[s].open_batch = Some(ev.t_s);
                }
                EventKind::EpochDone { .. } => {
                    replay[s].close_batch(ev.t_s);
                    let span_start = replay[s].span_start.unwrap_or(ev.t_s);
                    let span = ev.t_s - span_start;
                    if span > 0.0 {
                        let frac = (replay[s].busy_in_span / span).min(1.0);
                        servers[s].gpu_busy.push(ev.t_s, frac);
                    }
                    replay[s].busy_in_span = 0.0;
                    replay[s].span_start = Some(ev.t_s);
                }
                EventKind::Delivered { .. } => {
                    move_queued(&mut queued, &mut replay, &mut servers, ev.request, None, ev.t_s);
                    servers[s].delivered.push(ev.t_s, 1.0);
                }
                EventKind::EpochFrozen { .. }
                | EventKind::RetractedByDeath { .. }
                | EventKind::TransferStart
                | EventKind::Resumed { .. } => {}
                // A cache hit never queues; delivery (which the queue
                // replay keys on) follows as its own event.
                EventKind::CacheHit { .. } => {}
            }
        }
        Self { window_s, servers }
    }

    /// This server's share of fleet-wide deliveries in the window;
    /// 0 when nothing has been delivered anywhere.
    pub fn bandwidth_share(&self, server: usize) -> f64 {
        let total: usize = self.servers.iter().map(|s| s.delivered.count()).sum();
        if total == 0 || server >= self.servers.len() {
            return 0.0;
        }
        self.servers[server].delivered.count() as f64 / total as f64
    }

    /// Per-server one-liners for CLI summaries and the STATS reply.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (i, s) in self.servers.iter().enumerate() {
            out.push_str(&format!(
                "server {i}: depth_last {:.0} depth_p95 {:.1} gpu_busy {:.3} \
                 solve_overlap {:.3} delivered {} bw_share {:.3}\n",
                s.queue_depth.last().unwrap_or(0.0),
                s.queue_depth.percentile(95.0),
                s.gpu_busy.last().unwrap_or(0.0),
                s.solve_overlap_fraction(),
                s.delivered.count(),
                self.bandwidth_share(i)
            ));
        }
        out
    }
}

/// Compact per-kind counts for `aigc-edge trace`.
pub fn kind_counts(events: &[TraceEvent]) -> String {
    let mut counts: BTreeMap<&'static str, usize> = BTreeMap::new();
    for ev in events {
        *counts.entry(ev.kind.name()).or_default() += 1;
    }
    let max_id = events.iter().filter(|e| e.request != NO_REQUEST).map(|e| e.request).max();
    let mut out =
        format!("events: {} (request ids: {})\n", events.len(), max_id.map_or(0, |m| m + 1));
    for (name, n) in counts {
        out.push_str(&format!("  {name}: {n}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_s: f64, server: usize, request: usize, kind: EventKind) -> TraceEvent {
        TraceEvent { t_s, server, request, kind }
    }

    fn epoch_ev(t_s: f64, server: usize, kind: EventKind) -> TraceEvent {
        TraceEvent { t_s, server, request: NO_REQUEST, kind }
    }

    /// Hand-built two-epoch schedule on one server with pinned values:
    /// epoch 0 solves in the open ([1.0, 1.5], nothing to hide
    /// behind), executes [1.5, 3.5]; epoch 1's solve [3.0, 3.4] runs
    /// entirely inside epoch 0's batch window, so 0.4 of the 0.9 total
    /// solve seconds are hidden.
    fn two_epoch_events() -> Vec<TraceEvent> {
        vec![
            ev(0.0, 0, 0, EventKind::Arrived),
            ev(0.2, 0, 1, EventKind::Arrived),
            epoch_ev(1.0, 0, EventKind::EpochFrozen { epoch: 0 }),
            epoch_ev(1.0, 0, EventKind::SolveStart { epoch: 0 }),
            epoch_ev(1.5, 0, EventKind::SolveDone { epoch: 0 }),
            ev(1.5, 0, 0, EventKind::Admitted { epoch: 0 }),
            ev(1.5, 0, 1, EventKind::Admitted { epoch: 0 }),
            epoch_ev(1.5, 0, EventKind::BatchStart { bucket: 2, steps: 10 }),
            epoch_ev(2.5, 0, EventKind::BatchStart { bucket: 1, steps: 4 }),
            epoch_ev(3.0, 0, EventKind::SolveStart { epoch: 1 }),
            epoch_ev(3.4, 0, EventKind::SolveDone { epoch: 1 }),
            epoch_ev(3.5, 0, EventKind::EpochDone { epoch: 0 }),
            ev(4.0, 0, 0, EventKind::Delivered { steps: 10 }),
            ev(4.2, 0, 1, EventKind::Delivered { steps: 10 }),
        ]
    }

    #[test]
    fn two_epoch_schedule_pins_derived_values() {
        let t = FleetTelemetry::from_events(&two_epoch_events(), 100.0);
        assert_eq!(t.servers.len(), 1);
        let s = &t.servers[0];
        // Queue: 0→1 at arrival 0, →2 at 0.2, →1 and →0 at admission.
        assert_eq!(s.queue_depth.count(), 4);
        assert_eq!(s.queue_depth.max(), 2.0);
        assert_eq!(s.queue_depth.last(), Some(0.0));
        // GPU busy: batches cover [1.5, 3.5] of the [0.0, 3.5] span.
        assert_eq!(s.gpu_busy.count(), 1);
        assert!((s.gpu_busy.last().unwrap() - 2.0 / 3.5).abs() < 1e-12);
        // Solves: 0.5 s exposed + 0.4 s fully hidden ⇒ 0.4 / 0.9.
        assert_eq!(s.solve_total_s.count(), 2);
        assert!((s.solve_total_s.sum() - 0.9).abs() < 1e-12);
        assert!((s.solve_hidden_s.sum() - 0.4).abs() < 1e-12);
        assert!((s.solve_overlap_fraction() - 0.4 / 0.9).abs() < 1e-12);
        // Both deliveries land here ⇒ full bandwidth share.
        assert_eq!(s.delivered.count(), 2);
        assert!((t.bandwidth_share(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_yields_empty_fleet() {
        let t = FleetTelemetry::from_events(&[], 10.0);
        assert!(t.servers.is_empty());
        assert_eq!(t.bandwidth_share(0), 0.0);
        assert_eq!(t.summary(), "");
    }

    #[test]
    fn single_sample_edges_stay_finite() {
        let events = vec![ev(2.0, 0, 0, EventKind::Arrived)];
        let t = FleetTelemetry::from_events(&events, 10.0);
        let s = &t.servers[0];
        assert_eq!(s.queue_depth.count(), 1);
        assert_eq!(s.queue_depth.last(), Some(1.0));
        assert_eq!(s.gpu_busy.count(), 0);
        assert_eq!(s.solve_overlap_fraction(), 0.0);
        assert_eq!(t.bandwidth_share(0), 0.0);
        let line = t.summary();
        assert!(line.contains("server 0"), "{line}");
    }

    #[test]
    fn routed_moves_depth_between_servers() {
        let events = vec![
            ev(0.0, 0, 0, EventKind::Arrived),
            ev(0.0, 0, 0, EventKind::Routed { server: 1, score: 0.5 }),
            ev(1.0, 1, 0, EventKind::Admitted { epoch: 0 }),
            ev(2.0, 1, 0, EventKind::Delivered { steps: 3 }),
        ];
        let t = FleetTelemetry::from_events(&events, 100.0);
        assert_eq!(t.servers.len(), 2);
        assert_eq!(t.servers[0].queue_depth.last(), Some(0.0));
        assert_eq!(t.servers[1].queue_depth.last(), Some(0.0));
        assert_eq!(t.servers[1].queue_depth.max(), 1.0);
        assert!((t.bandwidth_share(1) - 1.0).abs() < 1e-12);
        assert_eq!(t.bandwidth_share(0), 0.0);
    }

    #[test]
    fn kind_counts_lists_every_kind_once() {
        let text = kind_counts(&two_epoch_events());
        assert!(text.contains("arrived: 2"), "{text}");
        assert!(text.contains("batch_start: 2"), "{text}");
        assert!(text.contains("delivered: 2"), "{text}");
        assert!(text.contains("request ids: 2"), "{text}");
    }
}
