//! Compact columnar binary span format for flight-recorder traces.
//!
//! Same framing discipline as `trace::columnar` (magic, version,
//! chunked column-major frames, little-endian fixed-width fields, f64
//! bit patterns preserved exactly):
//!
//! ```text
//! [magic 8B "AIGCSPN\0"] [version u32] [chunk_len u32] [count u64]
//! repeated frames:
//!   [n u32] [code u32 × n] [t_s f64 × n] [server u64 × n]
//!   [request u64 × n] [payload_a f64 × n] [payload_b f64 × n]
//! ```
//!
//! 44 bytes per event. Round-trips are bit-identical: every payload is
//! either an exact small integer (epochs, buckets, steps, ids — far
//! below 2^53) or a raw f64 (router scores) stored by bit pattern.
//! This is what `--trace-spans <path>` writes and `aigc-edge trace`
//! reads back.

use anyhow::{bail, ensure, Result};

use crate::obs::{EventKind, TraceEvent};
use crate::trace::columnar::{push_f64, push_u32, push_u64, read_f64, read_u32, read_u64};

const MAGIC: &[u8; 8] = b"AIGCSPN\0";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 8 + 4 + 4 + 8;
const ROW_LEN: usize = 4 + 8 + 8 + 8 + 8 + 8;
/// Events per frame (~360 KiB of payload per frame).
pub const DEFAULT_CHUNK_LEN: usize = 8192;

/// The two generic payload slots an event's kind-specific fields are
/// flattened into for the wire.
fn payload(kind: EventKind) -> (f64, f64) {
    match kind {
        EventKind::Arrived
        | EventKind::Rejected
        | EventKind::Expired
        | EventKind::Lost
        | EventKind::TransferStart => (0.0, 0.0),
        EventKind::Routed { server, score } => (server as f64, score),
        EventKind::Admitted { epoch }
        | EventKind::EpochFrozen { epoch }
        | EventKind::SolveStart { epoch }
        | EventKind::SolveDone { epoch }
        | EventKind::EpochDone { epoch } => (epoch as f64, 0.0),
        EventKind::BatchStart { bucket, steps } => (bucket as f64, steps as f64),
        EventKind::Delivered { steps } => (steps as f64, 0.0),
        EventKind::RetractedByDeath { done_steps } => (done_steps as f64, 0.0),
        EventKind::Resumed { server } => (server as f64, 0.0),
        EventKind::CacheHit { steps } => (steps as f64, 0.0),
    }
}

fn rebuild(code: u32, a: f64, b: f64) -> Result<EventKind> {
    Ok(match code {
        0 => EventKind::Arrived,
        1 => EventKind::Routed { server: a as usize, score: b },
        2 => EventKind::Admitted { epoch: a as usize },
        3 => EventKind::Rejected,
        4 => EventKind::Expired,
        5 => EventKind::EpochFrozen { epoch: a as usize },
        6 => EventKind::SolveStart { epoch: a as usize },
        7 => EventKind::SolveDone { epoch: a as usize },
        8 => EventKind::BatchStart { bucket: a as usize, steps: b as usize },
        9 => EventKind::EpochDone { epoch: a as usize },
        10 => EventKind::Delivered { steps: a as usize },
        11 => EventKind::Lost,
        12 => EventKind::RetractedByDeath { done_steps: a as usize },
        13 => EventKind::TransferStart,
        14 => EventKind::Resumed { server: a as usize },
        15 => EventKind::CacheHit { steps: a as usize },
        other => bail!("span trace: unknown event code {other}"),
    })
}

/// Encode a span stream with the given chunk length (events per frame).
pub fn encode_chunked(events: &[TraceEvent], chunk_len: usize) -> Vec<u8> {
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert!(chunk_len <= u32::MAX as usize, "chunk_len {chunk_len} exceeds the u32 frame header");
    let n = events.len();
    let mut out = Vec::with_capacity(HEADER_LEN + n * ROW_LEN + (n / chunk_len + 1) * 4);
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, VERSION);
    push_u32(&mut out, chunk_len as u32);
    push_u64(&mut out, n as u64);
    for chunk in events.chunks(chunk_len) {
        push_u32(&mut out, chunk.len() as u32);
        for ev in chunk {
            push_u32(&mut out, ev.kind.code());
        }
        for ev in chunk {
            push_f64(&mut out, ev.t_s);
        }
        for ev in chunk {
            push_u64(&mut out, ev.server as u64);
        }
        for ev in chunk {
            push_u64(&mut out, ev.request as u64);
        }
        for ev in chunk {
            push_f64(&mut out, payload(ev.kind).0);
        }
        for ev in chunk {
            push_f64(&mut out, payload(ev.kind).1);
        }
    }
    out
}

/// Encode with the default chunk length.
pub fn encode(events: &[TraceEvent]) -> Vec<u8> {
    encode_chunked(events, DEFAULT_CHUNK_LEN)
}

/// Decode a complete span stream.
pub fn decode(bytes: &[u8]) -> Result<Vec<TraceEvent>> {
    let mut pos = 0usize;
    ensure!(bytes.len() >= HEADER_LEN, "span trace shorter than its header");
    ensure!(&bytes[..8] == MAGIC, "not a span trace (bad magic)");
    pos += 8;
    let version = read_u32(bytes, &mut pos)?;
    ensure!(version == VERSION, "unsupported span trace version {version}");
    let chunk_len = read_u32(bytes, &mut pos)?;
    ensure!(chunk_len > 0, "span trace declares zero chunk length");
    let count = read_u64(bytes, &mut pos)? as usize;
    let mut events = Vec::with_capacity(count);
    while events.len() < count {
        let n = read_u32(bytes, &mut pos)? as usize;
        ensure!(n > 0, "span trace frame at byte {} is empty", pos - 4);
        ensure!(events.len() + n <= count, "span trace frames exceed declared count {count}");
        let base = pos;
        let (codes_at, t_at) = (base, base + 4 * n);
        let server_at = t_at + 8 * n;
        let request_at = server_at + 8 * n;
        let a_at = request_at + 8 * n;
        let b_at = a_at + 8 * n;
        for i in 0..n {
            let mut p = codes_at + 4 * i;
            let code = read_u32(bytes, &mut p)?;
            let mut p = t_at + 8 * i;
            let t_s = read_f64(bytes, &mut p)?;
            let mut p = server_at + 8 * i;
            let server = read_u64(bytes, &mut p)? as usize;
            let mut p = request_at + 8 * i;
            let request = read_u64(bytes, &mut p)? as usize;
            let mut p = a_at + 8 * i;
            let a = read_f64(bytes, &mut p)?;
            let mut p = b_at + 8 * i;
            let b = read_f64(bytes, &mut p)?;
            if !t_s.is_finite() {
                bail!("span trace: non-finite timestamp at event {}", events.len());
            }
            events.push(TraceEvent { t_s, server, request, kind: rebuild(code, a, b)? });
        }
        pos = b_at + 8 * n;
    }
    ensure!(pos == bytes.len(), "span trace has {} trailing bytes", bytes.len() - pos);
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::NO_REQUEST;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent { t_s: 0.25, server: 0, request: 0, kind: EventKind::Arrived },
            TraceEvent {
                t_s: 0.25,
                server: 2,
                request: 0,
                kind: EventKind::Routed { server: 2, score: -3.137_218_9e-2 },
            },
            TraceEvent {
                t_s: 1.0,
                server: 2,
                request: NO_REQUEST,
                kind: EventKind::EpochFrozen { epoch: 0 },
            },
            TraceEvent {
                t_s: 1.0,
                server: 2,
                request: NO_REQUEST,
                kind: EventKind::SolveStart { epoch: 0 },
            },
            TraceEvent {
                t_s: 1.1,
                server: 2,
                request: NO_REQUEST,
                kind: EventKind::SolveDone { epoch: 0 },
            },
            TraceEvent { t_s: 1.1, server: 2, request: 0, kind: EventKind::Admitted { epoch: 0 } },
            TraceEvent {
                t_s: 1.1,
                server: 2,
                request: NO_REQUEST,
                kind: EventKind::BatchStart { bucket: 4, steps: 12 },
            },
            TraceEvent {
                t_s: 1.9,
                server: 2,
                request: NO_REQUEST,
                kind: EventKind::EpochDone { epoch: 0 },
            },
            TraceEvent {
                t_s: 2.4,
                server: 2,
                request: 0,
                kind: EventKind::Delivered { steps: 12 },
            },
            TraceEvent {
                t_s: 3.0,
                server: 1,
                request: 5,
                kind: EventKind::RetractedByDeath { done_steps: 7 },
            },
            TraceEvent { t_s: 3.0, server: 1, request: 5, kind: EventKind::TransferStart },
            TraceEvent { t_s: 3.5, server: 0, request: 5, kind: EventKind::Resumed { server: 0 } },
            TraceEvent { t_s: 4.0, server: 0, request: 6, kind: EventKind::Rejected },
            TraceEvent { t_s: 4.0, server: 0, request: 7, kind: EventKind::Expired },
            TraceEvent { t_s: 4.0, server: 0, request: 8, kind: EventKind::Lost },
        ]
    }

    #[test]
    fn roundtrip_preserves_every_kind_exactly() {
        let events = sample_events();
        let decoded = decode(&encode(&events)).unwrap();
        assert_eq!(events, decoded);
        // Score must be bit-exact, not just PartialEq-equal.
        match (&events[1].kind, &decoded[1].kind) {
            (EventKind::Routed { score: a, .. }, EventKind::Routed { score: b, .. }) => {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            _ => panic!("kind mismatch"),
        }
    }

    #[test]
    fn chunk_length_does_not_change_payload() {
        let events = sample_events();
        for chunk_len in [1, 3, 7, 100_000] {
            let decoded = decode(&encode_chunked(&events, chunk_len)).unwrap();
            assert_eq!(events, decoded, "chunk_len={chunk_len}");
        }
    }

    #[test]
    fn empty_stream_roundtrips() {
        let decoded = decode(&encode(&[])).unwrap();
        assert!(decoded.is_empty());
    }

    #[test]
    fn size_is_44_bytes_per_event_plus_overhead() {
        let events = sample_events();
        let bytes = encode(&events);
        let overhead = bytes.len() - ROW_LEN * events.len();
        assert!(overhead < 40, "overhead {overhead}");
    }

    #[test]
    fn rejects_corrupt_inputs() {
        let events = sample_events();
        let good = encode(&events);
        assert!(decode(&good[..10]).is_err(), "truncated header");
        assert!(decode(&good[..good.len() - 5]).is_err(), "truncated frame");
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(decode(&bad_magic).is_err(), "bad magic");
        let mut bad_version = good.clone();
        bad_version[8] = 99;
        assert!(decode(&bad_version).is_err(), "bad version");
        // The first code u32 lives right after the 24-byte header and
        // the frame's n u32.
        let mut bad_code = good.clone();
        bad_code[28] = 200;
        assert!(decode(&bad_code).is_err(), "unknown code");
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode(&trailing).is_err(), "trailing bytes");
    }
}
