//! Minimal TOML-subset parser (no `serde`/`toml` in the vendored set).
//!
//! Supports the subset the config files use: `[section]` headers,
//! `key = value` with integer / float / boolean / string / homogeneous
//! scalar arrays, comments (`#`), and blank lines. Keys are flattened to
//! `"section.key"`.

use std::collections::BTreeMap;
use std::fmt;

/// A scalar (or scalar-array) TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// Parsed document: flattened `"section.key" -> value`.
pub type TomlDoc = BTreeMap<String, TomlValue>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| TomlError { line: lineno, message: "unclosed section".into() })?
                .trim();
            if name.is_empty() {
                return Err(TomlError { line: lineno, message: "empty section name".into() });
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| TomlError {
            line: lineno,
            message: format!("expected key = value, got '{line}'"),
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(TomlError { line: lineno, message: "empty key".into() });
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        doc.insert(full_key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<TomlValue, TomlError> {
    let err = |m: String| TomlError { line, message: m };
    if text.is_empty() {
        return Err(err("empty value".into()));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>, TomlError> =
            inner.split(',').map(|s| parse_value(s.trim(), line)).collect();
        return Ok(TomlValue::Array(items?));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = text.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = text.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(format!("cannot parse value '{text}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
            # top comment
            seed = 7
            [scenario]
            num_services = 20          # trailing comment
            deadline_lo = 7.0
            name = "paper"
            batched = true
            buckets = [1, 2, 4]
            "#,
        )
        .unwrap();
        assert_eq!(doc["seed"], TomlValue::Int(7));
        assert_eq!(doc["scenario.num_services"], TomlValue::Int(20));
        assert_eq!(doc["scenario.deadline_lo"], TomlValue::Float(7.0));
        assert_eq!(doc["scenario.name"].as_str(), Some("paper"));
        assert_eq!(doc["scenario.batched"].as_bool(), Some(true));
        let arr = match &doc["scenario.buckets"] {
            TomlValue::Array(a) => a.clone(),
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse("label = \"a # b\"").unwrap();
        assert_eq!(doc["label"].as_str(), Some("a # b"));
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("bw = 40_000").unwrap();
        assert_eq!(doc["bw"].as_i64(), Some(40_000));
    }

    #[test]
    fn scientific_floats() {
        let doc = parse("x = 1.5e3").unwrap();
        assert_eq!(doc["x"].as_f64(), Some(1500.0));
    }

    #[test]
    fn negative_numbers() {
        let doc = parse("a = -3\nb = -0.5").unwrap();
        assert_eq!(doc["a"].as_i64(), Some(-3));
        assert_eq!(doc["b"].as_f64(), Some(-0.5));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[unclosed").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("x = ").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = parse("i = 3\nf = 3.0").unwrap();
        assert_eq!(doc["i"], TomlValue::Int(3));
        assert_eq!(doc["f"], TomlValue::Float(3.0));
        // as_f64 coerces ints
        assert_eq!(doc["i"].as_f64(), Some(3.0));
        // as_i64 does not coerce floats
        assert_eq!(doc["f"].as_i64(), None);
    }
}
