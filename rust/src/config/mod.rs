//! Typed experiment/serving configuration with validation and presets.
//!
//! Two presets:
//! * [`ExperimentConfig::paper`] — Section IV of the paper: K = 20,
//!   deadlines ~ U[7, 20] s, B = 40 kHz, η ~ U[5, 10] b/s/Hz, the RTX
//!   3050 delay constants, power-law quality in the DDIM/CIFAR-10
//!   regime, S = 24 kbit (a CIFAR-sized JPEG).
//! * [`ExperimentConfig::measured`] — same scenario driven by the
//!   constants measured on *this* machine's PJRT runtime and the quality
//!   curve calibrated at `make artifacts` time (loaded from
//!   `artifacts/`).
//!
//! Configs also load from TOML-subset files (see `config/toml.rs`).

pub mod toml;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cache::{CacheSettings, EvictionKind};
use crate::coordinator::SolveMode;
use crate::faults::{DownInterval, FaultModeKind, FaultScript, MigrationPolicyKind};
use crate::metrics::MetricsMode;
use crate::routing::RouterKind;

use self::toml::{parse, TomlDoc};

/// Which quality model drives scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QualityModelKind {
    /// Power law with the paper-regime constants.
    PaperPowerLaw,
    /// Power law re-fitted by `make artifacts` (artifacts/quality.json).
    CalibratedPowerLaw,
    /// Interpolated measured curve (artifacts/quality.json) — exercises
    /// STACKING's quality-function agnosticism.
    CalibratedTable,
}

/// Full experiment configuration (the union of scenario, models and
/// solver settings; sub-structs keep call-sites narrow).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub scenario: ScenarioConfig,
    pub delay: DelayConfig,
    pub quality: QualityModelKind,
    pub pso: PsoSettings,
    pub stacking: StackingSettings,
    /// Arrival process for dynamic (multi-epoch) simulation.
    pub arrival: ArrivalSettings,
    /// Epoching/admission settings for dynamic simulation.
    pub dynamic: DynamicSettings,
    /// Multi-server sharding settings for cluster simulation.
    pub cluster: ClusterSettings,
    /// Failure-injection settings for the fault-aware event engine.
    pub faults: FaultSettings,
    /// Cross-server migration settings (`sim::event`).
    pub migration: MigrationSettings,
    /// Generation-cache + model-placement settings (all engines).
    pub cache: CacheSettings,
    /// Parallel-execution settings (`util::exec` fan-out).
    pub perf: PerfSettings,
    /// Metrics-aggregation settings (exact vs streaming percentiles).
    pub metrics: MetricsSettings,
    /// Directory holding the AOT artifacts (HLO, quality.json, …).
    pub artifacts_dir: PathBuf,
    pub seed: u64,
}

/// The wireless/workload scenario (Section IV defaults).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Number of devices/services K.
    pub num_services: usize,
    /// Deadline distribution τ_k ~ U[lo, hi] seconds.
    pub deadline_lo: f64,
    pub deadline_hi: f64,
    /// Total downlink bandwidth B in Hz.
    pub total_bandwidth_hz: f64,
    /// Spectral efficiency draw η ~ U[lo, hi] bit/s/Hz.
    pub eta_lo: f64,
    pub eta_hi: f64,
    /// Content size S in bits (identical across services — same model).
    pub content_bits: f64,
}

/// Delay model source.
#[derive(Debug, Clone)]
pub struct DelayConfig {
    /// a (s/task) and b (s/batch) of g(X) = aX + b.
    pub a: f64,
    pub b: f64,
}

/// PSO solver settings (subset of `bandwidth::PsoConfig`, kept here so
/// config files don't depend on solver internals).
#[derive(Debug, Clone, Copy)]
pub struct PsoSettings {
    pub particles: usize,
    pub iterations: usize,
    pub patience: usize,
}

/// STACKING settings.
#[derive(Debug, Clone, Copy)]
pub struct StackingSettings {
    /// 0 = derive from budgets.
    pub t_star_max: u32,
    pub max_steps: u32,
}

/// Which stochastic process generates request arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalProcessKind {
    /// Homogeneous Poisson at `rate_hz`.
    Poisson,
    /// Square-wave-modulated Poisson (diurnal/bursty): `burst_rate_hz`
    /// for the first `duty` fraction of every `period_s`, `rate_hz`
    /// otherwise.
    Burst,
}

/// Arrival-process settings for the dynamic simulator (`aigc-edge
/// dynamic`, `fig3_dynamic`). TOML section `[arrival]`.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalSettings {
    pub process: ArrivalProcessKind,
    /// Poisson rate λ (also the off-peak base rate for `Burst`).
    pub rate_hz: f64,
    /// Peak rate during burst windows (`Burst` only).
    pub burst_rate_hz: f64,
    /// Burst cycle length in seconds.
    pub period_s: f64,
    /// Fraction of every period spent at the burst rate, in (0, 1].
    pub duty: f64,
    /// Stop generating arrivals after this instant.
    pub horizon_s: f64,
    /// Hard cap on generated requests; 0 = until the horizon.
    pub max_requests: usize,
    /// Distinct prompts in the Zipf popularity law; 1 (with `models`
    /// = 1) disables prompt marks entirely — zero extra RNG draws.
    pub prompt_universe: usize,
    /// Zipf skew s: prompt rank k drawn ∝ k^-s. Higher = heavier head.
    pub zipf_s: f64,
    /// Distinct diffusion models, drawn uniformly per request.
    pub models: u32,
}

impl ArrivalSettings {
    /// Instantaneous arrival rate at time `t` — the intensity function
    /// the trace generator thins against.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        match self.process {
            ArrivalProcessKind::Poisson => self.rate_hz,
            ArrivalProcessKind::Burst => {
                let phase = t_s.rem_euclid(self.period_s);
                if phase < self.duty * self.period_s {
                    self.burst_rate_hz
                } else {
                    self.rate_hz
                }
            }
        }
    }

    /// Are the prompt-popularity knobs active? Off (universe 1, one
    /// model) means every arrival carries `PromptMark::ZERO` with zero
    /// extra RNG draws — the bit-identity position.
    pub fn prompts_enabled(&self) -> bool {
        self.prompt_universe > 1 || self.models > 1
    }
}

/// Dynamic-simulation settings (epoching, admission, observability).
/// TOML section `[dynamic]`.
#[derive(Debug, Clone, Copy)]
pub struct DynamicSettings {
    /// Epoch length in simulated seconds (the re-solve cadence).
    pub epoch_s: f64,
    /// Close an epoch early once this many requests are queued.
    pub max_batch: usize,
    /// Deadline-aware admission control: reject requests whose residual
    /// budget cannot fit one denoising step plus best-case transmission.
    pub admission: bool,
    /// Sliding window for the time-windowed metrics, seconds.
    pub window_s: f64,
    /// Per-epoch planning horizon: clamp each request's deadline to
    /// `min(residual, plan_horizon_s)` for the epoch solve, so one
    /// long-deadline request cannot monopolize the GPU (quality vs
    /// responsiveness knob).
    pub plan_horizon_s: f64,
    /// Load-adaptive planning horizon (opt-in): shrink under queue
    /// growth, stretch when idle. See
    /// `DynamicConfig::effective_plan_horizon`.
    pub plan_horizon_adaptive: bool,
    /// CPU cost of one epoch's (P1)∘(P2) solve, seconds (TOML key
    /// `solve_latency` or `solve_latency_s`). 0 keeps the
    /// pre-pipeline semantics bit-identical in either solve mode.
    pub solve_latency_s: f64,
    /// Epoch-solve lifecycle: `pipelined` (default — epoch n+1 solves
    /// on CPU while epoch n's batch executes) or `synchronous` (the
    /// paper's solve-then-execute loop).
    pub solve_mode: SolveMode,
}

/// Multi-server cluster settings (`sim::cluster`). TOML section
/// `[cluster]`.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSettings {
    /// Number of edge servers behind the router.
    pub servers: usize,
    /// Dispatch policy (`round-robin` | `jsq` | `quality`).
    pub router: RouterKind,
    /// GPU speed heterogeneity: per-server speed factors are evenly
    /// spaced in `[speed_min, speed_max]` (1.0 = the reference delay
    /// model; a single server gets the midpoint).
    pub speed_min: f64,
    pub speed_max: f64,
}

/// Failure-injection settings for the event engine (`sim::event`).
/// TOML section `[faults]`.
#[derive(Debug, Clone)]
pub struct FaultSettings {
    /// How the fault script is produced (`none` | `random` |
    /// `scheduled`).
    pub mode: FaultModeKind,
    /// Mean time between failures per server, seconds (`random` mode).
    pub mtbf_s: f64,
    /// Mean time to recovery, seconds (`random` mode).
    pub mttr_s: f64,
    /// Seed for the random fault process; 0 = derive from the
    /// experiment seed.
    pub seed: u64,
    /// Explicit down intervals (`scheduled` mode) — TOML/CLI spec
    /// `"server:from_s:until_s,..."`.
    pub down: Vec<DownInterval>,
}

impl FaultSettings {
    /// Materialize the fault script for an `n`-server fleet over
    /// `horizon_s` of arrivals. `fallback_seed` (the experiment seed)
    /// drives `random` mode when `seed` is 0.
    pub fn script(
        &self,
        servers: usize,
        horizon_s: f64,
        fallback_seed: u64,
    ) -> Result<FaultScript> {
        let script = match self.mode {
            FaultModeKind::None => FaultScript::empty(),
            FaultModeKind::Random => {
                let seed = if self.seed == 0 { fallback_seed } else { self.seed };
                FaultScript::random(servers, horizon_s, self.mtbf_s, self.mttr_s, seed)
            }
            FaultModeKind::Scheduled => FaultScript::scheduled(self.down.clone())?,
        };
        script.validate_servers(servers)?;
        Ok(script)
    }
}

/// Cross-server migration settings (`sim::event`). TOML section
/// `[migration]`.
#[derive(Debug, Clone, Copy)]
pub struct MigrationSettings {
    /// What happens to a dead/overloaded server's queued requests
    /// (`none` | `requeue` | `steal` | `checkpoint`).
    pub policy: MigrationPolicyKind,
    /// Latent-transfer delay (seconds) charged when a checkpointed
    /// partial request moves off a dead server; only read under the
    /// `checkpoint` policy.
    pub transfer_s: f64,
}

/// Performance settings — the solve/sweep fan-out knob. TOML section
/// `[perf]` (CLI `--threads`).
#[derive(Debug, Clone, Copy)]
pub struct PerfSettings {
    /// Worker threads for the parallel hot loops (PSO particle
    /// fitness, per-server epoch solves, bench sweep cells): `0` =
    /// auto-detect from `available_parallelism`, otherwise the literal
    /// count (`1` = fully serial). Outputs are bit-identical at every
    /// value — `util::exec::par_map` is order-preserving and the
    /// engines only fan out independent solves — so this never needs
    /// to appear in a replay recipe.
    pub threads: usize,
}

/// Metrics-aggregation settings — exact or constant-memory streaming
/// percentiles. TOML section `[metrics]` (CLI `--metrics-mode`).
#[derive(Debug, Clone, Copy)]
pub struct MetricsSettings {
    /// How percentile-bearing aggregates are computed: `exact` buffers
    /// and sorts per-request samples (the default — golden fixtures
    /// and bit-identity guards rely on it); `streaming` folds served
    /// delays into a GK quantile sketch so memory stays flat over
    /// 10⁷-request sweeps.
    pub mode: MetricsMode,
    /// Rank-error bound ε of the streaming sketch, in (0, 0.5):
    /// reported percentiles sit within ⌈ε·n⌉ ranks of the exact ones.
    pub sketch_eps: f64,
}

impl ExperimentConfig {
    /// The paper's Section-IV setup.
    pub fn paper() -> Self {
        Self {
            scenario: ScenarioConfig {
                num_services: 20,
                deadline_lo: 7.0,
                deadline_hi: 20.0,
                total_bandwidth_hz: 40_000.0,
                eta_lo: 5.0,
                eta_hi: 10.0,
                content_bits: 24_000.0,
            },
            delay: DelayConfig { a: 0.0240, b: 0.3543 },
            quality: QualityModelKind::PaperPowerLaw,
            pso: PsoSettings { particles: 24, iterations: 40, patience: 12 },
            stacking: StackingSettings { t_star_max: 0, max_steps: 1000 },
            arrival: ArrivalSettings {
                process: ArrivalProcessKind::Poisson,
                rate_hz: 2.0,
                burst_rate_hz: 8.0,
                period_s: 60.0,
                duty: 0.25,
                horizon_s: 300.0,
                max_requests: 0,
                prompt_universe: 1,
                zipf_s: 1.0,
                models: 1,
            },
            dynamic: DynamicSettings {
                epoch_s: 1.0,
                max_batch: 32,
                admission: true,
                window_s: 30.0,
                plan_horizon_s: 2.0,
                plan_horizon_adaptive: false,
                solve_latency_s: 0.0,
                solve_mode: SolveMode::Pipelined,
            },
            cluster: ClusterSettings {
                servers: 4,
                router: RouterKind::JoinShortestQueue,
                speed_min: 1.0,
                speed_max: 1.0,
            },
            faults: FaultSettings {
                mode: FaultModeKind::None,
                mtbf_s: 120.0,
                mttr_s: 15.0,
                seed: 0,
                down: Vec::new(),
            },
            migration: MigrationSettings {
                policy: MigrationPolicyKind::RequeueOnDeath,
                transfer_s: 0.05,
            },
            cache: CacheSettings::default(),
            perf: PerfSettings { threads: 0 },
            metrics: MetricsSettings { mode: MetricsMode::Exact, sketch_eps: 0.01 },
            artifacts_dir: default_artifacts_dir(),
            seed: 2025,
        }
    }

    /// Paper scenario but with models measured on this machine
    /// (delay constants must be profiled at runtime; quality comes from
    /// artifacts/quality.json).
    pub fn measured() -> Self {
        let mut cfg = Self::paper();
        cfg.quality = QualityModelKind::CalibratedPowerLaw;
        cfg
    }

    /// Load from a TOML-subset file; unspecified keys keep the paper
    /// defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml_text(&text)
    }

    /// Parse from TOML text (see `from_file`).
    pub fn from_toml_text(text: &str) -> Result<Self> {
        let doc = parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Self::paper();
        apply_doc(&mut cfg, &doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check invariants; every constructor funnels through here.
    pub fn validate(&self) -> Result<()> {
        let s = &self.scenario;
        if s.num_services == 0 {
            bail!("scenario.num_services must be >= 1");
        }
        if !(s.deadline_lo > 0.0 && s.deadline_hi >= s.deadline_lo) {
            bail!("deadline range invalid: [{}, {}]", s.deadline_lo, s.deadline_hi);
        }
        if s.total_bandwidth_hz <= 0.0 {
            bail!("total bandwidth must be positive");
        }
        if !(s.eta_lo > 0.0 && s.eta_hi >= s.eta_lo) {
            bail!("eta range invalid: [{}, {}]", s.eta_lo, s.eta_hi);
        }
        if s.content_bits <= 0.0 {
            bail!("content size must be positive");
        }
        if self.delay.a < 0.0 || self.delay.b < 0.0 {
            bail!("delay constants must be non-negative");
        }
        if self.pso.particles == 0 || self.pso.iterations == 0 {
            bail!("pso needs at least one particle and one iteration");
        }
        if self.stacking.max_steps == 0 {
            bail!("stacking.max_steps must be >= 1");
        }
        // NaN compares false against every bound, and an infinite
        // horizon would make trace generation loop forever — every
        // rate/duration must be positive AND finite.
        let pos_finite = |name: &str, v: f64| -> Result<()> {
            if !(v > 0.0 && v.is_finite()) {
                bail!("{name} must be positive and finite, got {v}");
            }
            Ok(())
        };
        let a = &self.arrival;
        pos_finite("arrival.rate_hz", a.rate_hz)?;
        if a.process == ArrivalProcessKind::Burst {
            pos_finite("arrival.burst_rate_hz", a.burst_rate_hz)?;
            if a.burst_rate_hz < a.rate_hz {
                bail!(
                    "arrival.burst_rate_hz ({}) must be >= arrival.rate_hz ({})",
                    a.burst_rate_hz,
                    a.rate_hz
                );
            }
            pos_finite("arrival.period_s", a.period_s)?;
            if !(a.duty > 0.0 && a.duty <= 1.0) {
                bail!("arrival.duty must be in (0, 1], got {}", a.duty);
            }
        }
        pos_finite("arrival.horizon_s", a.horizon_s)?;
        if a.prompt_universe == 0 {
            bail!("arrival.prompt_universe must be >= 1 (1 disables prompt marks)");
        }
        pos_finite("arrival.zipf_s", a.zipf_s)?;
        if a.models == 0 {
            bail!("arrival.models must be >= 1");
        }
        let d = &self.dynamic;
        pos_finite("dynamic.epoch_s", d.epoch_s)?;
        if d.max_batch == 0 {
            bail!("dynamic.max_batch must be >= 1");
        }
        pos_finite("dynamic.window_s", d.window_s)?;
        pos_finite("dynamic.plan_horizon_s", d.plan_horizon_s)?;
        if !(d.solve_latency_s >= 0.0 && d.solve_latency_s.is_finite()) {
            bail!(
                "dynamic.solve_latency must be finite and >= 0 seconds \
                 (0 keeps the pre-pipeline solve-instant semantics), got {}",
                d.solve_latency_s
            );
        }
        let c = &self.cluster;
        if c.servers == 0 {
            bail!("cluster.servers must be >= 1");
        }
        pos_finite("cluster.speed_min", c.speed_min)?;
        pos_finite("cluster.speed_max", c.speed_max)?;
        if c.speed_max < c.speed_min {
            bail!(
                "cluster.speed_max ({}) must be >= cluster.speed_min ({})",
                c.speed_max,
                c.speed_min
            );
        }
        let f = &self.faults;
        pos_finite("faults.mtbf_s", f.mtbf_s)?;
        pos_finite("faults.mttr_s", f.mttr_s)?;
        for d in &f.down {
            d.validate()?;
        }
        if f.mode == FaultModeKind::Scheduled {
            // Interval sanity (overlaps, server bounds) is checked
            // against the actual fleet when the script materializes;
            // here we catch the obviously-broken combination early.
            FaultScript::scheduled(f.down.clone())?.validate_servers(c.servers)?;
        }
        let m = &self.metrics;
        if !(m.sketch_eps > 0.0 && m.sketch_eps < 0.5) {
            bail!("metrics.sketch_eps must be in (0, 0.5), got {}", m.sketch_eps);
        }
        let mg = &self.migration;
        if !(mg.transfer_s >= 0.0 && mg.transfer_s.is_finite()) {
            bail!(
                "migration.transfer_s must be finite and >= 0 seconds, got {}",
                mg.transfer_s
            );
        }
        let ch = &self.cache;
        // capacity >= 0 holds by type (usize); 0 is legal placement-only
        // mode. model_slots and load delay must stay sane.
        if ch.model_slots == 0 {
            bail!("cache.model_slots must be >= 1 (every server holds at least one model)");
        }
        if !(ch.load_delay_s >= 0.0 && ch.load_delay_s.is_finite()) {
            bail!(
                "cache.load_delay_s must be finite and >= 0 seconds, got {}",
                ch.load_delay_s
            );
        }
        Ok(())
    }

    pub fn quality_json_path(&self) -> PathBuf {
        self.artifacts_dir.join("quality.json")
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.artifacts_dir.join("manifest.json")
    }
}

/// artifacts/ next to the workspace root (works from the repo and from
/// `target/...` binaries).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn apply_doc(cfg: &mut ExperimentConfig, doc: &TomlDoc) -> Result<()> {
    for (key, value) in doc {
        let ok = match key.as_str() {
            "seed" => set_u64(&mut cfg.seed, value),
            "artifacts_dir" => {
                if let Some(s) = value.as_str() {
                    cfg.artifacts_dir = PathBuf::from(s);
                    true
                } else {
                    false
                }
            }
            "quality.model" => match value.as_str() {
                Some("paper") => {
                    cfg.quality = QualityModelKind::PaperPowerLaw;
                    true
                }
                Some("calibrated") => {
                    cfg.quality = QualityModelKind::CalibratedPowerLaw;
                    true
                }
                Some("table") => {
                    cfg.quality = QualityModelKind::CalibratedTable;
                    true
                }
                _ => false,
            },
            "scenario.num_services" => set_usize(&mut cfg.scenario.num_services, value),
            "scenario.deadline_lo" => set_f64(&mut cfg.scenario.deadline_lo, value),
            "scenario.deadline_hi" => set_f64(&mut cfg.scenario.deadline_hi, value),
            "scenario.total_bandwidth_hz" => {
                set_f64(&mut cfg.scenario.total_bandwidth_hz, value)
            }
            "scenario.eta_lo" => set_f64(&mut cfg.scenario.eta_lo, value),
            "scenario.eta_hi" => set_f64(&mut cfg.scenario.eta_hi, value),
            "scenario.content_bits" => set_f64(&mut cfg.scenario.content_bits, value),
            "delay.a" => set_f64(&mut cfg.delay.a, value),
            "delay.b" => set_f64(&mut cfg.delay.b, value),
            "pso.particles" => set_usize(&mut cfg.pso.particles, value),
            "pso.iterations" => set_usize(&mut cfg.pso.iterations, value),
            "pso.patience" => set_usize(&mut cfg.pso.patience, value),
            "stacking.t_star_max" => set_u32(&mut cfg.stacking.t_star_max, value),
            "stacking.max_steps" => set_u32(&mut cfg.stacking.max_steps, value),
            "arrival.process" => match value.as_str() {
                Some("poisson") => {
                    cfg.arrival.process = ArrivalProcessKind::Poisson;
                    true
                }
                Some("burst") => {
                    cfg.arrival.process = ArrivalProcessKind::Burst;
                    true
                }
                _ => false,
            },
            "arrival.rate_hz" => set_f64(&mut cfg.arrival.rate_hz, value),
            "arrival.burst_rate_hz" => set_f64(&mut cfg.arrival.burst_rate_hz, value),
            "arrival.period_s" => set_f64(&mut cfg.arrival.period_s, value),
            "arrival.duty" => set_f64(&mut cfg.arrival.duty, value),
            "arrival.horizon_s" => set_f64(&mut cfg.arrival.horizon_s, value),
            "arrival.max_requests" => set_usize(&mut cfg.arrival.max_requests, value),
            "arrival.prompt_universe" => set_usize(&mut cfg.arrival.prompt_universe, value),
            "arrival.zipf_s" => set_f64(&mut cfg.arrival.zipf_s, value),
            "arrival.models" => set_u32(&mut cfg.arrival.models, value),
            "dynamic.epoch_s" => set_f64(&mut cfg.dynamic.epoch_s, value),
            "dynamic.max_batch" => set_usize(&mut cfg.dynamic.max_batch, value),
            "dynamic.admission" => set_bool(&mut cfg.dynamic.admission, value),
            "dynamic.window_s" => set_f64(&mut cfg.dynamic.window_s, value),
            "dynamic.plan_horizon_s" => set_f64(&mut cfg.dynamic.plan_horizon_s, value),
            "dynamic.plan_horizon_adaptive" => {
                set_bool(&mut cfg.dynamic.plan_horizon_adaptive, value)
            }
            "dynamic.solve_latency" | "dynamic.solve_latency_s" => {
                set_f64(&mut cfg.dynamic.solve_latency_s, value)
            }
            "dynamic.solve_mode" => match value.as_str() {
                Some(name) => {
                    cfg.dynamic.solve_mode = SolveMode::from_name(name)?;
                    true
                }
                None => false,
            },
            "cluster.servers" => set_usize(&mut cfg.cluster.servers, value),
            "cluster.router" => match value.as_str() {
                Some(name) => {
                    cfg.cluster.router = RouterKind::from_name(name)?;
                    true
                }
                None => false,
            },
            "cluster.speed_min" => set_f64(&mut cfg.cluster.speed_min, value),
            "cluster.speed_max" => set_f64(&mut cfg.cluster.speed_max, value),
            "faults.mode" => match value.as_str() {
                Some(name) => {
                    cfg.faults.mode = FaultModeKind::from_name(name)?;
                    true
                }
                None => false,
            },
            "faults.mtbf_s" => set_f64(&mut cfg.faults.mtbf_s, value),
            "faults.mttr_s" => set_f64(&mut cfg.faults.mttr_s, value),
            "faults.seed" => set_u64(&mut cfg.faults.seed, value),
            "faults.down" => match value.as_str() {
                Some(spec) => {
                    cfg.faults.down = FaultScript::parse_spec(spec)?;
                    true
                }
                None => false,
            },
            "perf.threads" => match value.as_i64() {
                Some(t) if t >= 0 => {
                    cfg.perf.threads = t as usize;
                    true
                }
                Some(t) => bail!(
                    "perf.threads must be 0 (auto-detect) or a positive thread count, got {t}"
                ),
                None => false,
            },
            "metrics.mode" => match value.as_str() {
                Some(name) => match MetricsMode::from_name(name) {
                    Some(mode) => {
                        cfg.metrics.mode = mode;
                        true
                    }
                    None => bail!(
                        "metrics.mode must be \"exact\" or \"streaming\", got \"{name}\""
                    ),
                },
                None => false,
            },
            "metrics.sketch_eps" => set_f64(&mut cfg.metrics.sketch_eps, value),
            "migration.policy" => match value.as_str() {
                Some(name) => {
                    cfg.migration.policy = MigrationPolicyKind::from_name(name)?;
                    true
                }
                None => false,
            },
            // `checkpoint = true` is shorthand for `policy =
            // "checkpoint"`; `false` leaves the configured policy alone
            // (the other policies never checkpoint anyway).
            "migration.checkpoint" => match value.as_bool() {
                Some(true) => {
                    cfg.migration.policy = MigrationPolicyKind::Checkpoint;
                    true
                }
                Some(false) => true,
                None => false,
            },
            "migration.transfer_s" => set_f64(&mut cfg.migration.transfer_s, value),
            "cache.enabled" => set_bool(&mut cfg.cache.enabled, value),
            "cache.capacity" => set_usize(&mut cfg.cache.capacity, value),
            "cache.eviction" => match value.as_str() {
                Some(name) => {
                    cfg.cache.eviction = EvictionKind::from_name(name)?;
                    true
                }
                None => false,
            },
            "cache.model_slots" => set_usize(&mut cfg.cache.model_slots, value),
            "cache.load_delay_s" => set_f64(&mut cfg.cache.load_delay_s, value),
            "cache.seed" => set_u64(&mut cfg.cache.seed, value),
            _ => bail!("unknown config key '{key}'"),
        };
        if !ok {
            bail!("config key '{key}' has the wrong type: {value:?}");
        }
    }
    Ok(())
}

fn set_f64(slot: &mut f64, v: &toml::TomlValue) -> bool {
    v.as_f64().map(|x| *slot = x).is_some()
}

fn set_usize(slot: &mut usize, v: &toml::TomlValue) -> bool {
    v.as_i64().filter(|&x| x >= 0).map(|x| *slot = x as usize).is_some()
}

fn set_u32(slot: &mut u32, v: &toml::TomlValue) -> bool {
    v.as_i64().filter(|&x| x >= 0).map(|x| *slot = x as u32).is_some()
}

fn set_u64(slot: &mut u64, v: &toml::TomlValue) -> bool {
    v.as_i64().filter(|&x| x >= 0).map(|x| *slot = x as u64).is_some()
}

fn set_bool(slot: &mut bool, v: &toml::TomlValue) -> bool {
    v.as_bool().map(|x| *slot = x).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_is_valid() {
        ExperimentConfig::paper().validate().unwrap();
    }

    #[test]
    fn toml_overrides_apply() {
        let cfg = ExperimentConfig::from_toml_text(
            r#"
            seed = 99
            [scenario]
            num_services = 10
            deadline_lo = 3.0
            [delay]
            a = 0.05
            [quality]
            model = "table"
            [pso]
            particles = 8
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.scenario.num_services, 10);
        assert_eq!(cfg.scenario.deadline_lo, 3.0);
        assert_eq!(cfg.scenario.deadline_hi, 20.0); // default kept
        assert_eq!(cfg.delay.a, 0.05);
        assert_eq!(cfg.quality, QualityModelKind::CalibratedTable);
        assert_eq!(cfg.pso.particles, 8);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = ExperimentConfig::from_toml_text("nope = 1").unwrap_err();
        assert!(err.to_string().contains("nope"));
    }

    #[test]
    fn wrong_type_rejected() {
        let err = ExperimentConfig::from_toml_text("[scenario]\nnum_services = \"x\"")
            .unwrap_err();
        assert!(err.to_string().contains("wrong type"));
    }

    #[test]
    fn invalid_ranges_rejected() {
        assert!(ExperimentConfig::from_toml_text("[scenario]\nnum_services = 0").is_err());
        assert!(
            ExperimentConfig::from_toml_text("[scenario]\ndeadline_lo = 9.0\ndeadline_hi = 3.0")
                .is_err()
        );
        assert!(ExperimentConfig::from_toml_text("[scenario]\neta_lo = -1.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[pso]\nparticles = 0").is_err());
    }

    #[test]
    fn arrival_and_dynamic_sections_apply() {
        let cfg = ExperimentConfig::from_toml_text(
            r#"
            [arrival]
            process = "burst"
            rate_hz = 1.5
            burst_rate_hz = 12.0
            period_s = 90.0
            duty = 0.2
            horizon_s = 600.0
            max_requests = 5000
            [dynamic]
            epoch_s = 0.5
            max_batch = 16
            admission = false
            window_s = 20.0
            plan_horizon_s = 3.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.arrival.process, ArrivalProcessKind::Burst);
        assert_eq!(cfg.arrival.rate_hz, 1.5);
        assert_eq!(cfg.arrival.burst_rate_hz, 12.0);
        assert_eq!(cfg.arrival.max_requests, 5000);
        assert_eq!(cfg.dynamic.epoch_s, 0.5);
        assert_eq!(cfg.dynamic.max_batch, 16);
        assert!(!cfg.dynamic.admission);
        assert_eq!(cfg.dynamic.window_s, 20.0);
        assert_eq!(cfg.dynamic.plan_horizon_s, 3.0);
    }

    #[test]
    fn arrival_validation_rejects_nonsense() {
        assert!(ExperimentConfig::from_toml_text("[arrival]\nrate_hz = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_text(
            "[arrival]\nprocess = \"burst\"\nrate_hz = 5.0\nburst_rate_hz = 1.0"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_text(
            "[arrival]\nprocess = \"burst\"\nduty = 1.5"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_text("[arrival]\nprocess = \"weibull\"").is_err());
        assert!(ExperimentConfig::from_toml_text("[dynamic]\nepoch_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[dynamic]\nmax_batch = 0").is_err());
        assert!(ExperimentConfig::from_toml_text("[dynamic]\nadmission = 3").is_err());
    }

    #[test]
    fn solve_latency_and_mode_apply_with_defaults() {
        // defaults: zero latency (bit-identical semantics), pipelined
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.dynamic.solve_latency_s, 0.0);
        assert_eq!(cfg.dynamic.solve_mode, SolveMode::Pipelined);
        let cfg = ExperimentConfig::from_toml_text(
            "[dynamic]\nsolve_latency = 0.25\nsolve_mode = \"synchronous\"",
        )
        .unwrap();
        assert_eq!(cfg.dynamic.solve_latency_s, 0.25);
        assert_eq!(cfg.dynamic.solve_mode, SolveMode::Synchronous);
        // the `_s`-suffixed alias matches the section's other keys
        let cfg = ExperimentConfig::from_toml_text("[dynamic]\nsolve_latency_s = 0.5").unwrap();
        assert_eq!(cfg.dynamic.solve_latency_s, 0.5);
    }

    #[test]
    fn solve_latency_and_mode_validation_errors_list_valid_values() {
        let err = ExperimentConfig::from_toml_text("[dynamic]\nsolve_mode = \"eager\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("synchronous") && err.contains("pipelined"), "{err}");
        let err = ExperimentConfig::from_toml_text("[dynamic]\nsolve_latency = -0.1")
            .unwrap_err()
            .to_string();
        assert!(err.contains(">= 0"), "{err}");
        let mut cfg = ExperimentConfig::paper();
        cfg.dynamic.solve_latency_s = f64::INFINITY;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.dynamic.solve_latency_s = f64::NAN;
        assert!(cfg.validate().is_err());
        // zero is explicitly legal: it is the bit-identity case
        assert!(ExperimentConfig::from_toml_text("[dynamic]\nsolve_latency = 0.0").is_ok());
    }

    #[test]
    fn live_router_parses_and_bad_router_error_lists_it() {
        let cfg = ExperimentConfig::from_toml_text("[cluster]\nrouter = \"live\"").unwrap();
        assert_eq!(cfg.cluster.router, RouterKind::LiveState);
        let err = ExperimentConfig::from_toml_text("[cluster]\nrouter = \"random\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("live"), "router error must list the live policy: {err}");
        assert!(err.contains("round-robin") && err.contains("jsq"), "{err}");
    }

    #[test]
    fn cluster_section_applies() {
        let cfg = ExperimentConfig::from_toml_text(
            r#"
            [cluster]
            servers = 6
            router = "quality"
            speed_min = 0.5
            speed_max = 2.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.servers, 6);
        assert_eq!(cfg.cluster.router, RouterKind::QualityAware);
        assert_eq!(cfg.cluster.speed_min, 0.5);
        assert_eq!(cfg.cluster.speed_max, 2.0);
        // defaults untouched elsewhere
        assert_eq!(cfg.scenario.num_services, 20);
    }

    #[test]
    fn cluster_validation_rejects_nonsense() {
        assert!(ExperimentConfig::from_toml_text("[cluster]\nservers = 0").is_err());
        assert!(ExperimentConfig::from_toml_text("[cluster]\nrouter = \"random\"").is_err());
        assert!(ExperimentConfig::from_toml_text("[cluster]\nspeed_min = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_text(
            "[cluster]\nspeed_min = 2.0\nspeed_max = 1.0"
        )
        .is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.speed_max = f64::INFINITY;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn non_finite_arrival_and_dynamic_values_rejected() {
        // NaN/inf slip past `<= 0.0` comparisons; validate() must
        // reject them explicitly (an infinite horizon would make trace
        // generation loop forever).
        let mut cfg = ExperimentConfig::paper();
        cfg.arrival.horizon_s = f64::INFINITY;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.arrival.rate_hz = f64::NAN;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.dynamic.plan_horizon_s = f64::INFINITY;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.dynamic.window_s = f64::NAN;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn faults_and_migration_sections_apply() {
        let cfg = ExperimentConfig::from_toml_text(
            r#"
            [dynamic]
            plan_horizon_adaptive = true
            [faults]
            mode = "scheduled"
            mtbf_s = 90.0
            mttr_s = 20.0
            seed = 41
            down = "1:10:25,0:40:60"
            [migration]
            policy = "steal"
            "#,
        )
        .unwrap();
        assert!(cfg.dynamic.plan_horizon_adaptive);
        assert_eq!(cfg.faults.mode, FaultModeKind::Scheduled);
        assert_eq!(cfg.faults.mtbf_s, 90.0);
        assert_eq!(cfg.faults.mttr_s, 20.0);
        assert_eq!(cfg.faults.seed, 41);
        assert_eq!(cfg.faults.down.len(), 2);
        assert_eq!(cfg.faults.down[0].server, 1);
        assert_eq!(cfg.migration.policy, MigrationPolicyKind::StealWhenIdle);
        // materializes into a validated script for the configured fleet
        let script = cfg.faults.script(cfg.cluster.servers, 300.0, cfg.seed).unwrap();
        assert_eq!(script.downs().len(), 2);
    }

    #[test]
    fn migration_checkpoint_knobs_apply() {
        let cfg = ExperimentConfig::from_toml_text(
            "[migration]\ncheckpoint = true\ntransfer_s = 0.4",
        )
        .unwrap();
        assert_eq!(cfg.migration.policy, MigrationPolicyKind::Checkpoint);
        assert_eq!(cfg.migration.transfer_s, 0.4);
        // the long-form policy name works too
        let cfg = ExperimentConfig::from_toml_text("[migration]\npolicy = \"checkpoint\"").unwrap();
        assert_eq!(cfg.migration.policy, MigrationPolicyKind::Checkpoint);
        // `checkpoint = false` keeps the configured policy
        let cfg = ExperimentConfig::from_toml_text(
            "[migration]\npolicy = \"steal\"\ncheckpoint = false",
        )
        .unwrap();
        assert_eq!(cfg.migration.policy, MigrationPolicyKind::StealWhenIdle);
        // transfer must be finite and non-negative
        assert!(ExperimentConfig::from_toml_text("[migration]\ntransfer_s = -1.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[migration]\ntransfer_s = inf").is_err());
    }

    #[test]
    fn faults_validation_rejects_nonsense() {
        assert!(ExperimentConfig::from_toml_text("[faults]\nmode = \"weibull\"").is_err());
        assert!(ExperimentConfig::from_toml_text("[faults]\nmtbf_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[faults]\nmttr_s = -2.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[faults]\ndown = \"1:9:3\"").is_err());
        assert!(ExperimentConfig::from_toml_text("[migration]\npolicy = \"teleport\"").is_err());
        // scheduled intervals must fit the configured fleet
        let err = ExperimentConfig::from_toml_text(
            "[cluster]\nservers = 2\n[faults]\nmode = \"scheduled\"\ndown = \"5:1:2\"",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("server 5"), "{err}");
        // the parser errors list the valid names
        let err = ExperimentConfig::from_toml_text("[migration]\npolicy = \"teleport\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("requeue"), "{err}");
    }

    #[test]
    fn perf_threads_applies_and_validation_lists_valid_values() {
        // default: auto-detect
        assert_eq!(ExperimentConfig::paper().perf.threads, 0);
        let cfg = ExperimentConfig::from_toml_text("[perf]\nthreads = 4").unwrap();
        assert_eq!(cfg.perf.threads, 4);
        let cfg = ExperimentConfig::from_toml_text("[perf]\nthreads = 0").unwrap();
        assert_eq!(cfg.perf.threads, 0, "0 is explicitly legal: auto-detect");
        let err = ExperimentConfig::from_toml_text("[perf]\nthreads = -2").unwrap_err().to_string();
        assert!(err.contains("0 (auto-detect)") && err.contains("positive"), "{err}");
        let err =
            ExperimentConfig::from_toml_text("[perf]\nthreads = \"many\"").unwrap_err().to_string();
        assert!(err.contains("wrong type"), "{err}");
    }

    #[test]
    fn metrics_section_applies_and_validation_lists_valid_values() {
        // default: exact — golden fixtures and bit-identity rely on it
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.metrics.mode, MetricsMode::Exact);
        assert_eq!(cfg.metrics.sketch_eps, 0.01);
        let cfg = ExperimentConfig::from_toml_text(
            "[metrics]\nmode = \"streaming\"\nsketch_eps = 0.05",
        )
        .unwrap();
        assert_eq!(cfg.metrics.mode, MetricsMode::Streaming);
        assert_eq!(cfg.metrics.sketch_eps, 0.05);
        let err = ExperimentConfig::from_toml_text("[metrics]\nmode = \"approx\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("exact") && err.contains("streaming"), "{err}");
        for bad in ["sketch_eps = 0.0", "sketch_eps = 0.5", "sketch_eps = -0.1"] {
            let toml = format!("[metrics]\n{bad}");
            let err = ExperimentConfig::from_toml_text(&toml).unwrap_err().to_string();
            assert!(err.contains("(0, 0.5)"), "{bad}: {err}");
        }
    }

    #[test]
    fn arrival_prompt_knobs_apply_with_off_defaults() {
        // defaults: marks off (the bit-identity position)
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.arrival.prompt_universe, 1);
        assert_eq!(cfg.arrival.zipf_s, 1.0);
        assert_eq!(cfg.arrival.models, 1);
        assert!(!cfg.arrival.prompts_enabled());
        let cfg = ExperimentConfig::from_toml_text(
            "[arrival]\nprompt_universe = 500\nzipf_s = 1.8\nmodels = 4",
        )
        .unwrap();
        assert_eq!(cfg.arrival.prompt_universe, 500);
        assert_eq!(cfg.arrival.zipf_s, 1.8);
        assert_eq!(cfg.arrival.models, 4);
        assert!(cfg.arrival.prompts_enabled());
    }

    #[test]
    fn arrival_prompt_validation_rejects_nonsense() {
        let err = ExperimentConfig::from_toml_text("[arrival]\nprompt_universe = 0")
            .unwrap_err()
            .to_string();
        assert!(err.contains(">= 1"), "{err}");
        assert!(ExperimentConfig::from_toml_text("[arrival]\nzipf_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[arrival]\nzipf_s = -1.0").is_err());
        assert!(ExperimentConfig::from_toml_text("[arrival]\nmodels = 0").is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.arrival.zipf_s = f64::INFINITY;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper();
        cfg.arrival.zipf_s = f64::NAN;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cache_section_applies_with_disabled_default() {
        let cfg = ExperimentConfig::paper();
        assert!(!cfg.cache.enabled, "cache must default off: bit-identity");
        let cfg = ExperimentConfig::from_toml_text(
            r#"
            [cache]
            enabled = true
            capacity = 128
            eviction = "random"
            model_slots = 3
            load_delay_s = 0.75
            seed = 21
            "#,
        )
        .unwrap();
        assert!(cfg.cache.enabled);
        assert_eq!(cfg.cache.capacity, 128);
        assert_eq!(cfg.cache.eviction, EvictionKind::SeededRandom);
        assert_eq!(cfg.cache.model_slots, 3);
        assert_eq!(cfg.cache.load_delay_s, 0.75);
        assert_eq!(cfg.cache.seed, 21);
        // capacity 0 is legal placement-only mode
        assert!(ExperimentConfig::from_toml_text("[cache]\ncapacity = 0").is_ok());
    }

    #[test]
    fn cache_validation_errors_list_valid_values() {
        let err = ExperimentConfig::from_toml_text("[cache]\neviction = \"lru\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("clock") && err.contains("random"), "{err}");
        assert!(ExperimentConfig::from_toml_text("[cache]\nmodel_slots = 0").is_err());
        assert!(ExperimentConfig::from_toml_text("[cache]\nload_delay_s = -0.5").is_err());
        assert!(ExperimentConfig::from_toml_text("[cache]\nload_delay_s = inf").is_err());
        assert!(ExperimentConfig::from_toml_text("[cache]\ncapacity = -3").is_err());
        let err = ExperimentConfig::from_toml_text("[cache]\nenabled = 2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("wrong type"), "{err}");
    }

    #[test]
    fn random_fault_seed_zero_derives_from_experiment_seed() {
        let mut cfg = ExperimentConfig::paper();
        cfg.faults.mode = FaultModeKind::Random;
        cfg.faults.seed = 0;
        let a = cfg.faults.script(4, 500.0, 7).unwrap();
        let b = cfg.faults.script(4, 500.0, 7).unwrap();
        assert_eq!(a, b);
        let c = cfg.faults.script(4, 500.0, 8).unwrap();
        assert_ne!(a, c, "fallback seed must drive the process");
        cfg.faults.seed = 99;
        let d = cfg.faults.script(4, 500.0, 7).unwrap();
        assert_ne!(a, d, "explicit seed overrides the fallback");
    }

    #[test]
    fn burst_rate_function_is_periodic() {
        let mut cfg = ExperimentConfig::paper();
        cfg.arrival.process = ArrivalProcessKind::Burst;
        cfg.arrival.rate_hz = 1.0;
        cfg.arrival.burst_rate_hz = 10.0;
        cfg.arrival.period_s = 10.0;
        cfg.arrival.duty = 0.3;
        assert_eq!(cfg.arrival.rate_at(0.0), 10.0);
        assert_eq!(cfg.arrival.rate_at(2.9), 10.0);
        assert_eq!(cfg.arrival.rate_at(3.1), 1.0);
        assert_eq!(cfg.arrival.rate_at(9.9), 1.0);
        assert_eq!(cfg.arrival.rate_at(12.9), 10.0);
    }

    #[test]
    fn quality_model_names() {
        for (name, kind) in [
            ("paper", QualityModelKind::PaperPowerLaw),
            ("calibrated", QualityModelKind::CalibratedPowerLaw),
            ("table", QualityModelKind::CalibratedTable),
        ] {
            let cfg = ExperimentConfig::from_toml_text(&format!(
                "[quality]\nmodel = \"{name}\""
            ))
            .unwrap();
            assert_eq!(cfg.quality, kind);
        }
        assert!(ExperimentConfig::from_toml_text("[quality]\nmodel = \"bogus\"").is_err());
    }
}

#[cfg(test)]
mod preset_file_tests {
    use super::*;

    /// The checked-in configs/ presets must always load and validate.
    #[test]
    fn shipped_config_files_are_valid() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        let mut seen = 0;
        for entry in std::fs::read_dir(&dir).expect("configs/ directory") {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) == Some("toml") {
                let cfg = ExperimentConfig::from_file(&path)
                    .unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
                cfg.validate().unwrap();
                seen += 1;
            }
        }
        assert!(seen >= 3, "expected at least 3 preset configs, found {seen}");
    }

    #[test]
    fn paper_toml_matches_paper_preset() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/paper.toml");
        let from_file = ExperimentConfig::from_file(&dir).unwrap();
        let preset = ExperimentConfig::paper();
        assert_eq!(from_file.scenario.num_services, preset.scenario.num_services);
        assert_eq!(from_file.scenario.deadline_lo, preset.scenario.deadline_lo);
        assert_eq!(from_file.scenario.total_bandwidth_hz, preset.scenario.total_bandwidth_hz);
        assert_eq!(from_file.delay.a, preset.delay.a);
        assert_eq!(from_file.delay.b, preset.delay.b);
        assert_eq!(from_file.quality, preset.quality);
        assert_eq!(from_file.seed, preset.seed);
    }
}
