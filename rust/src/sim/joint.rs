//! The joint generation-and-transmission solver — problem (P0) via the
//! (P1) ∘ (P2) decomposition of Section III-A.
//!
//! Outer loop: a bandwidth [`Allocator`] proposes `B_1..B_K`; for each
//! proposal the inner [`BatchScheduler`] solves (P2) on the induced
//! generation budgets and reports the mean quality `Q*`, which the
//! allocator minimizes.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::bandwidth::{AllocationProblem, Allocator};
use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;
use crate::scheduler::BatchScheduler;
use crate::trace::Workload;

use super::{evaluate, gen_budgets, Outcome};

/// Result of a joint solve.
#[derive(Debug, Clone)]
pub struct JointSolution {
    pub outcome: Outcome,
    /// Number of inner (P2) solves the outer search performed.
    pub inner_evals: usize,
}

/// Solve (P0): outer bandwidth search with inner batch-denoising solve.
///
/// The objective handed to the allocator is a pure `Fn` (each inner
/// (P2) solve depends only on the proposed allocation), so allocators
/// that support it — PSO with `PsoConfig::threads` — evaluate
/// candidates concurrently through [`Allocator::allocate_par`]; the
/// result is bit-identical to the serial path at any thread count.
pub fn solve_joint(
    workload: &Workload,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
) -> JointSolution {
    let problem = AllocationProblem::new(workload.total_bandwidth_hz, workload.links());
    let inner_evals = AtomicUsize::new(0);
    let allocation = {
        let objective = |alloc: &[f64]| -> f64 {
            inner_evals.fetch_add(1, Ordering::Relaxed);
            let services = gen_budgets(workload, alloc);
            scheduler.schedule(&services, delay, quality).mean_quality(quality)
        };
        allocator.allocate_par(&problem, &objective)
    };
    let outcome = evaluate(workload, &allocation, scheduler, delay, quality);
    JointSolution { outcome, inner_evals: inner_evals.into_inner() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::{EqualAllocator, PsoAllocator, PsoConfig};
    use crate::config::ExperimentConfig;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::Stacking;
    use crate::trace::generate;

    fn fast_pso() -> PsoAllocator {
        PsoAllocator::new(PsoConfig {
            particles: 8,
            iterations: 10,
            patience: 5,
            ..Default::default()
        })
    }

    #[test]
    fn pso_no_worse_than_equal() {
        let mut cfg = ExperimentConfig::paper();
        // Tight deadlines + small band make bandwidth allocation matter.
        cfg.scenario.deadline_lo = 3.0;
        cfg.scenario.total_bandwidth_hz = 15_000.0;
        let w = generate(&cfg.scenario, 3);
        let delay = crate::delay::BatchDelayModel::paper();
        let q = PowerLawQuality::paper();
        let sched = Stacking::default();
        let pso = solve_joint(&w, &sched, &fast_pso(), &delay, &q);
        let eq = solve_joint(&w, &sched, &EqualAllocator, &delay, &q);
        assert!(
            pso.outcome.mean_quality() <= eq.outcome.mean_quality() + 1e-9,
            "pso {} vs equal {}",
            pso.outcome.mean_quality(),
            eq.outcome.mean_quality()
        );
        assert!(pso.inner_evals > eq.inner_evals);
    }

    #[test]
    fn equal_allocator_single_eval() {
        let cfg = ExperimentConfig::paper();
        let w = generate(&cfg.scenario, 4);
        let delay = crate::delay::BatchDelayModel::paper();
        let q = PowerLawQuality::paper();
        let sol = solve_joint(&w, &Stacking::default(), &EqualAllocator, &delay, &q);
        assert_eq!(sol.inner_evals, 0); // equal split ignores the objective
        assert_eq!(sol.outcome.allocation_hz.len(), w.k());
    }

    #[test]
    fn allocation_feasible() {
        let cfg = ExperimentConfig::paper();
        let w = generate(&cfg.scenario, 5);
        let delay = crate::delay::BatchDelayModel::paper();
        let q = PowerLawQuality::paper();
        let sol = solve_joint(&w, &Stacking::default(), &fast_pso(), &delay, &q);
        let total: f64 = sol.outcome.allocation_hz.iter().sum();
        assert!(total <= w.total_bandwidth_hz * (1.0 + 1e-9));
        assert!(sol.outcome.allocation_hz.iter().all(|&b| b > 0.0));
    }
}
