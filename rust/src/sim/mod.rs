//! Scenario simulator: evaluates a complete solution (bandwidth
//! allocation + batch-denoising schedule) against the system model of
//! Section II, producing the per-service end-to-end outcomes behind
//! Figs. 2a–2c.

pub mod cluster;
pub mod dynamic;
pub mod event;
pub mod joint;

pub use cluster::{
    server_speeds, simulate_cluster, simulate_cluster_pooled, simulate_cluster_pooled_traced,
    simulate_cluster_traced, ClusterConfig, ClusterReport, ServerReport,
};
pub use dynamic::{
    censored_delays, mean_censored_delay, simulate_dynamic, simulate_dynamic_streaming,
    simulate_dynamic_traced, Disposition, DynamicConfig, DynamicReport, EpochRecord,
    RequestOutcome, StreamingDynamicReport,
};
pub use event::{
    simulate_event_cluster, simulate_event_cluster_pooled, simulate_event_cluster_pooled_traced,
    simulate_event_cluster_scan, simulate_event_cluster_traced, EventClusterConfig, EventReport,
    EventServerReport, MigrationReason, MigrationRecord, UNROUTED,
};
pub use joint::{solve_joint, JointSolution};

use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;
use crate::scheduler::{BatchScheduler, Schedule, Service};
use crate::trace::Workload;

/// Outcome of one service.
#[derive(Debug, Clone, Copy)]
pub struct ServiceOutcome {
    pub id: usize,
    pub deadline: f64,
    /// Steps completed T_k (0 = outage).
    pub steps: u32,
    /// Content generation delay D^cg_k (Eq. 5).
    pub gen_delay: f64,
    /// Transmission delay D^ct_k (Eq. 11).
    pub tx_delay: f64,
    /// End-to-end D^e2e_k (Eq. 12). For an outage this is 0 (nothing
    /// delivered) but `met` is false.
    pub e2e_delay: f64,
    /// FID-like quality actually delivered.
    pub quality: f64,
    /// Deadline satisfied with non-zero steps.
    pub met: bool,
}

/// Outcome of a whole scenario.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub services: Vec<ServiceOutcome>,
    pub schedule: Schedule,
    pub allocation_hz: Vec<f64>,
}

impl Outcome {
    /// The (P0) objective: mean quality across services.
    pub fn mean_quality(&self) -> f64 {
        if self.services.is_empty() {
            return 0.0;
        }
        self.services.iter().map(|s| s.quality).sum::<f64>() / self.services.len() as f64
    }

    pub fn outages(&self) -> usize {
        self.services.iter().filter(|s| !s.met).count()
    }

    pub fn mean_steps(&self) -> f64 {
        if self.services.is_empty() {
            return 0.0;
        }
        self.services.iter().map(|s| s.steps as f64).sum::<f64>() / self.services.len() as f64
    }

    pub fn max_e2e(&self) -> f64 {
        self.services.iter().map(|s| s.e2e_delay).fold(0.0, f64::max)
    }
}

/// Generation budgets τ'_k = τ_k − D^ct_k for a given allocation (Eq. 14).
pub fn gen_budgets(workload: &Workload, allocation_hz: &[f64]) -> Vec<Service> {
    assert_eq!(allocation_hz.len(), workload.k());
    workload
        .devices
        .iter()
        .zip(allocation_hz)
        .map(|(dev, &bw)| {
            let tx = dev.link.tx_delay(workload.content_bits, bw);
            Service::new(dev.id, dev.deadline - tx)
        })
        .collect()
}

/// Run one scheduler under one allocation and assemble the outcome.
pub fn evaluate(
    workload: &Workload,
    allocation_hz: &[f64],
    scheduler: &dyn BatchScheduler,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
) -> Outcome {
    let services = gen_budgets(workload, allocation_hz);
    let schedule = scheduler.schedule(&services, delay, quality);
    debug_assert!(
        crate::scheduler::validate_schedule(&schedule, &services, delay).is_ok(),
        "scheduler {} produced an invalid schedule",
        scheduler.name()
    );
    let outcomes = workload
        .devices
        .iter()
        .zip(allocation_hz)
        .map(|(dev, &bw)| {
            let steps = schedule.steps[dev.id];
            let gen_delay = schedule.completion[dev.id];
            let tx_delay = dev.link.tx_delay(workload.content_bits, bw);
            let (e2e, met) = if steps > 0 {
                let e2e = gen_delay + tx_delay;
                (e2e, e2e <= dev.deadline + 1e-9)
            } else {
                (0.0, false)
            };
            ServiceOutcome {
                id: dev.id,
                deadline: dev.deadline,
                steps,
                gen_delay,
                tx_delay,
                e2e_delay: e2e,
                quality: quality.quality(steps),
                met,
            }
        })
        .collect();
    Outcome { services: outcomes, schedule, allocation_hz: allocation_hz.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::{GreedyBatching, Stacking};
    use crate::trace::generate;

    fn setup() -> (Workload, BatchDelayModel, PowerLawQuality) {
        let cfg = ExperimentConfig::paper();
        (generate(&cfg.scenario, 7), BatchDelayModel::paper(), PowerLawQuality::paper())
    }

    fn equal_alloc(w: &Workload) -> Vec<f64> {
        vec![w.total_bandwidth_hz / w.k() as f64; w.k()]
    }

    #[test]
    fn budgets_subtract_tx_delay() {
        let (w, _, _) = setup();
        let alloc = equal_alloc(&w);
        let services = gen_budgets(&w, &alloc);
        for (svc, dev) in services.iter().zip(&w.devices) {
            let tx = dev.link.tx_delay(w.content_bits, alloc[dev.id]);
            assert!((svc.gen_budget - (dev.deadline - tx)).abs() < 1e-12);
            assert!(svc.gen_budget < dev.deadline);
        }
    }

    #[test]
    fn all_met_services_within_deadline() {
        let (w, delay, quality) = setup();
        let out = evaluate(&w, &equal_alloc(&w), &Stacking::default(), &delay, &quality);
        for s in &out.services {
            if s.met {
                assert!(s.e2e_delay <= s.deadline + 1e-9, "{s:?}");
                assert!(s.steps > 0);
            }
        }
        // Paper scenario at K=20 is comfortably feasible: no outages.
        assert_eq!(out.outages(), 0, "{:?}", out.services);
    }

    #[test]
    fn quality_matches_steps() {
        let (w, delay, quality) = setup();
        let out = evaluate(&w, &equal_alloc(&w), &GreedyBatching, &delay, &quality);
        for s in &out.services {
            assert!((s.quality - quality.quality(s.steps)).abs() < 1e-12);
        }
    }

    #[test]
    fn mean_quality_consistent_with_schedule() {
        let (w, delay, quality) = setup();
        let out = evaluate(&w, &equal_alloc(&w), &Stacking::default(), &delay, &quality);
        assert!((out.mean_quality() - out.schedule.mean_quality(&quality)).abs() < 1e-12);
    }

    #[test]
    fn starving_bandwidth_causes_outage() {
        let (w, delay, quality) = setup();
        // Give device 0 almost nothing: its tx delay exceeds its deadline.
        let mut alloc = equal_alloc(&w);
        alloc[0] = 1e-6;
        let out = evaluate(&w, &alloc, &Stacking::default(), &delay, &quality);
        assert!(!out.services[0].met);
        assert_eq!(out.services[0].steps, 0);
        assert_eq!(out.services[0].quality, quality.outage());
    }
}
