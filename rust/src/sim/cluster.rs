//! Multi-server cluster simulation — N independent `sim::dynamic`
//! server instances behind a pluggable [`Router`].
//!
//! This is the first sharding step toward the ROADMAP's
//! millions-of-users north star: arrivals stream in from one
//! [`ArrivalTrace`], the routing layer assigns each request to a server
//! at its arrival instant (using only causally-available state — the
//! virtual queues in [`crate::routing`]), and every server then runs
//! the full single-server serving loop on its share: its own
//! [`EpochPolicy`](crate::coordinator::EpochPolicy) epochs, per-epoch
//! STACKING + bandwidth (P0) solve, deadline-aware admission and
//! carry-over queue. GPU heterogeneity is first-class: each server has
//! a speed factor that scales the batch-delay model (`g_s(X) =
//! g(X)/speed`).
//!
//! The cluster layer owns:
//! * **arrival splitting** — routing decisions + per-server sub-traces
//!   (ids re-densified per server, mapped back on merge);
//! * **cross-server carry-over accounting** — a deferred request stays
//!   on its server (migration is a ROADMAP follow-up), and the merged
//!   report reconciles per-server deferral counts against the fleet
//!   total;
//! * **merged reporting** — one outcome per trace arrival under its
//!   original id, plus per-server and fleet-wide
//!   [`OutcomeStats`](crate::metrics::OutcomeStats).
//!
//! Determinism: everything is seeded and clockless, so identical
//! inputs replay bit-identically; a 1-server cluster at speed 1.0
//! reproduces [`simulate_dynamic`] exactly (the cluster layer adds zero
//! bias — asserted by `tests/cluster_dominance.rs`).

use crate::bandwidth::{Allocator, AllocatorPool};
use crate::cache::CacheStats;
use crate::delay::BatchDelayModel;
use crate::metrics::{MetricsMode, OutcomeAccumulator, OutcomeStats, ResolvedSample};
use crate::obs::{EventKind, NullSink, Recorder, TraceEvent, TraceSink};
use crate::quality::QualityModel;
use crate::routing::{route_trace, RouterKind, ServerState};
use crate::scheduler::BatchScheduler;
use crate::trace::{Arrival, ArrivalTrace};
use crate::util::exec::par_map;

use super::dynamic::{
    simulate_dynamic, simulate_dynamic_traced, DynamicConfig, DynamicReport, RequestOutcome,
};

/// Evenly-spaced GPU speed factors for an `n`-server fleet in
/// `[lo, hi]`. A single server gets the midpoint, so a homogeneous
/// range `[1, 1]` yields exactly 1.0 (the bit-identity case).
pub fn server_speeds(n: usize, lo: f64, hi: f64) -> Vec<f64> {
    assert!(n >= 1, "cluster needs at least one server");
    assert!(lo > 0.0 && hi >= lo, "speed range invalid: [{lo}, {hi}]");
    if n == 1 {
        return vec![(lo + hi) / 2.0];
    }
    (0..n).map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64).collect()
}

/// Settings for one cluster run.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-server GPU speed factors (1.0 = the reference delay model).
    pub speeds: Vec<f64>,
    /// Dispatch policy.
    pub router: RouterKind,
    /// Per-server serving-loop settings (shared by every server).
    pub dynamic: DynamicConfig,
}

impl ClusterConfig {
    /// Homogeneous fleet of `n` reference-speed servers.
    pub fn homogeneous(n: usize, router: RouterKind, dynamic: DynamicConfig) -> Self {
        Self { speeds: server_speeds(n, 1.0, 1.0), router, dynamic }
    }

    /// The single mapping from config-file settings to the cluster
    /// simulator's runtime config (used by the CLI and
    /// `bench::fig_cluster`).
    pub fn from_settings(
        c: &crate::config::ClusterSettings,
        d: &crate::config::DynamicSettings,
    ) -> Self {
        Self {
            speeds: server_speeds(c.servers, c.speed_min, c.speed_max),
            router: c.router,
            dynamic: DynamicConfig::from(d),
        }
    }

    pub fn servers(&self) -> usize {
        self.speeds.len()
    }
}

/// One server's slice of a cluster run.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub server: usize,
    pub speed: f64,
    /// Global arrival ids this server handled, in arrival order (the
    /// sub-trace id `i` maps to `assigned_ids[i]`).
    pub assigned_ids: Vec<usize>,
    /// The single-server dynamic report over the sub-trace (outcome ids
    /// are sub-trace-local; the merged view in [`ClusterReport`] uses
    /// global ids).
    pub report: DynamicReport,
}

impl ServerReport {
    pub fn assigned(&self) -> usize {
        self.assigned_ids.len()
    }

    /// Per-server summary over this server's share.
    pub fn stats(&self) -> OutcomeStats {
        OutcomeStats::from_samples(&samples(&self.report.outcomes))
    }

    /// Fold this server's outcomes into a fresh accumulator of the
    /// given mode — the per-server sketch the fleet summary merges.
    pub fn accumulator(&self, mode: MetricsMode, eps: f64) -> OutcomeAccumulator {
        let mut acc = OutcomeAccumulator::for_mode(mode, eps);
        for o in &self.report.outcomes {
            acc.push(sample(o));
        }
        acc
    }
}

/// Complete result of a cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// One outcome per trace arrival, indexed by (global) arrival id.
    pub outcomes: Vec<RequestOutcome>,
    /// Destination server per arrival, indexed by arrival id.
    pub assignment: Vec<usize>,
    pub servers: Vec<ServerReport>,
    /// Total simulated span (max over servers).
    pub horizon_s: f64,
}

pub(crate) fn sample(o: &RequestOutcome) -> ResolvedSample {
    ResolvedSample {
        quality: o.quality,
        met: o.met,
        served: o.disposition.is_served(),
        e2e_s: o.e2e_s,
        wait_s: o.wait_s,
    }
}

pub(crate) fn samples(outcomes: &[RequestOutcome]) -> Vec<ResolvedSample> {
    outcomes.iter().map(sample).collect()
}

impl ClusterReport {
    // The aggregate definitions live in `metrics::OutcomeStats`; the
    // named accessors below are thin delegates so the fleet objective
    // can never drift from the printed summary.

    pub fn served(&self) -> usize {
        self.fleet_stats().served
    }

    pub fn dropped(&self) -> usize {
        self.outcomes.len() - self.served()
    }

    /// The fleet (P0) objective: mean charged quality over every
    /// request that entered the cluster.
    pub fn mean_quality(&self) -> f64 {
        self.fleet_stats().mean_quality
    }

    pub fn outage_rate(&self) -> f64 {
        self.fleet_stats().outage_rate
    }

    /// Fleet-wide summary (quality, outage, e2e percentiles, wait).
    pub fn fleet_stats(&self) -> OutcomeStats {
        OutcomeStats::from_samples(&samples(&self.outcomes))
    }

    /// Fleet summary via per-server accumulators merged in server
    /// order. With [`MetricsMode::Streaming`] the e2e percentiles come
    /// from per-server GK sketches combined without a lossy merge —
    /// no fleet-wide served-delay vector is ever materialized or
    /// sorted, and the combined rank error stays within `eps · N`.
    /// Exact mode reproduces [`fleet_stats`](Self::fleet_stats)'s
    /// percentiles bit-for-bit (means re-associate across servers, so
    /// those match to fp tolerance only).
    pub fn fleet_stats_with(&self, mode: MetricsMode, eps: f64) -> OutcomeStats {
        let mut fleet = OutcomeAccumulator::for_mode(mode, eps);
        for server in &self.servers {
            fleet.merge(server.accumulator(mode, eps));
        }
        fleet.stats()
    }

    /// Deferral (cross-epoch carry-over) events summed over servers.
    pub fn total_deferrals(&self) -> usize {
        self.servers
            .iter()
            .map(|s| s.report.outcomes.iter().map(|o| o.deferrals as usize).sum::<usize>())
            .sum()
    }

    /// Deepest per-epoch queue any single server saw.
    pub fn peak_queue_depth(&self) -> usize {
        self.servers.iter().map(|s| s.report.peak_queue_depth()).max().unwrap_or(0)
    }

    /// Epoch solves summed over servers.
    pub fn total_epochs(&self) -> usize {
        self.servers.iter().map(|s| s.report.epochs.len()).sum()
    }

    /// Generation-cache counters summed over servers (each server's
    /// `simulate_dynamic` loop owns a private cache; the fleet view is
    /// their sum). All zero when `[cache]` is disabled.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.servers {
            total.merge(&s.report.cache_stats);
        }
        total
    }

    /// Requests answered straight from a server's generation cache.
    pub fn served_from_cache(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| o.disposition == super::dynamic::Disposition::ServedFromCache)
            .count()
    }
}

/// Run the cluster simulation of `trace` under the given policies with
/// one shared allocator instance (the legacy entry point).
///
/// `delay` is the reference (speed-1.0) batch-delay model; each server
/// runs `simulate_dynamic` under `g(X)/speed`.
///
/// The one `allocator` instance is threaded through every server's
/// (sequential) serving loop. A *stateful* allocator — i.e.
/// [`PsoConfig::warm_start`](crate::bandwidth::PsoConfig) — therefore
/// carries swarm state from server k into server k+1's first epoch and
/// across `simulate_cluster` calls on the same instance; pass a fresh
/// (or [`reset`](crate::bandwidth::PsoAllocator::reset)) allocator per
/// run for bit-identical replay, exactly as with `simulate_dynamic` —
/// or use [`simulate_cluster_pooled`] for per-server instances that
/// keep warm-start state on its server.
pub fn simulate_cluster(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &ClusterConfig,
) -> ClusterReport {
    simulate_cluster_traced(trace, scheduler, allocator, delay, quality, cfg, &mut NullSink)
}

/// [`simulate_cluster`] with a flight recorder attached. Each server's
/// serving loop streams its lifecycle into a private capture (emission
/// inside the `par_map` fan-out never touches the shared sink); the
/// merge then replays the captures into `tracer` in server order,
/// remapped to fleet coordinates, inserting a synthesized
/// [`EventKind::Routed`] after each arrival (the dispatch decision
/// lives in the routing layer, outside the per-server loop). The sink
/// only observes: outputs are bit-identical for any sink.
pub fn simulate_cluster_traced(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &ClusterConfig,
    tracer: &mut dyn TraceSink,
) -> ClusterReport {
    let allocators = vec![allocator; cfg.servers().max(1)];
    run_cluster(trace, scheduler, allocators, delay, quality, cfg, tracer)
}

/// [`simulate_cluster`] with per-server allocator instances from an
/// [`AllocatorPool`]. With per-server warm-start PSO this engine and
/// `sim::event`'s zero-fault case coincide bitwise (each server's
/// solve sequence is identical in both), which a shared stateful
/// allocator cannot guarantee — `tests/pipeline_properties.rs` pins
/// this.
pub fn simulate_cluster_pooled(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    pool: &AllocatorPool,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &ClusterConfig,
) -> ClusterReport {
    let allocators = pool.refs(cfg.servers().max(1));
    run_cluster(trace, scheduler, allocators, delay, quality, cfg, &mut NullSink)
}

/// [`simulate_cluster_pooled`] with a flight recorder attached.
pub fn simulate_cluster_pooled_traced(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    pool: &AllocatorPool,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &ClusterConfig,
    tracer: &mut dyn TraceSink,
) -> ClusterReport {
    let allocators = pool.refs(cfg.servers().max(1));
    run_cluster(trace, scheduler, allocators, delay, quality, cfg, tracer)
}

fn run_cluster(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocators: Vec<&dyn Allocator>,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &ClusterConfig,
    tracer: &mut dyn TraceSink,
) -> ClusterReport {
    let n = cfg.servers();
    assert!(n >= 1, "cluster needs at least one server");
    assert_eq!(allocators.len(), n, "one allocator reference per server");

    // ---- arrival splitting (the routing layer) ----
    // `route_trace` dispatches through the incremental `FleetIndex`
    // (O(arrivals · log N)); decision-identical to the old full-fleet
    // scan by the `route_indexed` contract, so assignments — and
    // everything downstream — are unchanged bit for bit.
    let mut fleet = ServerState::fleet(&cfg.speeds);
    let mut router = cfg.router.build_with_cache(*delay, cfg.dynamic.cache);
    let assignment = route_trace(trace, &mut fleet, router.as_mut(), delay);

    let mut per_server: Vec<Vec<Arrival>> = vec![Vec::new(); n];
    let mut assigned_ids: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (arrival, &server) in trace.arrivals.iter().zip(&assignment) {
        // Re-densify ids so the sub-trace is a valid ArrivalTrace; the
        // dense sub-id is the index into assigned_ids[server].
        let sub = Arrival { id: per_server[server].len(), ..*arrival };
        per_server[server].push(sub);
        assigned_ids[server].push(arrival.id);
    }

    // ---- independent per-server serving loops ----
    // Once dispatch is fixed, the per-server loops cannot observe each
    // other, so they fan out across `cfg.dynamic.threads` workers —
    // unless a *shared stateful* allocator (legacy shared warm-start
    // PSO) makes the serial server order load-bearing, in which case
    // the fan-out degrades to the serial loop so replay stays exact.
    let sub_traces: Vec<ArrivalTrace> = per_server
        .into_iter()
        .map(|arrivals| ArrivalTrace {
            arrivals,
            total_bandwidth_hz: trace.total_bandwidth_hz,
            content_bits: trace.content_bits,
        })
        .collect();
    let par_safe = allocators.iter().all(|a| a.parallel_replay_safe())
        || crate::bandwidth::distinct_instances(&allocators);
    let threads = if par_safe { cfg.dynamic.threads } else { 1 };
    // With a live tracer each server fills a private capture inside the
    // fan-out (the shared sink is never touched concurrently); with
    // NullSink the untraced loop runs — both call the same core, so the
    // float stream is identical either way.
    let capture = tracer.enabled();
    let results: Vec<(DynamicReport, Vec<TraceEvent>)> =
        par_map(threads, &sub_traces, |server, sub_trace| {
            let speed = cfg.speeds[server];
            let scaled = BatchDelayModel::new(delay.a / speed, delay.b / speed);
            let alloc = allocators[server];
            if capture {
                let mut rec = Recorder::new();
                let report = simulate_dynamic_traced(
                    sub_trace,
                    scheduler,
                    alloc,
                    &scaled,
                    quality,
                    &cfg.dynamic,
                    &mut rec,
                );
                (report, rec.events)
            } else {
                let report =
                    simulate_dynamic(sub_trace, scheduler, alloc, &scaled, quality, &cfg.dynamic);
                (report, Vec::new())
            }
        });

    // ---- merge: map sub-trace outcomes back to global ids ----
    let mut outcomes: Vec<Option<RequestOutcome>> = vec![None; trace.len()];
    let mut servers = Vec::with_capacity(n);
    let mut horizon = 0.0f64;
    for (server, ((report, mut events), ids)) in results.into_iter().zip(assigned_ids).enumerate() {
        horizon = horizon.max(report.horizon_s);
        for outcome in &report.outcomes {
            let global = ids[outcome.id];
            debug_assert!(outcomes[global].is_none(), "request {global} resolved twice");
            outcomes[global] = Some(RequestOutcome { id: global, ..*outcome });
        }
        // Replay this server's capture into the shared sink in fleet
        // coordinates, splicing the routing layer's dispatch decision
        // in right after each arrival.
        crate::obs::remap(&mut events, server, &ids);
        for ev in events {
            tracer.record(ev);
            if ev.kind == EventKind::Arrived {
                let kind = EventKind::Routed { server, score: 0.0 };
                tracer.emit(ev.t_s, server, ev.request, kind);
            }
        }
        servers.push(ServerReport { server, speed: cfg.speeds[server], assigned_ids: ids, report });
    }

    let outcomes: Vec<RequestOutcome> =
        outcomes.into_iter().map(|o| o.expect("every request routed and resolved")).collect();
    ClusterReport { outcomes, assignment, servers, horizon_s: horizon }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::EqualAllocator;
    use crate::cache::CacheSettings;
    use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
    use crate::quality::PowerLawQuality;
    use crate::scheduler::Stacking;
    use crate::sim::dynamic::Disposition;
    use crate::trace::PromptMark;

    fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 1,
            zipf_s: 1.0,
            models: 1,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    /// Zipf-marked twin of [`trace`]: a small skewed prompt universe so
    /// repeats (and therefore cache hits) are plentiful.
    fn marked_trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 12,
            zipf_s: 1.5,
            models: 2,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    fn run(trace: &ArrivalTrace, cfg: &ClusterConfig) -> ClusterReport {
        simulate_cluster(
            trace,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            cfg,
        )
    }

    #[test]
    fn every_request_resolved_exactly_once_across_servers() {
        let t = trace(6.0, 60.0, 1);
        for router in RouterKind::all() {
            let cfg = ClusterConfig {
                speeds: server_speeds(3, 0.5, 1.5),
                router,
                dynamic: DynamicConfig::default(),
            };
            let report = run(&t, &cfg);
            assert_eq!(report.outcomes.len(), t.len(), "{}", router.name());
            assert_eq!(report.assignment.len(), t.len());
            for (i, o) in report.outcomes.iter().enumerate() {
                assert_eq!(o.id, i, "{}: outcomes indexed by global id", router.name());
            }
            let assigned: usize = report.servers.iter().map(|s| s.assigned()).sum();
            assert_eq!(assigned, t.len(), "{}: conservation", router.name());
            assert_eq!(report.served() + report.dropped(), t.len());
        }
    }

    #[test]
    fn deterministic_replay() {
        let t = trace(8.0, 50.0, 7);
        let cfg = ClusterConfig {
            speeds: server_speeds(4, 0.5, 2.0),
            router: RouterKind::QualityAware,
            dynamic: DynamicConfig::default(),
        };
        let a = run(&t, &cfg);
        let b = run(&t, &cfg);
        assert_eq!(a.assignment, b.assignment);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.disposition, y.disposition);
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
            assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits());
        }
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    }

    #[test]
    fn sharding_relieves_overload() {
        // A λ that buries one server is comfortable for four.
        let t = trace(10.0, 60.0, 3);
        let dynamic = DynamicConfig::default();
        let single = ClusterConfig::homogeneous(1, RouterKind::RoundRobin, dynamic);
        let quad = ClusterConfig::homogeneous(4, RouterKind::RoundRobin, dynamic);
        let one = run(&t, &single);
        let four = run(&t, &quad);
        assert!(
            four.mean_quality() < one.mean_quality(),
            "4 servers {} must beat 1 server {}",
            four.mean_quality(),
            one.mean_quality()
        );
        assert!(four.outage_rate() <= one.outage_rate());
    }

    #[test]
    fn fleet_stats_match_outcome_aggregates() {
        let t = trace(5.0, 40.0, 9);
        let cfg = ClusterConfig {
            speeds: server_speeds(2, 0.8, 1.2),
            router: RouterKind::JoinShortestQueue,
            dynamic: DynamicConfig::default(),
        };
        let report = run(&t, &cfg);
        let stats = report.fleet_stats();
        assert_eq!(stats.count, t.len());
        // against a direct scan of the merged outcomes (the
        // DynamicReport definitions)
        let served =
            report.outcomes.iter().filter(|o| o.disposition == Disposition::Served).count();
        let mean_q = report.outcomes.iter().map(|o| o.quality).sum::<f64>() / t.len() as f64;
        let outage = report.outcomes.iter().filter(|o| !o.met).count() as f64 / t.len() as f64;
        assert_eq!(stats.served, served);
        assert!((stats.mean_quality - mean_q).abs() < 1e-12);
        assert!((stats.outage_rate - outage).abs() < 1e-12);
        // per-server counts partition the fleet
        let counts: usize = report.servers.iter().map(|s| s.stats().count).sum();
        assert_eq!(counts, t.len());
    }

    #[test]
    fn streaming_fleet_stats_track_exact() {
        let t = trace(8.0, 60.0, 4);
        let cfg = ClusterConfig {
            speeds: server_speeds(3, 0.5, 1.5),
            router: RouterKind::RoundRobin,
            dynamic: DynamicConfig::default(),
        };
        let report = run(&t, &cfg);
        let exact = report.fleet_stats();
        // Exact accumulators merged in server order: same percentile
        // multiset (bit-equal), means re-associated (fp tolerance).
        let via_acc = report.fleet_stats_with(MetricsMode::Exact, 0.01);
        assert_eq!(via_acc.count, exact.count);
        assert_eq!(via_acc.served, exact.served);
        assert!((via_acc.mean_quality - exact.mean_quality).abs() < 1e-9);
        assert!((via_acc.mean_wait_s - exact.mean_wait_s).abs() < 1e-9);
        assert_eq!(via_acc.p50_e2e_s.to_bits(), exact.p50_e2e_s.to_bits());
        assert_eq!(via_acc.p95_e2e_s.to_bits(), exact.p95_e2e_s.to_bits());
        assert_eq!(via_acc.p99_e2e_s.to_bits(), exact.p99_e2e_s.to_bits());

        // Per-server sketches combined fleet-wide: scalar aggregates
        // exact, percentiles within the combined rank bound.
        let eps = 0.02;
        let sketched = report.fleet_stats_with(MetricsMode::Streaming, eps);
        assert_eq!(sketched.count, exact.count);
        assert_eq!(sketched.served, exact.served);
        assert!((sketched.mean_quality - exact.mean_quality).abs() < 1e-9);
        let mut served: Vec<f64> = report
            .outcomes
            .iter()
            .filter(|o| o.disposition == Disposition::Served)
            .map(|o| o.e2e_s)
            .collect();
        served.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = served.len() as f64;
        let budget = 2 * (eps * n).ceil() as i64 + 2;
        for (p, g) in [(50.0, sketched.p50_e2e_s), (95.0, sketched.p95_e2e_s)] {
            let target = (p / 100.0 * n).ceil().max(1.0) as i64;
            let rank = served.iter().filter(|&&v| v <= g).count() as i64;
            assert!((rank - target).abs() <= budget, "p{p}: rank {rank} target {target}");
        }
    }

    #[test]
    fn traced_run_is_bit_identical_and_audits_clean() {
        let t = trace(6.0, 50.0, 7);
        let cfg = ClusterConfig {
            speeds: server_speeds(3, 0.5, 1.5),
            router: RouterKind::JoinShortestQueue,
            dynamic: DynamicConfig::default(),
        };
        let plain = run(&t, &cfg);
        let mut rec = Recorder::new();
        let traced = simulate_cluster_traced(
            &t,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &cfg,
            &mut rec,
        );
        assert_eq!(plain.assignment, traced.assignment);
        assert_eq!(plain.horizon_s.to_bits(), traced.horizon_s.to_bits());
        for (a, b) in plain.outcomes.iter().zip(&traced.outcomes) {
            assert_eq!(a.disposition, b.disposition, "request {}", a.id);
            assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "request {}", a.id);
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
        }
        let audit = crate::obs::audit::audit_expecting(&rec.events, t.len());
        assert!(audit.is_clean(), "{}", audit.render());
        // Every arrival carries its dispatch decision, matching the
        // merged assignment vector.
        let routed =
            rec.events.iter().filter(|e| matches!(e.kind, EventKind::Routed { .. })).count();
        assert_eq!(routed, t.len());
        for ev in &rec.events {
            if let EventKind::Routed { server, .. } = ev.kind {
                assert_eq!(server, traced.assignment[ev.request]);
            }
        }
    }

    #[test]
    fn cache_disabled_cluster_ignores_prompt_marks_bitwise() {
        let marked = marked_trace(6.0, 50.0, 7);
        let mut stripped = marked.clone();
        for a in &mut stripped.arrivals {
            a.mark = PromptMark::ZERO;
        }
        for router in RouterKind::all() {
            let cfg = ClusterConfig {
                speeds: server_speeds(3, 0.5, 1.5),
                router,
                dynamic: DynamicConfig::default(),
            };
            let a = run(&marked, &cfg);
            let b = run(&stripped, &cfg);
            assert_eq!(a.assignment, b.assignment, "{}", router.name());
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits(), "{}", router.name());
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.disposition, y.disposition, "{} request {}", router.name(), x.id);
                assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "request {}", x.id);
                assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits(), "request {}", x.id);
            }
            assert_eq!(a.served_from_cache(), 0);
            assert_eq!(a.cache_stats(), CacheStats::default());
        }
    }

    #[test]
    fn cache_enabled_cluster_hits_conserves_and_replays() {
        let t = marked_trace(6.0, 50.0, 7);
        let cfg = ClusterConfig {
            speeds: server_speeds(3, 0.5, 1.5),
            router: RouterKind::CacheAware,
            dynamic: DynamicConfig {
                cache: CacheSettings { enabled: true, capacity: 32, ..CacheSettings::default() },
                ..DynamicConfig::default()
            },
        };
        let report = run(&t, &cfg);
        assert_eq!(report.outcomes.len(), t.len());
        assert_eq!(report.served() + report.dropped(), t.len(), "census conservation");
        let hits = report.served_from_cache();
        assert!(hits > 0, "a skewed Zipf trace must hit the cluster caches");
        assert_eq!(report.cache_stats().hits, hits as u64);
        // The fleet counters are exactly the per-server sums.
        let per_server: u64 = report.servers.iter().map(|s| s.report.cache_stats.hits).sum();
        assert_eq!(per_server, hits as u64);
        let again = run(&t, &cfg);
        assert_eq!(report.assignment, again.assignment);
        assert_eq!(report.horizon_s.to_bits(), again.horizon_s.to_bits());
        for (x, y) in report.outcomes.iter().zip(&again.outcomes) {
            assert_eq!(x.disposition, y.disposition);
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
        }
    }

    #[test]
    fn speeds_are_evenly_spaced_and_midpoint_for_one() {
        assert_eq!(server_speeds(1, 1.0, 1.0), vec![1.0]);
        assert_eq!(server_speeds(1, 0.5, 1.5), vec![1.0]);
        let s = server_speeds(3, 0.5, 1.5);
        assert_eq!(s.len(), 3);
        assert!((s[0] - 0.5).abs() < 1e-12);
        assert!((s[1] - 1.0).abs() < 1e-12);
        assert!((s[2] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_is_empty_report() {
        let t = ArrivalTrace {
            arrivals: vec![],
            total_bandwidth_hz: 40_000.0,
            content_bits: 24_000.0,
        };
        let cfg = ClusterConfig::homogeneous(3, RouterKind::RoundRobin, DynamicConfig::default());
        let report = run(&t, &cfg);
        assert!(report.outcomes.is_empty());
        assert_eq!(report.mean_quality(), 0.0);
        assert_eq!(report.total_epochs(), 0);
    }

    #[test]
    fn deferral_accounting_reconciles() {
        use crate::coordinator::EpochPolicy;
        let dynamic =
            DynamicConfig { epoch: EpochPolicy::new(0.25, 4), ..DynamicConfig::default() };
        let cfg = ClusterConfig {
            speeds: server_speeds(2, 0.6, 1.0),
            router: RouterKind::RoundRobin,
            dynamic,
        };
        let report = run(&trace(12.0, 40.0, 6), &cfg);
        let recorded: usize = report
            .servers
            .iter()
            .map(|s| s.report.epochs.iter().map(|e| e.deferred).sum::<usize>())
            .sum();
        assert_eq!(report.total_deferrals(), recorded, "carry-over accounting must reconcile");
    }
}
