//! Event-driven, multi-epoch dynamic simulation — requests arrive over
//! continuous time and the coordinator re-solves (P0) per epoch.
//!
//! This turns the paper's one-shot snapshot (K requests at t = 0, one
//! solve) into the serving loop its system model implies:
//!
//! 1. arrivals stream in from an [`ArrivalTrace`] (Poisson / burst /
//!    replayed);
//! 2. the epoch closes under the *same* [`EpochPolicy`] the TCP server
//!    uses (time-or-batch, whichever first);
//! 3. deadline-aware **admission control** drops requests whose
//!    residual budget cannot fit even one denoising step `g(1)` plus
//!    best-case transmission;
//! 4. one (P1) ∘ (P2) solve runs over the queue with *residual*
//!    deadlines, the GPU executes the plan (simulated time advances by
//!    the schedule makespan). The solve itself costs
//!    `solve_latency_s` CPU seconds under the explicit epoch lifecycle
//!    ([`SolveTiming`]): pipelined mode (default) starts it at the
//!    epoch freeze — hidden behind the previous batch whenever the GPU
//!    is still busy — while synchronous mode replays the paper's
//!    solve-then-execute loop. Zero latency keeps the historical
//!    semantics bit-identical in either mode;
//! 5. **carry-over**: a request the solve left at zero steps stays
//!    queued and spans epochs until it is served or its deadline makes
//!    it infeasible.
//!
//! Everything is seeded and clockless — identical inputs replay
//! bit-identically, which the `fig3_dynamic` bench asserts.

use crate::bandwidth::Allocator;
use crate::cache::{CacheSettings, CacheStats, ServerCache};
use crate::coordinator::{EpochPolicy, SolveMode, SolveTiming};
use crate::delay::BatchDelayModel;
use crate::metrics::{OutcomeAccumulator, OutcomeStats, ResolvedSample, ServiceWindows};
use crate::obs::{EventKind, NullSink, TraceEvent, TraceSink, NO_REQUEST};
use crate::quality::QualityModel;
use crate::scheduler::BatchScheduler;
use crate::trace::{Arrival, ArrivalTrace, DeviceRequest, PromptMark, Workload};
use crate::util::stats::percentile;

use super::solve_joint;

/// Settings for one dynamic run.
#[derive(Debug, Clone, Copy)]
pub struct DynamicConfig {
    /// Epoch-closing rule (shared with `server::serve`).
    pub epoch: EpochPolicy,
    /// Deadline-aware admission control. When off, infeasible requests
    /// still expire once they cannot fit `g(1)` at all (the queue never
    /// grows without bound), but marginal ones are attempted.
    pub admission: bool,
    /// Sliding window for the per-epoch aggregates, seconds.
    pub window_s: f64,
    /// Per-epoch planning horizon: each request's deadline is clamped
    /// to `min(residual, plan_horizon_s)` for the epoch solve. Without
    /// this, one long-deadline request makes the myopic (P0) solve
    /// occupy the GPU for its entire deadline and every later arrival
    /// starves — the fundamental static→dynamic tension. Smaller values
    /// trade per-request quality for responsiveness.
    pub plan_horizon_s: f64,
    /// Load-adaptive planning horizon (opt-in): scale `plan_horizon_s`
    /// by queue pressure — shrink when the queue outgrows the batch
    /// cap, stretch (up to 2×) when it idles. See
    /// [`effective_plan_horizon`](Self::effective_plan_horizon).
    pub plan_horizon_adaptive: bool,
    /// CPU cost of one epoch's (P1)∘(P2) solve, seconds. Zero keeps
    /// the pre-pipeline semantics bit-identical in either mode.
    pub solve_latency_s: f64,
    /// Where the solve runs relative to the GPU: pipelined (the
    /// default — epoch n+1 solves while epoch n executes) or the
    /// paper's synchronous loop. See [`SolveMode`].
    pub solve_mode: SolveMode,
    /// Engine-level solve fan-out: worker threads for *independent*
    /// per-server epoch solves (0 = auto, 1 = serial — the default).
    /// `sim::cluster` runs whole per-server serving loops concurrently;
    /// `sim::event` fans out per-server solves that share a freeze
    /// instant. Results are bit-identical at any value (the engines
    /// only parallelize solves that cannot observe each other —
    /// `tests/exec_determinism.rs`); `simulate_dynamic` itself is a
    /// single server and ignores it.
    pub threads: usize,
    /// Generation cache + model catalog (`[cache]` config). Disabled by
    /// default: no cache is constructed and runs are bitwise identical
    /// to the pre-cache engine. Enabled, each serving loop owns one
    /// [`ServerCache`]: a marked arrival that hits resolves at its
    /// arrival instant as [`Disposition::ServedFromCache`] (it pays
    /// only transmission over the full band and never joins an epoch
    /// batch), while a miss on a non-resident model spends
    /// `load_delay_s` of its deadline budget on the swap.
    pub cache: CacheSettings,
}

impl DynamicConfig {
    /// The planning horizon an epoch solve actually uses, given the
    /// queue depth at the solve instant. With `plan_horizon_adaptive`
    /// off this is `plan_horizon_s` unconditionally (bit-identical to
    /// the pre-adaptive behaviour). With it on, the horizon is
    /// `plan_horizon_s · 2/(1 + depth/max_batch)`, clamped to
    /// `[0.25, 2] × plan_horizon_s`: monotone non-increasing in depth,
    /// equal to the static value at exactly one full batch, stretched
    /// toward 2× when idle and floored at 0.25× under deep backlog.
    pub fn effective_plan_horizon(&self, queue_depth: usize) -> f64 {
        if !self.plan_horizon_adaptive {
            return self.plan_horizon_s;
        }
        let load = queue_depth as f64 / self.epoch.max_batch as f64;
        let factor = (2.0 / (1.0 + load)).clamp(0.25, 2.0);
        self.plan_horizon_s * factor
    }
}

impl Default for DynamicConfig {
    fn default() -> Self {
        Self {
            epoch: EpochPolicy::new(1.0, 32),
            admission: true,
            window_s: 30.0,
            plan_horizon_s: 2.0,
            plan_horizon_adaptive: false,
            solve_latency_s: 0.0,
            solve_mode: SolveMode::Pipelined,
            threads: 1,
            cache: CacheSettings::default(),
        }
    }
}

impl From<&crate::config::DynamicSettings> for DynamicConfig {
    /// The single mapping from config-file settings to the simulator's
    /// runtime config (used by the CLI and `bench::fig3_dynamic`).
    /// Engine fan-out stays serial here — the `[perf] threads` knob is
    /// applied by the caller that owns the fan-out level (the CLI
    /// parallelizes servers, the bench sweeps parallelize cells). The
    /// cache stays at its disabled default — `[cache]` lives on
    /// `ExperimentConfig`, so the caller that owns the experiment
    /// attaches it (`cfg.cache = experiment.cache`).
    fn from(d: &crate::config::DynamicSettings) -> Self {
        Self {
            epoch: EpochPolicy::new(d.epoch_s, d.max_batch),
            admission: d.admission,
            window_s: d.window_s,
            plan_horizon_s: d.plan_horizon_s,
            plan_horizon_adaptive: d.plan_horizon_adaptive,
            solve_latency_s: d.solve_latency_s,
            solve_mode: d.solve_mode,
            threads: 1,
            cache: CacheSettings::default(),
        }
    }
}

/// How a request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Disposition {
    /// Content was generated and transmitted.
    Served,
    /// Admission control refused it at its first epoch.
    RejectedOnArrival,
    /// Carried over at least one epoch, then became infeasible.
    ExpiredInQueue,
    /// Stranded on a failed server and not migrated (`sim::event` with
    /// a fault script; never produced by `simulate_dynamic` itself).
    LostToFailure,
    /// Served, but its first server died mid-batch: the checkpointed
    /// partial resumed and finished on another server (`sim::event`
    /// under `CheckpointOnDeath`; never produced by `simulate_dynamic`
    /// itself).
    ResumedElsewhere,
    /// Served straight from the generation cache at its arrival
    /// instant: the content already existed at the cached step count,
    /// so the request paid only transmission and never joined an epoch
    /// batch (`[cache]` enabled runs only).
    ServedFromCache,
}

impl Disposition {
    /// Whether content was actually delivered — the serving-semantic
    /// predicate every aggregate uses. A checkpoint-resumed or
    /// cache-served request is served content like any other; only the
    /// path differed.
    pub fn is_served(self) -> bool {
        matches!(
            self,
            Disposition::Served | Disposition::ResumedElsewhere | Disposition::ServedFromCache
        )
    }
}

/// Per-request outcome of a dynamic run.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub id: usize,
    pub arrival_s: f64,
    /// Relative deadline τ (absolute deadline = arrival + τ).
    pub deadline_s: f64,
    pub disposition: Disposition,
    /// Denoising steps delivered (0 unless served).
    pub steps: u32,
    /// Quality charged: `quality(steps)` when served, the outage
    /// quality otherwise.
    pub quality: f64,
    /// End-to-end delay, arrival → content delivered (0.0 when not
    /// served).
    pub e2e_s: f64,
    /// Arrival → start of the epoch that resolved the request.
    pub wait_s: f64,
    /// Epochs the request was deferred past its first.
    pub deferrals: u32,
    /// Index of the epoch that resolved (served or dropped) it.
    pub epoch: usize,
    /// Served within the deadline.
    pub met: bool,
    /// Instant the request left the system (completion or drop time).
    pub resolved_s: f64,
    /// Denoising steps salvaged from a dead server's checkpoint and
    /// credited toward `steps` (0 except for
    /// [`Disposition::ResumedElsewhere`]).
    pub recovered_steps: u32,
}

/// Per-epoch record, including sliding-window aggregates sampled at the
/// solve instant.
#[derive(Debug, Clone, Copy)]
pub struct EpochRecord {
    pub index: usize,
    /// Solve instant (epoch close, or later if the GPU was busy).
    pub t_solve_s: f64,
    /// Queue depth at the solve instant, before admission.
    pub queue_depth: usize,
    pub admitted: usize,
    pub served: usize,
    pub deferred: usize,
    pub dropped: usize,
    /// Generation-phase makespan of this epoch's schedule.
    pub makespan_s: f64,
    /// Solve time hidden behind GPU execution (0 unless pipelined with
    /// nonzero `solve_latency_s` and a busy GPU at the freeze).
    pub solve_hidden_s: f64,
    // ---- sliding-window aggregates at t_solve (window = config) ----
    pub arrival_rate_hz: f64,
    pub mean_quality_w: f64,
    pub outage_rate_w: f64,
    pub p50_e2e_w: f64,
    pub p95_e2e_w: f64,
    pub p99_e2e_w: f64,
    /// Windowed solve-overlap gauge: hidden solve time / total solve
    /// time over the trailing window (0 when no solve cost is charged).
    pub solve_overlap_w: f64,
}

/// Complete result of a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicReport {
    /// One outcome per trace arrival, indexed by arrival id.
    pub outcomes: Vec<RequestOutcome>,
    pub epochs: Vec<EpochRecord>,
    /// Total simulated span (last resolution instant).
    pub horizon_s: f64,
    /// Generation-cache counters (all zero when `[cache]` is disabled).
    pub cache_stats: CacheStats,
}

impl DynamicReport {
    pub fn served(&self) -> usize {
        self.outcomes.iter().filter(|o| o.disposition.is_served()).count()
    }

    pub fn dropped(&self) -> usize {
        self.outcomes.len() - self.served()
    }

    /// The (P0) objective over the whole run: mean charged quality
    /// (dropped requests are charged the outage quality).
    pub fn mean_quality(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.quality).sum::<f64>() / self.outcomes.len() as f64
    }

    /// Fraction of requests not served within their deadline.
    pub fn outage_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().filter(|o| !o.met).count() as f64 / self.outcomes.len() as f64
    }

    fn served_e2e(&self) -> Vec<f64> {
        self.outcomes
            .iter()
            .filter(|o| o.disposition.is_served())
            .map(|o| o.e2e_s)
            .collect()
    }

    /// End-to-end delay percentile over served requests.
    pub fn e2e_percentile(&self, p: f64) -> f64 {
        percentile(&self.served_e2e(), p)
    }

    /// Mean queueing delay (arrival → solving epoch) over served
    /// requests.
    pub fn mean_wait_s(&self) -> f64 {
        let waits: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| o.disposition.is_served())
            .map(|o| o.wait_s)
            .collect();
        if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        }
    }

    /// Served requests per simulated second.
    pub fn throughput_hz(&self) -> f64 {
        if self.horizon_s <= 0.0 {
            0.0
        } else {
            self.served() as f64 / self.horizon_s
        }
    }

    pub fn peak_queue_depth(&self) -> usize {
        self.epochs.iter().map(|e| e.queue_depth).max().unwrap_or(0)
    }

    /// Total solve time hidden behind GPU execution, summed over
    /// epochs. Divide by `epochs.len() × solve_latency_s` for the
    /// run-wide overlap fraction.
    pub fn solve_hidden_s(&self) -> f64 {
        self.epochs.iter().map(|e| e.solve_hidden_s).sum()
    }

    /// Mean deadline-censored end-to-end delay (see
    /// [`censored_delays`]) — the drop-robust delay aggregate the
    /// pipeline comparisons use. 0.0 for an empty run.
    pub fn mean_e2e_censored_s(&self) -> f64 {
        mean_censored_delay(&self.outcomes)
    }
}

/// Deadline-censored end-to-end delays, one per outcome: served
/// requests charge their e2e, dropped ones their relative deadline
/// (the user waited at least that and got nothing) — so dropping
/// requests can never flatter a delay aggregate. The single censoring
/// definition every report and sweep shares.
pub fn censored_delays(outcomes: &[RequestOutcome]) -> Vec<f64> {
    outcomes
        .iter()
        .map(|o| if o.disposition.is_served() { o.e2e_s } else { o.deadline_s })
        .collect()
}

/// Mean of [`censored_delays`]; 0.0 for an empty set. Both engines'
/// reports delegate here so the aggregate can never drift between
/// them.
pub fn mean_censored_delay(outcomes: &[RequestOutcome]) -> f64 {
    if outcomes.is_empty() {
        return 0.0;
    }
    censored_delays(outcomes).iter().sum::<f64>() / outcomes.len() as f64
}

/// One queued request during simulation.
#[derive(Debug, Clone, Copy)]
struct Queued {
    id: usize,
    arrival_s: f64,
    abs_deadline_s: f64,
    deadline_s: f64,
    link: crate::channel::Link,
    mark: PromptMark,
    deferrals: u32,
}

/// Where resolved requests and epoch records land. [`simulate_dynamic`]
/// collects them into a [`DynamicReport`];
/// [`simulate_dynamic_streaming`] folds them into an
/// [`OutcomeAccumulator`] so memory stays flat over arbitrarily long
/// traces. Sinks only observe — they cannot influence the serving loop.
trait OutcomeSink {
    fn resolve(&mut self, outcome: RequestOutcome);
    fn epoch(&mut self, record: EpochRecord);
}

/// Sink behind [`simulate_dynamic`]: every outcome keyed by arrival id,
/// every epoch record kept.
struct CollectingSink {
    outcomes: Vec<Option<RequestOutcome>>,
    epochs: Vec<EpochRecord>,
}

impl OutcomeSink for CollectingSink {
    fn resolve(&mut self, outcome: RequestOutcome) {
        debug_assert!(self.outcomes[outcome.id].is_none(), "request {} resolved twice", outcome.id);
        self.outcomes[outcome.id] = Some(outcome);
    }

    fn epoch(&mut self, record: EpochRecord) {
        self.epochs.push(record);
    }
}

/// Sink behind [`simulate_dynamic_streaming`]: constant-memory
/// aggregates only.
struct StreamingSink {
    acc: OutcomeAccumulator,
    epochs: usize,
    peak_queue_depth: usize,
}

impl OutcomeSink for StreamingSink {
    fn resolve(&mut self, o: RequestOutcome) {
        self.acc.push(ResolvedSample {
            quality: o.quality,
            met: o.met,
            served: o.disposition.is_served(),
            e2e_s: o.e2e_s,
            wait_s: o.wait_s,
        });
    }

    fn epoch(&mut self, record: EpochRecord) {
        self.epochs += 1;
        self.peak_queue_depth = self.peak_queue_depth.max(record.queue_depth);
    }
}

/// Run the dynamic simulation of `trace` under the given policies.
///
/// MIRROR CONTRACT: `sim::event` replays this loop's epoch semantics
/// op-for-op (ingest rules, solve-lifecycle timing via
/// [`SolveTiming::compute`], admission, solve, resolve, carry-over) so
/// its zero-fault case stays bit-identical to the cluster layer — at
/// every solve latency and mode, not just the zero-latency default.
/// Any behavioural change here must be mirrored in
/// `sim::event::Engine::{solve_server, open_after_solve}` and
/// `ServerSim::ingest` — `tests/event_equivalence.rs` and
/// `tests/pipeline_equivalence.rs` are the guards. The loop itself
/// lives in [`run_dynamic_core`], shared op-for-op with
/// [`simulate_dynamic_streaming`].
pub fn simulate_dynamic(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &DynamicConfig,
) -> DynamicReport {
    simulate_dynamic_traced(trace, scheduler, allocator, delay, quality, cfg, &mut NullSink)
}

/// [`simulate_dynamic`] with a flight recorder attached: every
/// lifecycle transition (arrival, epoch freeze, solve start/done,
/// admission or drop, batch starts, drain, delivery) is mirrored into
/// `tracer` as it happens. The recorder only observes values the loop
/// already computed, so with any sink — including [`NullSink`], which
/// is what [`simulate_dynamic`] passes — the report is bit-identical
/// to the untraced run (`benches/obs_overhead.rs` gates this).
pub fn simulate_dynamic_traced(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &DynamicConfig,
    tracer: &mut dyn TraceSink,
) -> DynamicReport {
    let mut sink = CollectingSink { outcomes: vec![None; trace.len()], epochs: Vec::new() };
    let (horizon, cache_stats) = run_dynamic_core(
        trace.arrivals.iter().copied(),
        trace.total_bandwidth_hz,
        trace.content_bits,
        scheduler,
        allocator,
        delay,
        quality,
        cfg,
        &mut sink,
        tracer,
    );
    let outcomes: Vec<RequestOutcome> =
        sink.outcomes.into_iter().map(|o| o.expect("every request resolved")).collect();
    DynamicReport { outcomes, epochs: sink.epochs, horizon_s: horizon, cache_stats }
}

/// Constant-memory result of [`simulate_dynamic_streaming`]: streaming
/// aggregates instead of per-request outcomes and per-epoch records.
#[derive(Debug, Clone)]
pub struct StreamingDynamicReport {
    /// Aggregates over every resolved request (exact or sketch-backed,
    /// per the accumulator the caller passed in).
    pub accumulator: OutcomeAccumulator,
    /// Epoch solves that ran.
    pub epochs: usize,
    /// Deepest pre-admission queue any epoch saw.
    pub peak_queue_depth: usize,
    /// Total simulated span (last resolution instant).
    pub horizon_s: f64,
    /// Generation-cache counters (all zero when `[cache]` is disabled).
    pub cache_stats: CacheStats,
}

impl StreamingDynamicReport {
    pub fn count(&self) -> usize {
        self.accumulator.count()
    }

    pub fn served(&self) -> usize {
        self.accumulator.served()
    }

    pub fn dropped(&self) -> usize {
        self.count() - self.served()
    }

    /// The standard summary from the accumulator.
    pub fn stats(&self) -> OutcomeStats {
        self.accumulator.stats()
    }

    /// Served requests per simulated second.
    pub fn throughput_hz(&self) -> f64 {
        if self.horizon_s <= 0.0 {
            0.0
        } else {
            self.served() as f64 / self.horizon_s
        }
    }
}

/// [`simulate_dynamic`] over an arrival *iterator* — the serving loop
/// never materializes the trace or the per-request outcomes, so memory
/// stays flat no matter how many requests stream through (the
/// `fig_scale` bench drives 10⁷). Arrivals must be time-sorted with
/// dense ids starting at 0, exactly like `ArrivalTrace` — both
/// [`ArrivalStream`](crate::trace::ArrivalStream) and
/// [`ColumnarReader`](crate::trace::ColumnarReader) guarantee this.
///
/// Identical arrivals and config run the same floating-point ops in
/// the same order as [`simulate_dynamic`]: with an exact accumulator
/// the resulting [`OutcomeStats`] percentiles are bit-identical to the
/// collected report's.
pub fn simulate_dynamic_streaming(
    arrivals: impl Iterator<Item = Arrival>,
    total_bandwidth_hz: f64,
    content_bits: f64,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &DynamicConfig,
    accumulator: OutcomeAccumulator,
) -> StreamingDynamicReport {
    let mut sink = StreamingSink { acc: accumulator, epochs: 0, peak_queue_depth: 0 };
    let (horizon, cache_stats) = run_dynamic_core(
        arrivals,
        total_bandwidth_hz,
        content_bits,
        scheduler,
        allocator,
        delay,
        quality,
        cfg,
        &mut sink,
        &mut NullSink,
    );
    StreamingDynamicReport {
        accumulator: sink.acc,
        epochs: sink.epochs,
        peak_queue_depth: sink.peak_queue_depth,
        horizon_s: horizon,
        cache_stats,
    }
}

/// Epoch-scope flight-recorder event on the core's single server
/// (index 0 until a cluster merge remaps it).
fn mark(tracer: &mut dyn TraceSink, t_s: f64, kind: EventKind) {
    tracer.record(TraceEvent { t_s, server: 0, request: NO_REQUEST, kind });
}

/// Emit one `BatchStart` per run of equal-size batches: a run of `n`
/// same-size batches is `n` denoising steps through one batch-size
/// bucket — exactly how the runtime engine would execute it. Guarded by
/// `enabled()` so the untraced path never walks the schedule. Shared
/// with `sim::event`, which emits per-server.
pub(crate) fn emit_batches(
    tracer: &mut dyn TraceSink,
    server: usize,
    t0: f64,
    schedule: &crate::scheduler::Schedule,
) {
    if !tracer.enabled() {
        return;
    }
    let batches = &schedule.batches;
    let mut i = 0;
    while i < batches.len() {
        let size = batches[i].size();
        let mut j = i + 1;
        while j < batches.len() && batches[j].size() == size {
            j += 1;
        }
        let kind = EventKind::BatchStart { bucket: size as usize, steps: j - i };
        tracer.emit(t0 + batches[i].start, server, NO_REQUEST, kind);
        i = j;
    }
}

/// Ingest one arrival at its arrival instant. With the generation
/// cache enabled and the arrival marked, a content hit resolves the
/// request right here — [`Disposition::ServedFromCache`], transmission
/// over the full band, no epoch batch, no `should_close` contribution —
/// and returns its completion instant; a miss on a non-resident model
/// spends `load_delay_s` of the deadline budget on the swap before
/// queueing. With the cache disabled (`cache == None`) this is exactly
/// the pre-cache enqueue: same branches, same float ops, bitwise
/// identical. Shared by both ingest points of [`run_dynamic_core`].
fn ingest_arrival<S: OutcomeSink>(
    a: Arrival,
    epoch_index: usize,
    total_bandwidth_hz: f64,
    content_bits: f64,
    quality: &dyn QualityModel,
    cache: &mut Option<ServerCache>,
    queue: &mut Vec<Queued>,
    windows: &mut ServiceWindows,
    sink: &mut S,
    tracer: &mut dyn TraceSink,
) -> Option<f64> {
    windows.record_arrival(a.t_s);
    tracer.emit(a.t_s, 0, a.id, EventKind::Arrived);
    let mut deadline_s = a.deadline_s;
    if let Some(c) = cache.as_mut() {
        if !a.mark.is_zero() {
            if let Some(steps) = c.lookup(a.mark) {
                let e2e = a.link.tx_delay(content_bits, total_bandwidth_hz);
                let completion = a.t_s + e2e;
                let met = e2e <= a.deadline_s;
                let q = quality.quality(steps);
                tracer.emit(a.t_s, 0, a.id, EventKind::CacheHit { steps: steps as usize });
                tracer.emit(completion, 0, a.id, EventKind::Delivered { steps: steps as usize });
                windows.record_served(a.t_s, e2e, q, met);
                sink.resolve(RequestOutcome {
                    id: a.id,
                    arrival_s: a.t_s,
                    deadline_s: a.deadline_s,
                    disposition: Disposition::ServedFromCache,
                    steps,
                    quality: q,
                    e2e_s: e2e,
                    wait_s: 0.0,
                    deferrals: 0,
                    epoch: epoch_index,
                    met,
                    resolved_s: completion,
                    recovered_steps: 0,
                });
                return Some(completion);
            }
            // The generation must run here, so the model must be
            // resident: a swap eats into the residual deadline.
            deadline_s -= c.ensure_resident(a.mark.model);
        }
    }
    queue.push(Queued {
        id: a.id,
        arrival_s: a.t_s,
        abs_deadline_s: a.t_s + deadline_s,
        deadline_s,
        link: a.link,
        mark: a.mark,
        deferrals: 0,
    });
    None
}

/// The serving loop shared by both entry points: generic over where
/// arrivals come from and where outcomes land, so the buffered and the
/// streaming entries run the *same* floating-point operations in the
/// same order — the sinks only observe. Returns the simulated horizon
/// (last resolution instant) and the generation-cache counters (zeros
/// when `[cache]` is disabled).
fn run_dynamic_core<I, S>(
    arrivals: I,
    total_bandwidth_hz: f64,
    content_bits: f64,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &DynamicConfig,
    sink: &mut S,
    tracer: &mut dyn TraceSink,
) -> (f64, CacheStats)
where
    I: Iterator<Item = Arrival>,
    S: OutcomeSink,
{
    let mut arrivals = arrivals.peekable();
    let mut windows = ServiceWindows::new(cfg.window_s);
    let mut queue: Vec<Queued> = Vec::new();
    let mut clock = 0.0f64; // last solve instant
    let mut gpu_free = 0.0f64;
    let mut horizon = 0.0f64;
    let mut epoch_count = 0usize;
    let outage_q = quality.outage();
    // Off-by-default generation cache: `None` constructs nothing and
    // touches nothing — the bit-identity position.
    let mut cache: Option<ServerCache> =
        if cfg.cache.enabled { Some(ServerCache::new(&cfg.cache)) } else { None };

    while arrivals.peek().is_some() || !queue.is_empty() {
        // ---- open the next epoch ----
        // Carry-overs have been waiting since the last solve; otherwise
        // the epoch opens with the next arrival.
        let open = if queue.is_empty() {
            arrivals.peek().expect("empty queue implies a pending arrival").t_s
        } else {
            clock
        };
        let mut close = cfg.epoch.close_deadline(open);
        // Backlogged arrivals (t ≤ open) are already waiting: they join
        // unconditionally, like carry-overs. The batch rule below only
        // decides how long to keep waiting for *future* arrivals.
        while let Some(&a) = arrivals.peek() {
            if a.t_s > open {
                break;
            }
            arrivals.next();
            if let Some(done) = ingest_arrival(
                a,
                epoch_count,
                total_bandwidth_hz,
                content_bits,
                quality,
                &mut cache,
                &mut queue,
                &mut windows,
                sink,
                tracer,
            ) {
                horizon = horizon.max(done);
            }
        }
        while let Some(&a) = arrivals.peek() {
            if a.t_s > close {
                break;
            }
            arrivals.next();
            if let Some(done) = ingest_arrival(
                a,
                epoch_count,
                total_bandwidth_hz,
                content_bits,
                quality,
                &mut cache,
                &mut queue,
                &mut windows,
                sink,
                tracer,
            ) {
                horizon = horizon.max(done);
            }
            // Cache hits never queue, so they never close an epoch on
            // batch size — only generation work counts.
            if cfg.epoch.should_close(queue.len(), a.t_s - open) {
                close = a.t_s;
                break;
            }
        }
        if queue.is_empty() {
            // Every arrival this epoch was served straight from the
            // cache: nothing to freeze, solve, or execute.
            continue;
        }

        // The epoch is frozen at `close`; the lifecycle rule decides
        // when its solve runs (pipelined: immediately, overlapped with
        // the in-flight batch; synchronous: once the GPU frees) and
        // when the batch starts. Residual deadlines are evaluated at
        // the batch start — the instant the plan targets.
        let timing = SolveTiming::compute(close, gpu_free, cfg.solve_latency_s, cfg.solve_mode);
        let t0 = timing.batch_start_s;
        let epoch_index = epoch_count;
        let queue_depth = queue.len();
        mark(tracer, close, EventKind::EpochFrozen { epoch: epoch_index });
        mark(tracer, timing.solve_begin_s, EventKind::SolveStart { epoch: epoch_index });
        mark(tracer, timing.solve_end_s, EventKind::SolveDone { epoch: epoch_index });

        // ---- admission control ----
        // A request is hopeless once its residual budget cannot fit one
        // denoising step plus (with admission on) best-case
        // transmission over the whole band.
        let mut admitted: Vec<Queued> = Vec::new();
        let mut dropped_now = 0usize;
        for q in queue.drain(..) {
            let residual = q.abs_deadline_s - t0;
            let min_tx = if cfg.admission {
                q.link.tx_delay(content_bits, total_bandwidth_hz)
            } else {
                0.0
            };
            if residual < delay.g(1) + min_tx {
                let disposition = if q.deferrals == 0 {
                    Disposition::RejectedOnArrival
                } else {
                    Disposition::ExpiredInQueue
                };
                let kind = if q.deferrals == 0 { EventKind::Rejected } else { EventKind::Expired };
                tracer.emit(t0, 0, q.id, kind);
                windows.record_dropped(t0, outage_q);
                sink.resolve(RequestOutcome {
                    id: q.id,
                    arrival_s: q.arrival_s,
                    deadline_s: q.deadline_s,
                    disposition,
                    steps: 0,
                    quality: outage_q,
                    e2e_s: 0.0,
                    wait_s: t0 - q.arrival_s,
                    deferrals: q.deferrals,
                    epoch: epoch_index,
                    met: false,
                    resolved_s: t0,
                    recovered_steps: 0,
                });
                horizon = horizon.max(t0);
                dropped_now += 1;
            } else {
                tracer.emit(t0, 0, q.id, EventKind::Admitted { epoch: epoch_index });
                admitted.push(q);
            }
        }

        if admitted.is_empty() {
            // Everyone in this epoch was dropped; move on. The solve
            // still ran (admission is part of planning), so its cost
            // and overlap are charged like any other epoch's.
            clock = t0;
            mark(tracer, t0, EventKind::EpochDone { epoch: epoch_index });
            windows.record_solve(t0, cfg.solve_latency_s, timing.hidden_s);
            windows.prune(t0);
            let [p50_e2e_w, p95_e2e_w, p99_e2e_w] = windows.e2e_s.percentiles([50.0, 95.0, 99.0]);
            sink.epoch(EpochRecord {
                index: epoch_index,
                t_solve_s: t0,
                queue_depth,
                admitted: 0,
                served: 0,
                deferred: 0,
                dropped: dropped_now,
                makespan_s: 0.0,
                solve_hidden_s: timing.hidden_s,
                arrival_rate_hz: windows.arrivals.rate_hz(),
                mean_quality_w: windows.quality.mean(),
                outage_rate_w: windows.outage_rate(),
                p50_e2e_w,
                p95_e2e_w,
                p99_e2e_w,
                solve_overlap_w: windows.solve_overlap_fraction(),
            });
            epoch_count += 1;
            continue;
        }

        // ---- one (P0) solve over residual deadlines ----
        // Deadlines are clamped to the planning horizon so this epoch's
        // schedule cannot monopolize the GPU against future arrivals;
        // `met` stays conservative (met under the clamp ⇒ met for
        // real). The horizon itself may adapt to queue pressure.
        let plan_horizon = cfg.effective_plan_horizon(queue_depth);
        let devices: Vec<DeviceRequest> = admitted
            .iter()
            .enumerate()
            .map(|(i, q)| DeviceRequest {
                id: i,
                deadline: (q.abs_deadline_s - t0).min(plan_horizon),
                link: q.link,
            })
            .collect();
        let workload = Workload { devices, total_bandwidth_hz, content_bits };
        let sol = solve_joint(&workload, scheduler, allocator, delay, quality);
        let makespan = sol.outcome.schedule.makespan();
        emit_batches(tracer, 0, t0, &sol.outcome.schedule);

        // ---- resolve served requests; carry the rest over ----
        let mut served_now = 0usize;
        let mut deferred_now = 0usize;
        for (i, q) in admitted.into_iter().enumerate() {
            let svc = sol.outcome.services[i];
            if svc.steps > 0 {
                let completion = t0 + svc.e2e_delay;
                let e2e = completion - q.arrival_s;
                let met = svc.met; // e2e vs residual ⇔ completion vs absolute deadline
                let done = svc.steps as usize;
                tracer.emit(completion, 0, q.id, EventKind::Delivered { steps: done });
                windows.record_served(t0, e2e, svc.quality, met);
                sink.resolve(RequestOutcome {
                    id: q.id,
                    arrival_s: q.arrival_s,
                    deadline_s: q.deadline_s,
                    disposition: Disposition::Served,
                    steps: svc.steps,
                    quality: svc.quality,
                    e2e_s: e2e,
                    wait_s: t0 - q.arrival_s,
                    deferrals: q.deferrals,
                    epoch: epoch_index,
                    met,
                    resolved_s: completion,
                    recovered_steps: 0,
                });
                // A freshly generated result is cacheable content:
                // later arrivals with the same mark can skip the GPU.
                if let Some(c) = cache.as_mut() {
                    if !q.mark.is_zero() {
                        c.insert(q.mark, svc.steps);
                    }
                }
                horizon = horizon.max(completion);
                served_now += 1;
            } else {
                // Zero steps this epoch: defer — the request spans
                // epochs until served or infeasible.
                queue.push(Queued { deferrals: q.deferrals + 1, ..q });
                deferred_now += 1;
            }
        }

        gpu_free = t0 + makespan;
        mark(tracer, gpu_free, EventKind::EpochDone { epoch: epoch_index });
        clock = t0;
        horizon = horizon.max(gpu_free);
        windows.record_solve(t0, cfg.solve_latency_s, timing.hidden_s);
        windows.prune(t0);
        let [p50_e2e_w, p95_e2e_w, p99_e2e_w] = windows.e2e_s.percentiles([50.0, 95.0, 99.0]);
        sink.epoch(EpochRecord {
            index: epoch_index,
            t_solve_s: t0,
            queue_depth,
            admitted: served_now + deferred_now,
            served: served_now,
            deferred: deferred_now,
            dropped: dropped_now,
            makespan_s: makespan,
            solve_hidden_s: timing.hidden_s,
            arrival_rate_hz: windows.arrivals.rate_hz(),
            mean_quality_w: windows.quality.mean(),
            outage_rate_w: windows.outage_rate(),
            p50_e2e_w,
            p95_e2e_w,
            p99_e2e_w,
            solve_overlap_w: windows.solve_overlap_fraction(),
        });
        epoch_count += 1;
    }

    (horizon, cache.map(|c| c.stats()).unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::EqualAllocator;
    use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
    use crate::quality::PowerLawQuality;
    use crate::scheduler::Stacking;
    use crate::trace::ArrivalTrace;

    fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 1,
            zipf_s: 1.0,
            models: 1,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    fn marked_trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 12,
            zipf_s: 1.5,
            models: 2,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    fn enabled_cache() -> crate::cache::CacheSettings {
        crate::cache::CacheSettings { enabled: true, capacity: 32, ..Default::default() }
    }

    fn run(trace: &ArrivalTrace, cfg: &DynamicConfig) -> DynamicReport {
        simulate_dynamic(
            trace,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            cfg,
        )
    }

    #[test]
    fn every_request_resolved_exactly_once() {
        let t = trace(3.0, 60.0, 1);
        let report = run(&t, &DynamicConfig::default());
        assert_eq!(report.outcomes.len(), t.len());
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.id, i);
            match o.disposition {
                Disposition::Served => {
                    assert!(o.steps > 0);
                    assert!(o.e2e_s > 0.0);
                    assert!(o.resolved_s >= o.arrival_s);
                }
                _ => {
                    assert_eq!(o.steps, 0);
                    assert!(!o.met);
                }
            }
        }
        assert_eq!(report.served() + report.dropped(), t.len());
        assert!(!report.epochs.is_empty());
    }

    #[test]
    fn light_load_serves_everyone_within_deadline() {
        // λ = 0.5 Hz against a GPU that batches ~25 tasks/s: no backlog,
        // every paper-distribution deadline is comfortably met.
        let t = trace(0.5, 120.0, 2);
        let report = run(&t, &DynamicConfig::default());
        assert_eq!(report.dropped(), 0, "drops under light load");
        for o in &report.outcomes {
            assert!(o.met, "{o:?}");
            assert!(o.e2e_s <= o.deadline_s + 1e-9, "{o:?}");
            // waited at most one epoch plus one in-flight plan horizon
            assert!(o.wait_s <= 1.0 + 2.0 + 0.5, "{o:?}");
        }
        assert!(report.mean_quality() < 100.0, "quality {}", report.mean_quality());
    }

    #[test]
    fn deterministic_replay() {
        let t = trace(4.0, 90.0, 7);
        let cfg = DynamicConfig::default();
        let a = run(&t, &cfg);
        let b = run(&t, &cfg);
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.disposition, y.disposition);
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits(), "non-deterministic e2e");
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
        }
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    }

    #[test]
    fn overload_triggers_admission_and_quality_degrades() {
        let light = run(&trace(0.5, 120.0, 3), &DynamicConfig::default());
        let heavy = run(&trace(20.0, 120.0, 3), &DynamicConfig::default());
        // Overload must cost quality and may drop requests; it must
        // never deadlock or leave requests unresolved (checked by
        // construction in simulate_dynamic).
        assert!(heavy.mean_quality() > light.mean_quality());
        assert!(heavy.outage_rate() >= light.outage_rate());
        assert!(heavy.peak_queue_depth() >= light.peak_queue_depth());
    }

    #[test]
    fn full_batches_close_epochs_early() {
        // λ = 10 against max_batch 8 and a 5 s epoch: epochs must close
        // on batch size (~0.8 s apart), not on the timer.
        let cfg = DynamicConfig { epoch: EpochPolicy::new(5.0, 8), ..DynamicConfig::default() };
        let t = trace(10.0, 30.0, 4);
        let report = run(&t, &cfg);
        assert_eq!(report.outcomes.len(), t.len());
        let gaps: Vec<f64> =
            report.epochs.windows(2).map(|w| w[1].t_solve_s - w[0].t_solve_s).collect();
        assert!(
            gaps.iter().filter(|&&g| g < 5.0 - 1e-9).count() * 2 > gaps.len(),
            "most epochs should close early on batch size: {gaps:?}"
        );
        assert!(report.epochs.len() > 10);
    }

    #[test]
    fn windowed_metrics_track_arrival_rate() {
        let rate = 6.0;
        let t = trace(rate, 200.0, 5);
        let report = run(&t, &DynamicConfig::default());
        // After warm-up, the windowed arrival rate should be in the
        // right ballpark (Poisson noise over a 30 s window: σ ≈ 0.45).
        let late: Vec<f64> = report
            .epochs
            .iter()
            .filter(|e| e.t_solve_s > 50.0 && e.t_solve_s < 190.0)
            .map(|e| e.arrival_rate_hz)
            .collect();
        assert!(!late.is_empty());
        let mean = late.iter().sum::<f64>() / late.len() as f64;
        assert!((mean - rate).abs() < 1.5, "windowed rate {mean} vs λ {rate}");
    }

    #[test]
    fn carry_over_requests_span_epochs() {
        // Tiny epochs + bursty load ⇒ some requests must wait several
        // epochs yet still complete within their (long) deadlines.
        let cfg = DynamicConfig { epoch: EpochPolicy::new(0.25, 4), ..Default::default() };
        let report = run(&trace(12.0, 40.0, 6), &cfg);
        let max_deferrals = report.outcomes.iter().map(|o| o.deferrals).max().unwrap();
        let served_after_wait = report
            .outcomes
            .iter()
            .filter(|o| o.disposition == Disposition::Served && o.wait_s > 0.25)
            .count();
        assert!(served_after_wait > 0, "no request ever waited past an epoch");
        // deferrals happen under this pressure, or every epoch served
        // its whole queue (also fine) — but the accounting must agree:
        let total_deferrals: u32 = report.outcomes.iter().map(|o| o.deferrals).sum();
        let recorded: usize = report.epochs.iter().map(|e| e.deferred).sum();
        assert_eq!(total_deferrals as usize, recorded, "max {max_deferrals}");
    }

    #[test]
    fn admission_off_still_terminates_and_resolves_all() {
        let t = trace(15.0, 30.0, 8);
        let cfg = DynamicConfig { admission: false, ..Default::default() };
        let report = run(&t, &cfg);
        assert_eq!(report.outcomes.len(), t.len());
        // hard expiry still fires: nothing lingers much past its
        // deadline (one epoch + one in-flight plan horizon of slack)
        for o in &report.outcomes {
            let latest = o.arrival_s + o.deadline_s + cfg.epoch.epoch_s + cfg.plan_horizon_s + 1.0;
            assert!(o.resolved_s <= latest, "{o:?}");
        }
    }

    #[test]
    fn empty_trace_is_empty_report() {
        let t = ArrivalTrace {
            arrivals: vec![],
            total_bandwidth_hz: 40_000.0,
            content_bits: 24_000.0,
        };
        let report = run(&t, &DynamicConfig::default());
        assert!(report.outcomes.is_empty());
        assert!(report.epochs.is_empty());
        assert_eq!(report.mean_quality(), 0.0);
        assert_eq!(report.outage_rate(), 0.0);
        assert_eq!(report.throughput_hz(), 0.0);
    }

    #[test]
    fn adaptive_plan_horizon_is_monotone_bounded_and_off_by_default() {
        let off = DynamicConfig::default();
        assert!(!off.plan_horizon_adaptive, "adaptive horizon must be opt-in");
        for depth in [0, 1, 32, 500] {
            assert_eq!(off.effective_plan_horizon(depth), off.plan_horizon_s);
        }
        let cfg = DynamicConfig { plan_horizon_adaptive: true, ..DynamicConfig::default() };
        // monotone non-increasing in queue depth
        let horizons: Vec<f64> = (0..200).map(|d| cfg.effective_plan_horizon(d)).collect();
        assert!(
            horizons.windows(2).all(|w| w[1] <= w[0] + 1e-15),
            "horizon must shrink as the queue grows"
        );
        // stretched when idle, static value at one full batch, floored deep
        assert!((cfg.effective_plan_horizon(0) - 2.0 * cfg.plan_horizon_s).abs() < 1e-12);
        let full = cfg.effective_plan_horizon(cfg.epoch.max_batch);
        assert!((full - cfg.plan_horizon_s).abs() < 1e-12, "one full batch keeps the static value");
        let deep = cfg.effective_plan_horizon(100 * cfg.epoch.max_batch);
        assert!((deep - 0.25 * cfg.plan_horizon_s).abs() < 1e-12, "deep backlog hits the floor");
        for depth in 0..500 {
            let h = cfg.effective_plan_horizon(depth);
            assert!(h >= 0.25 * cfg.plan_horizon_s - 1e-12, "below floor at {depth}: {h}");
            assert!(h <= 2.0 * cfg.plan_horizon_s + 1e-12, "above ceiling at {depth}: {h}");
        }
    }

    #[test]
    fn zero_solve_latency_modes_are_bit_identical() {
        let t = trace(6.0, 60.0, 7);
        let pipelined =
            run(&t, &DynamicConfig { solve_mode: SolveMode::Pipelined, ..Default::default() });
        let sync =
            run(&t, &DynamicConfig { solve_mode: SolveMode::Synchronous, ..Default::default() });
        for (a, b) in pipelined.outcomes.iter().zip(&sync.outcomes) {
            assert_eq!(a.disposition, b.disposition);
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits());
            assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits());
        }
        assert_eq!(pipelined.horizon_s.to_bits(), sync.horizon_s.to_bits());
        for (a, b) in pipelined.epochs.iter().zip(&sync.epochs) {
            assert_eq!(a.t_solve_s.to_bits(), b.t_solve_s.to_bits());
            assert_eq!(a.solve_hidden_s, 0.0);
            assert_eq!(b.solve_hidden_s, 0.0);
            assert_eq!(a.solve_overlap_w, 0.0);
        }
    }

    #[test]
    fn pipelined_solve_hides_latency_under_backlog() {
        // Overload keeps the GPU busy past every epoch close, so the
        // pipelined solve overlaps execution while the synchronous one
        // idles the GPU — strictly later batches, strictly more delay.
        let t = trace(8.0, 60.0, 7);
        let base = DynamicConfig { solve_latency_s: 0.3, ..Default::default() };
        let pipelined = run(&t, &DynamicConfig { solve_mode: SolveMode::Pipelined, ..base });
        let sync = run(&t, &DynamicConfig { solve_mode: SolveMode::Synchronous, ..base });
        assert!(pipelined.solve_hidden_s() > 0.0, "backlog must hide some solve time");
        assert_eq!(sync.solve_hidden_s(), 0.0, "synchronous solves are never hidden");
        assert!(
            pipelined.mean_e2e_censored_s() < sync.mean_e2e_censored_s(),
            "pipelined {} vs synchronous {}",
            pipelined.mean_e2e_censored_s(),
            sync.mean_e2e_censored_s()
        );
        // the windowed gauge reports the hiding
        assert!(pipelined.epochs.iter().any(|e| e.solve_overlap_w > 0.0));
    }

    #[test]
    fn streaming_entry_matches_collected_report() {
        let t = trace(6.0, 60.0, 11);
        let cfg = DynamicConfig::default();
        let report = run(&t, &cfg);
        let stream = |acc: OutcomeAccumulator| {
            simulate_dynamic_streaming(
                t.arrivals.iter().copied(),
                t.total_bandwidth_hz,
                t.content_bits,
                &Stacking::default(),
                &EqualAllocator,
                &BatchDelayModel::paper(),
                &PowerLawQuality::paper(),
                &cfg,
                acc,
            )
        };
        let exact = stream(OutcomeAccumulator::exact());
        assert_eq!(exact.count(), report.outcomes.len());
        assert_eq!(exact.served(), report.served());
        assert_eq!(exact.dropped(), report.dropped());
        assert_eq!(exact.epochs, report.epochs.len());
        assert_eq!(exact.peak_queue_depth, report.peak_queue_depth());
        assert_eq!(exact.horizon_s.to_bits(), report.horizon_s.to_bits());
        let stats = exact.stats();
        // Resolution order re-associates the scalar sums, so means
        // match to fp tolerance; sorted percentiles are bit-equal.
        assert!((stats.mean_quality - report.mean_quality()).abs() < 1e-9);
        assert!((stats.outage_rate - report.outage_rate()).abs() < 1e-12);
        assert_eq!(stats.p50_e2e_s.to_bits(), report.e2e_percentile(50.0).to_bits());
        assert_eq!(stats.p95_e2e_s.to_bits(), report.e2e_percentile(95.0).to_bits());
        assert_eq!(stats.p99_e2e_s.to_bits(), report.e2e_percentile(99.0).to_bits());

        // A sketch-backed run pushes the same samples in the same
        // order: scalar aggregates are bit-equal, percentiles track the
        // exact ones within the sketch's rank bound.
        let eps = 0.01;
        let sketch = stream(OutcomeAccumulator::streaming(eps));
        assert_eq!(sketch.count(), exact.count());
        assert_eq!(sketch.served(), exact.served());
        let sk = sketch.stats();
        assert_eq!(sk.mean_quality.to_bits(), stats.mean_quality.to_bits());
        assert_eq!(sk.mean_wait_s.to_bits(), stats.mean_wait_s.to_bits());
        let mut served: Vec<f64> = report
            .outcomes
            .iter()
            .filter(|o| o.disposition == Disposition::Served)
            .map(|o| o.e2e_s)
            .collect();
        served.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = served.len() as f64;
        let budget = (eps * n).ceil() as i64 + 1;
        for (p, g) in [(50.0, sk.p50_e2e_s), (95.0, sk.p95_e2e_s), (99.0, sk.p99_e2e_s)] {
            let target = (p / 100.0 * n).ceil().max(1.0) as i64;
            let rank = served.iter().filter(|&&v| v <= g).count() as i64;
            assert!((rank - target).abs() <= budget, "p{p}: rank {rank} target {target}");
        }
    }

    #[test]
    fn streaming_empty_iterator_is_zero() {
        let r = simulate_dynamic_streaming(
            std::iter::empty(),
            40_000.0,
            24_000.0,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &DynamicConfig::default(),
            OutcomeAccumulator::exact(),
        );
        assert_eq!(r.count(), 0);
        assert_eq!(r.epochs, 0);
        assert_eq!(r.peak_queue_depth, 0);
        assert_eq!(r.horizon_s, 0.0);
        assert_eq!(r.throughput_hz(), 0.0);
        assert_eq!(r.stats(), crate::metrics::OutcomeStats::from_samples(&[]));
    }

    #[test]
    fn adaptive_horizon_changes_behaviour_under_pressure_only() {
        // Light load never fills an epoch past the batch cap, so the
        // adaptive horizon only stretches — everyone is still served.
        let t = trace(0.5, 60.0, 2);
        let adaptive = DynamicConfig { plan_horizon_adaptive: true, ..DynamicConfig::default() };
        let report = run(&t, &adaptive);
        assert_eq!(report.dropped(), 0, "adaptive horizon must not drop under light load");
        // Under pressure the shrunken horizon keeps epochs short: the
        // peak per-epoch makespan must not exceed the stretched bound.
        let heavy = run(&trace(15.0, 40.0, 3), &adaptive);
        let max_makespan = heavy.epochs.iter().map(|e| e.makespan_s).fold(0.0, f64::max);
        assert!(max_makespan <= 2.0 * adaptive.plan_horizon_s + 1.0, "makespan {max_makespan}");
    }

    #[test]
    fn disabled_cache_ignores_prompt_marks_bitwise() {
        // With `[cache]` off, prompt marks are inert payload: a marked
        // trace and its mark-stripped twin replay bitwise identically.
        let marked = marked_trace(6.0, 90.0, 13);
        assert!(marked.is_marked());
        let mut stripped = marked.clone();
        for a in &mut stripped.arrivals {
            a.mark = crate::trace::PromptMark::ZERO;
        }
        let cfg = DynamicConfig::default();
        assert!(!cfg.cache.enabled, "cache must be opt-in");
        let a = run(&marked, &cfg);
        let b = run(&stripped, &cfg);
        assert_eq!(a.cache_stats, crate::cache::CacheStats::default());
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.disposition, y.disposition);
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits());
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
        }
    }

    #[test]
    fn cache_hits_bypass_the_batch_and_conserve_census() {
        let t = marked_trace(6.0, 120.0, 5);
        let cfg = DynamicConfig { cache: enabled_cache(), ..Default::default() };
        let report = run(&t, &cfg);
        assert_eq!(report.outcomes.len(), t.len(), "census conservation");
        let hits: Vec<_> = report
            .outcomes
            .iter()
            .filter(|o| o.disposition == Disposition::ServedFromCache)
            .collect();
        assert!(!hits.is_empty(), "a 12-prompt Zipf(1.5) universe must repeat");
        assert_eq!(report.cache_stats.hits as usize, hits.len());
        assert!(report.cache_stats.insertions > 0);
        assert!(report.cache_stats.hit_rate() > 0.0);
        for o in &hits {
            assert!(o.steps > 0, "cached content has a real step count");
            assert_eq!(o.wait_s, 0.0, "hits never wait on an epoch");
            assert!(o.e2e_s > 0.0, "transmission is still paid");
            assert!(o.met, "tx over the full band beats any paper deadline");
        }
        // Deterministic replay, hits included.
        let again = run(&t, &cfg);
        assert_eq!(report.horizon_s.to_bits(), again.horizon_s.to_bits());
        for (x, y) in report.outcomes.iter().zip(&again.outcomes) {
            assert_eq!(x.disposition, y.disposition);
            assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits());
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
        }
    }

    #[test]
    fn model_swaps_charge_deadline_budget() {
        // Two models on a single-slot catalog: every model flip costs a
        // swap, visible both in the stats and as tightened deadlines.
        let t = marked_trace(4.0, 90.0, 9);
        let cache = crate::cache::CacheSettings {
            enabled: true,
            capacity: 0, // placement-only: no hits, swaps still charged
            ..Default::default()
        };
        let cfg = DynamicConfig { cache, ..Default::default() };
        let report = run(&t, &cfg);
        assert_eq!(report.cache_stats.hits, 0, "capacity 0 never hits");
        assert!(report.cache_stats.swaps > 0, "model flips must swap");
        let baseline = run(&t, &DynamicConfig::default());
        let tightened = report
            .outcomes
            .iter()
            .zip(&baseline.outcomes)
            .filter(|(c, b)| c.deadline_s < b.deadline_s)
            .count();
        assert!(tightened > 0, "some deadlines must show the swap charge");
    }

    #[test]
    fn cache_enabled_traced_run_audits_clean() {
        let t = marked_trace(6.0, 60.0, 9);
        let cfg = DynamicConfig { cache: enabled_cache(), ..DynamicConfig::default() };
        let plain = run(&t, &cfg);
        let mut rec = crate::obs::Recorder::new();
        let traced = simulate_dynamic_traced(
            &t,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &cfg,
            &mut rec,
        );
        assert_eq!(plain.horizon_s.to_bits(), traced.horizon_s.to_bits());
        assert!(plain.cache_stats.hits > 0, "the audit must see CacheHit events");
        let cache_hits =
            rec.events.iter().filter(|e| matches!(e.kind, EventKind::CacheHit { .. })).count();
        assert_eq!(cache_hits as u64, plain.cache_stats.hits);
        let audit = crate::obs::audit::audit_expecting(&rec.events, t.len());
        assert!(audit.is_clean(), "{}", audit.render());
    }

    #[test]
    fn traced_run_is_bit_identical_and_audits_clean() {
        let t = trace(6.0, 60.0, 9);
        let cfg = DynamicConfig { solve_latency_s: 0.2, ..DynamicConfig::default() };
        let plain = run(&t, &cfg);
        let mut rec = crate::obs::Recorder::new();
        let traced = simulate_dynamic_traced(
            &t,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &cfg,
            &mut rec,
        );
        assert_eq!(plain.horizon_s.to_bits(), traced.horizon_s.to_bits());
        for (a, b) in plain.outcomes.iter().zip(&traced.outcomes) {
            assert_eq!(a.disposition, b.disposition);
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits());
            assert_eq!(a.quality.to_bits(), b.quality.to_bits());
        }
        let audit = crate::obs::audit::audit_expecting(&rec.events, t.len());
        assert!(audit.is_clean(), "{}", audit.render());
        assert!(rec.events.len() > 2 * t.len(), "each request leaves several events");
    }
}
