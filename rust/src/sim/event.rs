//! Fault-aware shared-clock cluster engine — one discrete-event loop
//! over the whole fleet, replacing `simulate_cluster`'s per-server
//! sequential replay.
//!
//! `sim::cluster` routes every arrival up front and then replays each
//! server's serving loop to completion, one server at a time. That is
//! exact for an all-alive fleet (per-server loops are independent once
//! dispatch is fixed) but cannot express anything that happens *between*
//! servers mid-trace: failures, recoveries, or work moving across the
//! fleet. This engine runs the same per-server epoch semantics —
//! op-for-op identical to [`simulate_dynamic`](super::simulate_dynamic);
//! the zero-fault case reproduces
//! [`simulate_cluster`](super::simulate_cluster) bit-for-bit
//! (asserted by `tests/event_equivalence.rs`) — but under one shared
//! wall clock with an explicit, totally-ordered event stream:
//!
//! * **arrival** events route each request at its arrival instant
//!   through the live fleet (a failed server is skipped the moment it
//!   fails — the router's availability view is no longer stale);
//! * **epoch** events (per-server, naturally staggered: every server's
//!   epochs open and close on its own queue) freeze an epoch's
//!   membership at its close and run the (P0) solve once the GPU frees;
//! * **failure/recovery** events from a [`FaultScript`] toggle server
//!   availability; a dying server's queued-but-unsolved requests are
//!   handed to the configured [`MigrationPolicy`].
//!
//! Migration preserves the elapsed deadline budget: a re-routed request
//! keeps its original arrival id, arrival instant and absolute
//! deadline, so waiting on a dead server is never forgiven.
//!
//! **In-flight work dies with its server.** Under any faulted run the
//! engine is physically honest about committed batches: a death at `t`
//! stops the GPU mid-execution, so every batch member not yet
//! *delivered* by `t` dies with the server. What happens next is the
//! migration policy's call: the legacy policies lose those victims
//! (`LostToFailure`), while [`CheckpointOnDeath`] retracts each victim
//! at its last completed denoising-step boundary
//! ([`Schedule::steps_completed_by`](crate::scheduler::Schedule)) and,
//! after a configurable latent-transfer delay
//! ([`EventClusterConfig::resume_transfer_s`]), hands the *partial*
//! request back through the router with its original id, arrival
//! instant and absolute deadline — the resume-aware router
//! ([`Router::route_resume`]) credits the salvaged steps when
//! predicting marginal (P0) quality, and the serving solve adds them to
//! the delivered step count (`Disposition::ResumedElsewhere`,
//! `RequestOutcome::recovered_steps`). Zero-fault runs never track
//! in-flight state (the bookkeeping is gated on fault events
//! remaining), so they stay bit-identical to the fault-free engines.
//!
//! [`CheckpointOnDeath`]: crate::faults::CheckpointOnDeath
//!
//! Event ordering is total and deterministic: time-ascending, and at
//! equal instants fault events first, then arrivals, then per-server
//! epoch events by ascending server id. Identical inputs replay
//! bit-identically (asserted by `tests/migration_properties.rs`).
//!
//! **Pipelined epoch lifecycle.** Each server's epoch walks the
//! explicit state machine of [`crate::coordinator::lifecycle`]
//! (`Building → PlanPending → Solved → Executing → Closed`): under the
//! default [`SolveMode::Pipelined`], epoch n+1's (P1)∘(P2) solve runs
//! on CPU from the freeze instant — overlapped with epoch n's batch on
//! the GPU — so nonzero `solve_latency_s` is hidden whenever the GPU
//! is backlogged. [`SolveMode::Synchronous`] replays the paper's
//! solve-then-execute loop. Zero solve latency keeps both modes
//! bit-identical to the pre-pipeline engine
//! (`tests/pipeline_equivalence.rs`). A server dying before its batch
//! starts (any phase up to `Solved`) strands the queued epoch exactly
//! as before; a committed batch (`Executing`) is cut at the death
//! instant — delivered members stand, undelivered members are lost or
//! checkpointed per the migration policy.
//!
//! **Dispatch state.** Before every routing decision the engine
//! publishes each server's true queue depth and `gpu_free` as a
//! [`LiveView`], so [`RouterKind::LiveState`] dispatches on live state
//! while the virtual-view policies (which ignore the view) stay
//! bit-identical to `simulate_cluster`; `bench::fig_pipeline`
//! quantifies the stale-vs-live gap.
//!
//! **Allocators.** Solves draw per-server allocator instances from an
//! [`AllocatorPool`] (`simulate_event_cluster_pooled`), so PSO
//! warm-start state is per server and the shared-clock solve order no
//! longer interleaves swarm state across the fleet — with per-server
//! pools the engines coincide bitwise even under warm starts. The
//! legacy `simulate_event_cluster` entry point shares one instance
//! fleet-wide, as before.
//!
//! **Generation cache.** With `[cache]` enabled each server carries a
//! [`ServerCache`]: an arrival whose `(model, prompt)` mark hits the
//! routed server's cache bypasses the epoch batch entirely and is
//! delivered after transmission alone (`Disposition::ServedFromCache`
//! — it never joins an epoch, so it neither counts toward the
//! batch-close rule nor consumes GPU time); a miss whose model is not
//! resident charges the catalog's load delay by tightening the
//! request's residual deadline. Fresh generations populate the serving
//! server's cache at resolution. Disabled (the default) no cache is
//! constructed and runs are bitwise identical to the pre-cache engine.
//! Hand-offs (migration, steal, resume) intentionally skip the cache:
//! a checkpointed partial cannot be served from cache, and the legacy
//! migration paths must stay byte-comparable across cache configs.
//!
//! **Hot-path structure.** The main loop picks each next server event
//! from a lazily-invalidated min-heap over `(time, server)` — updated
//! only when a server's epoch state actually changes — instead of
//! rescanning the whole fleet per iteration; and a mid-batch death
//! retracts a victim's optimistic resolution through an O(1) position
//! map + in-place tombstone instead of scanning everything its server
//! ever resolved. Both are pure data-structure swaps: the event order
//! and every float op are unchanged (gated bitwise by
//! `tests/exec_determinism.rs` and `tests/migration_properties.rs`).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::bandwidth::{Allocator, AllocatorPool};
use crate::cache::{CacheStats, ServerCache};
use crate::channel::Link;
use crate::coordinator::{EpochPhase, EpochPolicy, SolveMode, SolveTiming};
use crate::delay::BatchDelayModel;
use crate::faults::{FaultEvent, FaultKind, FaultScript, MigrationPolicy, MigrationPolicyKind};
use crate::metrics::{
    MetricsMode, OutcomeAccumulator, OutcomeStats, RecoverySample, RecoveryStats, ServiceWindows,
};
use crate::obs::{EventKind, NullSink, TraceSink, NO_REQUEST};
use crate::quality::QualityModel;
use crate::routing::{
    live_queue_cost_s, FleetIndex, LiveView, RouteContext, Router, RouterKind, ServerState,
};
use crate::scheduler::{BatchScheduler, Schedule};
use crate::trace::{Arrival, ArrivalTrace, DeviceRequest, PromptMark, Workload};
use crate::util::exec::par_map;

use super::cluster::{sample, samples, ClusterConfig};
use super::dynamic::{emit_batches, Disposition, DynamicConfig, EpochRecord, RequestOutcome};
use super::{solve_joint, JointSolution};

/// Sentinel in [`EventReport::assignment`] for a request that was never
/// dispatched to any server (the whole fleet was down from its arrival
/// until its deadline).
pub const UNROUTED: usize = usize::MAX;

/// In-progress tombstone in a server's `resolved_ids` for an outcome a
/// mid-batch death retracted. Written in place (preserving every other
/// entry's position and the final emission order) and filtered out
/// before delivery emission and the report — it never escapes the
/// engine.
const RETRACTED: usize = usize::MAX;

/// Settings for one fault-aware cluster run. Fleet-shaped inputs
/// (speeds, fault script) are borrowed, not owned: sweeps build one
/// config per cell — λ × router × policy grids used to clone both per
/// cell, which was pure churn since every cell reads them immutably.
#[derive(Debug, Clone)]
pub struct EventClusterConfig<'a> {
    /// Per-server GPU speed factors (1.0 = the reference delay model).
    pub speeds: &'a [f64],
    /// Dispatch policy.
    pub router: RouterKind,
    /// Per-server serving-loop settings (shared by every server).
    pub dynamic: DynamicConfig,
    /// Failure trace to inject ([`crate::faults::NO_FAULTS`] =
    /// all-alive).
    pub faults: &'a FaultScript,
    /// What happens to a dead/overloaded server's queued requests.
    pub migration: MigrationPolicyKind,
    /// Latent-transfer delay charged when a checkpointed partial
    /// request moves off a dead server: the victim re-enters the router
    /// at `death + resume_transfer_s` (shipping the denoising latent to
    /// the new edge server is not free). Only read under
    /// [`MigrationPolicyKind::Checkpoint`].
    pub resume_transfer_s: f64,
}

impl<'a> EventClusterConfig<'a> {
    /// The zero-fault configuration equivalent to `cluster` — the
    /// bit-identity case against
    /// [`simulate_cluster`](super::simulate_cluster).
    pub fn fault_free(cluster: &'a ClusterConfig) -> Self {
        Self {
            speeds: &cluster.speeds,
            router: cluster.router,
            dynamic: cluster.dynamic,
            faults: &crate::faults::NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        }
    }

    pub fn servers(&self) -> usize {
        self.speeds.len()
    }
}

/// Why a request moved between servers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationReason {
    /// Its server died with the request still queued.
    DeadServer,
    /// A carry-over handed back to the router because an idle sibling
    /// existed at the solve instant.
    StealWhenIdle,
    /// Re-dispatched from the unroutable pool when a server recovered.
    Recovery,
    /// Checkpointed off a dying server mid-batch: the partial request
    /// (completed steps in hand) resumed on the destination after the
    /// latent transfer.
    Checkpoint,
}

/// One hand-off of a request through the router after its initial
/// dispatch (or, for `to: None`, a failed hand-off that parked it).
#[derive(Debug, Clone, Copy)]
pub struct MigrationRecord {
    /// Global arrival id — migration never renames a request.
    pub id: usize,
    /// Server it left (`None`: it was parked unroutable).
    pub from: Option<usize>,
    /// Server it landed on (`None`: no server was alive; parked).
    pub to: Option<usize>,
    pub t_s: f64,
    pub reason: MigrationReason,
}

/// One server's slice of a fault-aware cluster run.
#[derive(Debug, Clone)]
pub struct EventServerReport {
    pub server: usize,
    pub speed: f64,
    /// Global ids first dispatched here, in dispatch order.
    pub assigned_ids: Vec<usize>,
    /// Global ids this server resolved (served or dropped), in
    /// resolution order — under migration this differs from
    /// `assigned_ids`.
    pub resolved_ids: Vec<usize>,
    pub epochs: Vec<EpochRecord>,
    /// Total time this server spent failed.
    pub downtime_s: f64,
    /// Generation-cache counters for this server — all zero when the
    /// cache is disabled.
    pub cache_stats: CacheStats,
}

/// Complete result of a fault-aware cluster run.
#[derive(Debug, Clone)]
pub struct EventReport {
    /// One outcome per trace arrival, indexed by (global) arrival id.
    pub outcomes: Vec<RequestOutcome>,
    /// First dispatch destination per arrival ([`UNROUTED`] when the
    /// request never reached any server).
    pub assignment: Vec<usize>,
    pub servers: Vec<EventServerReport>,
    /// Every post-dispatch hand-off, in hand-off order.
    pub migrations: Vec<MigrationRecord>,
    /// Availability transitions that actually fired during the run.
    pub fault_log: Vec<FaultEvent>,
    /// Total simulated span.
    pub horizon_s: f64,
}

impl EventReport {
    pub fn served(&self) -> usize {
        self.fleet_stats().served
    }

    pub fn dropped(&self) -> usize {
        self.outcomes.len() - self.served()
    }

    /// The fleet (P0) objective: mean charged quality over every
    /// request that entered the cluster.
    pub fn mean_quality(&self) -> f64 {
        self.fleet_stats().mean_quality
    }

    pub fn outage_rate(&self) -> f64 {
        self.fleet_stats().outage_rate
    }

    /// Fleet-wide summary (quality, outage, e2e percentiles, wait).
    pub fn fleet_stats(&self) -> OutcomeStats {
        OutcomeStats::from_samples(&samples(&self.outcomes))
    }

    /// Fleet summary folded through an [`OutcomeAccumulator`] in one
    /// pass over the outcomes — with [`MetricsMode::Streaming`] the
    /// e2e percentiles come from a GK sketch, so nothing proportional
    /// to the request count is materialized or sorted. Exact mode
    /// pushes in id order and reproduces
    /// [`fleet_stats`](Self::fleet_stats) bit-for-bit.
    pub fn fleet_stats_with(&self, mode: MetricsMode, eps: f64) -> OutcomeStats {
        let mut acc = OutcomeAccumulator::for_mode(mode, eps);
        for o in &self.outcomes {
            acc.push(sample(o));
        }
        acc.stats()
    }

    /// Summary over the requests one server resolved.
    pub fn server_stats(&self, server: usize) -> OutcomeStats {
        let outcomes: Vec<RequestOutcome> =
            self.servers[server].resolved_ids.iter().map(|&id| self.outcomes[id]).collect();
        OutcomeStats::from_samples(&samples(&outcomes))
    }

    /// Requests dropped because their server died (no or failed
    /// migration).
    pub fn lost_to_failure(&self) -> usize {
        self.outcomes.iter().filter(|o| o.disposition == Disposition::LostToFailure).count()
    }

    /// Requests whose in-flight work was checkpointed off a dying
    /// server and finished elsewhere.
    pub fn resumed_elsewhere(&self) -> usize {
        self.outcomes.iter().filter(|o| o.disposition == Disposition::ResumedElsewhere).count()
    }

    /// Denoising steps salvaged from dead servers' checkpoints, summed
    /// over every resumed request.
    pub fn recovered_steps(&self) -> u64 {
        self.outcomes.iter().map(|o| o.recovered_steps as u64).sum()
    }

    /// Successful hand-offs that actually changed servers.
    pub fn migrated(&self) -> usize {
        self.migrations.iter().filter(|m| m.to.is_some() && m.to != m.from).count()
    }

    pub fn failures(&self) -> usize {
        self.fault_log.iter().filter(|e| e.kind == FaultKind::Down).count()
    }

    /// Epoch solves summed over servers.
    pub fn total_epochs(&self) -> usize {
        self.servers.iter().map(|s| s.epochs.len()).sum()
    }

    /// Fleet-wide generation-cache counters (all zero when disabled).
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.servers {
            total.merge(&s.cache_stats);
        }
        total
    }

    /// Requests served straight from a generation cache.
    pub fn served_from_cache(&self) -> usize {
        self.outcomes.iter().filter(|o| o.disposition == Disposition::ServedFromCache).count()
    }

    /// Deepest per-epoch queue any single server saw.
    pub fn peak_queue_depth(&self) -> usize {
        self.servers
            .iter()
            .map(|s| s.epochs.iter().map(|e| e.queue_depth).max().unwrap_or(0))
            .max()
            .unwrap_or(0)
    }

    /// Deferral (cross-epoch carry-over) events summed over requests.
    pub fn total_deferrals(&self) -> usize {
        self.outcomes.iter().map(|o| o.deferrals as usize).sum()
    }

    /// Mean deadline-censored end-to-end delay (served requests charge
    /// their e2e, dropped ones their relative deadline — see
    /// [`super::dynamic::censored_delays`]) — the drop-robust delay
    /// aggregate the pipeline sweep compares on. 0.0 for an empty run.
    pub fn mean_e2e_censored_s(&self) -> f64 {
        super::dynamic::mean_censored_delay(&self.outcomes)
    }

    /// Percentile of the deadline-censored end-to-end delays.
    pub fn e2e_censored_percentile(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&super::dynamic::censored_delays(&self.outcomes), p)
    }

    /// Total solve time hidden behind GPU execution, summed over every
    /// server's epochs.
    pub fn solve_hidden_s(&self) -> f64 {
        self.servers
            .iter()
            .map(|s| s.epochs.iter().map(|e| e.solve_hidden_s).sum::<f64>())
            .sum()
    }

    /// Post-failure recovery aggregates (time-to-drain, censored p99
    /// tail over the `window_s` after each failure, migration counts).
    pub fn recovery_stats(&self, window_s: f64) -> RecoveryStats {
        let failures: Vec<f64> = self
            .fault_log
            .iter()
            .filter(|e| e.kind == FaultKind::Down)
            .map(|e| e.t_s)
            .collect();
        let samples: Vec<RecoverySample> = self
            .outcomes
            .iter()
            .map(|o| RecoverySample {
                arrival_s: o.arrival_s,
                resolved_s: o.resolved_s,
                e2e_s: o.e2e_s,
                deadline_s: o.deadline_s,
                served: o.disposition.is_served(),
                resumed: o.disposition == Disposition::ResumedElsewhere,
                recovered_steps: o.recovered_steps,
                met: o.met,
            })
            .collect();
        let migrated = self.migrated();
        let lost = self.lost_to_failure();
        RecoveryStats::compute(&failures, window_s, migrated, lost, &samples)
    }
}

/// One request queued somewhere in the fleet.
#[derive(Debug, Clone, Copy)]
struct Pending {
    /// Global arrival id — preserved across migrations.
    id: usize,
    /// Original arrival instant — preserved across migrations.
    arrival_s: f64,
    /// When it entered its *current* server's stream (= `arrival_s`
    /// until migrated).
    enqueued_s: f64,
    /// Absolute deadline — preserved across migrations (elapsed budget
    /// is never refunded).
    abs_deadline_s: f64,
    /// Relative deadline τ.
    deadline_s: f64,
    link: Link,
    /// Content identity `(model, prompt)` — zero on unmarked traces;
    /// read only by the generation cache.
    mark: PromptMark,
    deferrals: u32,
    /// Already counted in the current server's arrival window (reset
    /// when migrating to a different server, so per-server windows see
    /// each request at most once).
    recorded: bool,
    /// Denoising steps already completed on earlier (dead) servers and
    /// carried along in the checkpointed latent — credited on top of
    /// whatever the serving solve schedules. 0 on the normal path.
    done_steps: u32,
}

impl Pending {
    fn from_arrival(a: &Arrival) -> Self {
        Self {
            id: a.id,
            arrival_s: a.t_s,
            enqueued_s: a.t_s,
            abs_deadline_s: a.t_s + a.deadline_s,
            deadline_s: a.deadline_s,
            link: a.link,
            mark: a.mark,
            deferrals: 0,
            recorded: false,
            done_steps: 0,
        }
    }
}

/// One undelivered member of a committed batch — everything a mid-batch
/// death needs to retract it and (under checkpointing) resume it.
#[derive(Debug, Clone, Copy)]
struct InFlightReq {
    /// Queue state as of the batch start (includes prior `done_steps`
    /// if the request was itself a resume).
    pending: Pending,
    /// Absolute delivery instant the optimistic outcome recorded.
    completion_s: f64,
    /// Slot in the committed plan (= `TaskRef::service`), for
    /// step-boundary accounting against the schedule.
    service_slot: usize,
}

/// The batch currently committed on a server's GPU. Tracked only while
/// fault events remain (a zero-fault run allocates none of this), so
/// that a death can cut the batch at the wall clock instead of
/// pretending it ran to completion.
#[derive(Debug, Clone)]
struct InFlight {
    /// Batch start (the solve's `t0`).
    start_s: f64,
    /// End of the generation phase (`t0 + makespan`); past it only
    /// transmission tails remain and the batch can no longer be cut.
    gen_end_s: f64,
    /// The committed plan, for [`Schedule::steps_completed_by`].
    schedule: Schedule,
    /// Members the optimistic resolve already recorded as served.
    requests: Vec<InFlightReq>,
}

/// One server's epoch walking the lifecycle state machine
/// ([`EpochPhase`]): `Building` while arrivals may still join, then
/// frozen (`PlanPending` onward) with its solve/batch instants fixed
/// by [`SolveTiming`].
#[derive(Debug, Clone)]
struct Epoch {
    open_s: f64,
    /// Scheduled close (timer), pulled earlier on batch-fill. Once
    /// frozen this is the solve-lifecycle anchor.
    close_s: f64,
    /// Lifecycle phase. `Building` = open; anything later = membership
    /// frozen, no further joins.
    phase: EpochPhase,
    queue: Vec<Pending>,
}

impl Epoch {
    fn frozen(&self) -> bool {
        self.phase != EpochPhase::Building
    }

    fn freeze(&mut self, close_s: f64) {
        debug_assert!(!self.frozen());
        self.close_s = close_s;
        self.phase = self.phase.advance();
    }
}

/// One server's live serving-loop state.
struct ServerSim {
    id: usize,
    speed: f64,
    /// Speed-scaled delay model `g_s(X) = g(X)/speed`.
    delay: BatchDelayModel,
    /// Solve-lifecycle settings (shared fleet-wide from the dynamic
    /// config; copied here so timing never needs the engine).
    solve_latency_s: f64,
    solve_mode: SolveMode,
    alive: bool,
    epoch: Option<Epoch>,
    /// Requests routed here while the current epoch was frozen; they
    /// seed the next epoch, exactly like simulate_dynamic's
    /// not-yet-ingested trace arrivals.
    backlog: VecDeque<Pending>,
    gpu_free_s: f64,
    /// The committed batch on the GPU (`None` in zero-fault runs and
    /// once the last fault has fired — stale entries are harmless: the
    /// death-time cut only applies strictly before `gen_end_s`, and a
    /// later batch always overwrites).
    in_flight: Option<InFlight>,
    windows: ServiceWindows,
    epochs: Vec<EpochRecord>,
    assigned_ids: Vec<usize>,
    resolved_ids: Vec<usize>,
    /// `resolved_ids` position per id, maintained only while fault
    /// events remain (the only runs where a retraction can happen) so
    /// a mid-batch death tombstones a victim in O(1) instead of
    /// scanning everything this server ever resolved. Positions are
    /// stable: `resolved_ids` is append-only with in-place tombstones.
    resolved_pos: HashMap<usize, usize>,
    down_since: Option<f64>,
    downtime_s: f64,
}

impl ServerSim {
    fn new(id: usize, speed: f64, reference: &BatchDelayModel, dynamic: &DynamicConfig) -> Self {
        Self {
            id,
            speed,
            delay: BatchDelayModel::new(reference.a / speed, reference.b / speed),
            solve_latency_s: dynamic.solve_latency_s,
            solve_mode: dynamic.solve_mode,
            alive: true,
            epoch: None,
            backlog: VecDeque::new(),
            gpu_free_s: 0.0,
            in_flight: None,
            windows: ServiceWindows::new(dynamic.window_s),
            epochs: Vec::new(),
            assigned_ids: Vec::new(),
            resolved_ids: Vec::new(),
            resolved_pos: HashMap::new(),
            down_since: None,
            downtime_s: 0.0,
        }
    }

    /// Count a request in this server's arrival window, at most once
    /// per server (simulate_dynamic records at first epoch entry and
    /// never re-records carry-overs).
    fn note_arrival(windows: &mut ServiceWindows, p: &mut Pending) {
        if !p.recorded {
            windows.record_arrival(p.enqueued_s);
            p.recorded = true;
        }
    }

    /// Route a request into this server's stream at instant `t`,
    /// replaying simulate_dynamic's ingest rules: join an open epoch
    /// (unconditionally at `t ≤ open`, with the batch-close check past
    /// it), or wait in the backlog while an epoch is frozen.
    fn ingest(&mut self, mut p: Pending, t: f64, policy: &EpochPolicy) {
        match self.epoch.as_mut() {
            None => {
                Self::note_arrival(&mut self.windows, &mut p);
                let e = Epoch {
                    open_s: t,
                    close_s: policy.close_deadline(t),
                    phase: EpochPhase::Building,
                    queue: vec![p],
                };
                self.epoch = Some(e);
            }
            Some(e) if !e.frozen() => {
                Self::note_arrival(&mut self.windows, &mut p);
                e.queue.push(p);
                if t > e.open_s && policy.should_close(e.queue.len(), t - e.open_s) {
                    e.freeze(t);
                }
            }
            Some(_) => self.backlog.push_back(p),
        }
    }

    /// The frozen epoch's solve/batch instants under this server's
    /// lifecycle settings. `gpu_free_s` cannot change between the
    /// freeze and the batch start (this server's GPU is serial), so
    /// the timing is fixed the moment the epoch freezes.
    fn solve_timing(&self, e: &Epoch) -> SolveTiming {
        debug_assert!(e.frozen());
        SolveTiming::compute(e.close_s, self.gpu_free_s, self.solve_latency_s, self.solve_mode)
    }

    /// The instant this server next needs the shared clock: its epoch
    /// timer (building) or its batch start (frozen — under the
    /// pipelined lifecycle the solve itself runs earlier, overlapped
    /// with the in-flight batch). Dead or idle servers have no events.
    fn next_event_time(&self) -> Option<f64> {
        if !self.alive {
            return None;
        }
        match &self.epoch {
            Some(e) if !e.frozen() => Some(e.close_s),
            Some(e) => Some(self.solve_timing(e).batch_start_s),
            None => None,
        }
    }

    /// Requests actually waiting on this server (open/frozen epoch
    /// plus backlog) — the live queue depth the router may read.
    fn queued(&self) -> usize {
        self.epoch.as_ref().map(|e| e.queue.len()).unwrap_or(0) + self.backlog.len()
    }

    /// No queued work and a free GPU at `t` — a steal target.
    fn is_idle(&self, t: f64) -> bool {
        self.epoch.is_none() && self.backlog.is_empty() && self.gpu_free_s <= t
    }
}

struct Engine<'a> {
    trace: &'a ArrivalTrace,
    scheduler: &'a dyn BatchScheduler,
    /// One allocator per server (a shared pool repeats one instance) —
    /// PSO warm-start state is per server, not fleet-wide.
    allocators: Vec<&'a dyn Allocator>,
    /// Reference (speed-1.0) delay model — parameterizes routing's
    /// shared service estimate, exactly as in `route_trace`.
    delay: &'a BatchDelayModel,
    quality: &'a dyn QualityModel,
    /// Per-server serving settings; `dynamic.threads` also gates the
    /// solve fan-out — frozen epochs whose batch starts coincide on
    /// the shared clock solve concurrently, with (P0) inputs fixed at
    /// the freeze, so the fan-out is bit-identical to the serial event
    /// order (see `run`).
    dynamic: DynamicConfig,
    policy: Box<dyn MigrationPolicy>,
    router: Box<dyn Router>,
    /// The router's virtual-queue view of the fleet (liveness is kept
    /// current by fault events — the non-stale part of the view).
    states: Vec<ServerState>,
    /// Ordered dispatch index over `states` (work half) and the
    /// published live views (live half) — maintained at every state
    /// mutation so `route_indexed` sees exactly what the scan would.
    index: FleetIndex,
    /// Route through the O(N) scan path instead of the index —
    /// [`simulate_event_cluster_scan`]'s executable specification for
    /// the bitwise-identity gates. The index is maintained either way.
    scan_routing: bool,
    /// Dirty-set incremental live publication: servers whose engine
    /// state changed since the last dispatch. `live_dirty` dedups,
    /// `dirty` is the drain list.
    live_dirty: Vec<bool>,
    dirty: Vec<usize>,
    ctx: RouteContext,
    servers: Vec<ServerSim>,
    fault_events: Vec<FaultEvent>,
    next_fault: usize,
    next_arrival: usize,
    /// Requests with no alive server to go to, waiting for a recovery.
    unroutable: VecDeque<Pending>,
    /// Checkpointed partials in latent transfer: `(resume_s, from,
    /// request)`. Deaths are consumed in time order and the transfer
    /// delay is constant, so the queue is non-decreasing in `resume_s`.
    resume_q: VecDeque<(f64, usize, Pending)>,
    /// Latent-transfer delay for checkpointed resumes.
    transfer_s: f64,
    /// Per-server generation caches — `None` unless `[cache]` is
    /// enabled, so disabled runs construct nothing and stay bitwise
    /// identical to the pre-cache engine.
    caches: Option<Vec<ServerCache>>,
    /// Lazily-invalidated min-heap over per-server next-event times,
    /// keyed `(t.to_bits(), id)` — sim times are non-negative, so the
    /// bit order is the float order and ties break by ascending id,
    /// exactly the old full-fleet scan's order. Entries go stale when
    /// a server's epoch state changes; [`Engine::next_server_event`]
    /// discards any entry that no longer matches `next_event_time()`.
    server_events: BinaryHeap<Reverse<(u64, usize)>>,
    outcomes: Vec<Option<RequestOutcome>>,
    assignment: Vec<usize>,
    migrations: Vec<MigrationRecord>,
    fault_log: Vec<FaultEvent>,
    horizon: f64,
    outage_q: f64,
    /// Flight recorder ([`NullSink`] on the untraced entry points).
    /// Emission happens only in the deterministic serial phases — never
    /// inside `solve_batch`'s `par_map` closure — so captures replay
    /// bit-identically. Delivery events are deferred to [`finish`]
    /// (see [`Engine::emit_deliveries`]).
    ///
    /// [`finish`]: Engine::finish
    tracer: &'a mut dyn TraceSink,
}

fn better(cand: (f64, u8, usize), best: Option<(f64, u8, usize)>) -> bool {
    match best {
        None => true,
        Some(b) => cand.0 < b.0 || (cand.0 == b.0 && (cand.1, cand.2) < (b.1, b.2)),
    }
}

impl Engine<'_> {
    /// Epoch-scope flight-recorder event on `server`'s timeline.
    fn mark(&mut self, t_s: f64, server: usize, kind: EventKind) {
        self.tracer.emit(t_s, server, NO_REQUEST, kind);
    }

    /// Re-index `idx` after anything that can move its next event:
    /// an ingest (epoch opened or batch-filled early), a timer freeze,
    /// or a solve opening the next epoch. Stale entries left behind are
    /// discarded lazily by [`next_server_event`](Self::next_server_event).
    fn touch(&mut self, idx: usize) {
        if let Some(t) = self.servers[idx].next_event_time() {
            debug_assert!(t >= 0.0, "sim clock went negative");
            self.server_events.push(Reverse((t.to_bits(), idx)));
        }
    }

    /// Earliest live `(time, server)` epoch event, or `None` when no
    /// server has one. Non-destructive for the winning entry (the main
    /// loop may hand the instant to a fault or arrival instead); stale
    /// entries are popped on the way.
    fn next_server_event(&mut self) -> Option<(f64, usize)> {
        while let Some(&Reverse((bits, idx))) = self.server_events.peek() {
            match self.servers[idx].next_event_time() {
                Some(cur) if cur.to_bits() == bits => return Some((cur, idx)),
                _ => {
                    self.server_events.pop();
                }
            }
        }
        None
    }

    fn run(&mut self) {
        loop {
            let work_left = self.next_arrival < self.trace.len()
                || self.servers.iter().any(|s| s.epoch.is_some())
                || !self.unroutable.is_empty()
                || !self.resume_q.is_empty();
            if !work_left {
                break;
            }
            // Earliest event wins; ties break fault < resume < arrival
            // < server, then ascending server id — a fixed total order,
            // so replay is bit-identical.
            let mut best: Option<(f64, u8, usize)> = None;
            if self.next_fault < self.fault_events.len() {
                let c = (self.fault_events[self.next_fault].t_s, 0u8, 0usize);
                if better(c, best) {
                    best = Some(c);
                }
            }
            if let Some(&(t_resume, _, _)) = self.resume_q.front() {
                let c = (t_resume, 1u8, 0usize);
                if better(c, best) {
                    best = Some(c);
                }
            }
            if self.next_arrival < self.trace.len() {
                let c = (self.trace.arrivals[self.next_arrival].t_s, 2u8, 0usize);
                if better(c, best) {
                    best = Some(c);
                }
            }
            if let Some((t, idx)) = self.next_server_event() {
                let c = (t, 3u8, idx);
                if better(c, best) {
                    best = Some(c);
                }
            }
            let Some((t, class, idx)) = best else {
                // Only parked unroutable requests remain and no
                // recovery can ever free them.
                self.drain_unroutable();
                break;
            };
            match class {
                0 => self.handle_fault(),
                1 => self.handle_resume(),
                2 => self.handle_arrival(),
                _ => {
                    // A shared freeze instant: every *frozen* server
                    // whose batch also starts exactly at `t` would be
                    // processed back-to-back (ascending id) with no
                    // intervening event — fault/arrival events at `t`
                    // would have won the tie-break above — and their
                    // solves read only their own frozen queues. Fan
                    // them out together. The scan stops at the first
                    // non-frozen epoch (a timer freeze, which an
                    // earlier solve's steal hand-off may still grow).
                    let batch = self.coincident_ready_solves(t, idx);
                    if batch.len() >= 2 {
                        self.solve_batch(t, batch);
                    } else {
                        self.handle_server_event(idx);
                    }
                }
            }
        }
        debug_assert!(self.unroutable.is_empty());
        debug_assert!(self.resume_q.is_empty());
        debug_assert!(self.servers.iter().all(|s| s.backlog.is_empty()));
    }

    /// A checkpointed partial finished its latent transfer: hand it
    /// back through the router with its salvaged steps — unless its
    /// absolute deadline already passed in transit, in which case it
    /// expired at the deadline, not at the transfer's end.
    fn handle_resume(&mut self) {
        let (t, from, p) = self.resume_q.pop_front().expect("resume event to fire");
        if p.abs_deadline_s <= t {
            self.resolve_lost(p, p.abs_deadline_s, None);
        } else {
            self.reroute(p, t, MigrationReason::Checkpoint, Some(from));
        }
    }

    fn handle_fault(&mut self) {
        let ev = self.fault_events[self.next_fault];
        self.next_fault += 1;
        match ev.kind {
            FaultKind::Down => self.kill_server(ev.server, ev.t_s),
            FaultKind::Up => self.revive_server(ev.server, ev.t_s),
        }
    }

    fn kill_server(&mut self, s: usize, t: f64) {
        if !self.servers[s].alive {
            return;
        }
        self.states[s].alive = false;
        self.index.remove(s);
        self.mark_dirty(s);
        self.servers[s].alive = false;
        self.servers[s].down_since = Some(t);
        self.fault_log.push(FaultEvent { t_s: t, server: s, kind: FaultKind::Down });
        // Orphan the queued-but-unsolved work: the current epoch
        // (building or frozen-awaiting-solve) and the backlog, in
        // queue order.
        let mut orphans: Vec<Pending> = Vec::new();
        if let Some(e) = self.servers[s].epoch.take() {
            orphans.extend(e.queue);
        }
        orphans.extend(self.servers[s].backlog.drain(..));
        let requeue = self.policy.requeue_on_death();
        for p in orphans {
            if requeue {
                self.reroute(p, t, MigrationReason::DeadServer, Some(s));
            } else {
                self.resolve_lost(p, t, Some(s));
            }
        }
        // Cut the committed batch at the wall clock: the GPU stopped at
        // `t`, so members not delivered by then were never actually
        // served — retract their optimistic outcomes. Checkpointing
        // salvages each victim at its last completed step boundary and
        // ships the latent; every other policy loses it outright (there
        // is no checkpoint to move, and the un-checkpointed latent died
        // with the GPU).
        let Some(fl) = self.servers[s].in_flight.take() else { return };
        if t >= fl.gen_end_s {
            // Generation finished before the death; only transmission
            // tails remain and those belong to the edge link, not the
            // dead GPU.
            return;
        }
        let checkpoint = self.policy.checkpoint_in_flight();
        let mut retracted = false;
        for r in fl.requests {
            if r.completion_s <= t {
                continue; // delivered before the death — stands
            }
            debug_assert!(self.outcomes[r.pending.id].is_some());
            self.outcomes[r.pending.id] = None;
            // O(1) retraction: tombstone the optimistic resolution in
            // place (positions are stable, emission order preserved)
            // instead of rescanning everything this server resolved.
            let sv = &mut self.servers[s];
            let pos = sv
                .resolved_pos
                .remove(&r.pending.id)
                .expect("in-flight member was resolved while faults remained");
            debug_assert_eq!(sv.resolved_ids[pos], r.pending.id);
            sv.resolved_ids[pos] = RETRACTED;
            retracted = true;
            if checkpoint {
                let done = fl.schedule.steps_completed_by(r.service_slot, t - fl.start_s);
                let p = Pending { done_steps: r.pending.done_steps + done, ..r.pending };
                let kind = EventKind::RetractedByDeath { done_steps: p.done_steps as usize };
                self.tracer.emit(t, s, p.id, kind);
                self.tracer.emit(t, s, p.id, EventKind::TransferStart);
                self.resume_q.push_back((t + self.transfer_s, s, p));
            } else {
                let kind = EventKind::RetractedByDeath { done_steps: 0 };
                self.tracer.emit(t, s, r.pending.id, kind);
                self.resolve_lost(r.pending, t, Some(s));
            }
        }
        if retracted {
            // The dead GPU frees at the cut, and the retracted
            // completions may have been the horizon's high-water mark.
            // Re-mark dirty: the orphan reroutes above may already have
            // drained this server's flag with the pre-cut `gpu_free`.
            self.servers[s].gpu_free_s = t;
            self.mark_dirty(s);
            self.recompute_horizon(t);
        }
    }

    /// Re-derive the simulated span from what still stands — resolved
    /// outcomes and every server's GPU busy-until — after a retraction
    /// invalidated the running maximum.
    fn recompute_horizon(&mut self, floor: f64) {
        let mut h = floor;
        for o in self.outcomes.iter().flatten() {
            h = h.max(o.resolved_s);
        }
        for s in &self.servers {
            h = h.max(s.gpu_free_s);
        }
        self.horizon = h;
    }

    fn revive_server(&mut self, s: usize, t: f64) {
        if self.servers[s].alive {
            return;
        }
        self.states[s].alive = true;
        self.index.touch(&self.states[s]);
        self.mark_dirty(s);
        self.servers[s].alive = true;
        if let Some(since) = self.servers[s].down_since.take() {
            self.servers[s].downtime_s += t - since;
        }
        self.fault_log.push(FaultEvent { t_s: t, server: s, kind: FaultKind::Up });
        // Capacity returned: everything parked unroutable re-enters
        // the router with whatever deadline budget it has left. A
        // request whose deadline already passed during the outage
        // expired at that deadline, not at the (possibly much later)
        // recovery instant.
        let parked: Vec<Pending> = self.unroutable.drain(..).collect();
        for p in parked {
            if p.abs_deadline_s <= t {
                self.resolve_lost(p, p.abs_deadline_s, None);
            } else {
                self.reroute(p, t, MigrationReason::Recovery, None);
            }
        }
    }

    /// Flag a server's engine state (queue depth, `gpu_free`,
    /// liveness) as changed since the last dispatch, so the next
    /// [`Engine::refresh_states`] republishes its [`LiveView`].
    /// Over-marking is safe (republication is idempotent on unchanged
    /// state); *under*-marking would hand the router a stale view.
    fn mark_dirty(&mut self, s: usize) {
        if !self.live_dirty[s] {
            self.live_dirty[s] = true;
            self.dirty.push(s);
        }
    }

    /// Bring the router's fleet view current — incrementally: only
    /// servers whose engine state changed since the last dispatch
    /// (the dirty set) get their true queue depth and `gpu_free`
    /// republished, to both the [`ServerState::live`] view and the
    /// index's live half. Virtual-view policies ignore the live half,
    /// so publishing it never perturbs them. The per-dispatch
    /// advance-every-server loop is gone: decisions read
    /// [`ServerState::queue_len_at`] / `outstanding_work_s`, which
    /// never need it, and the virtual queue is GC'd lazily on the
    /// chosen server at charge time.
    fn refresh_states(&mut self) {
        for s in self.dirty.drain(..) {
            self.live_dirty[s] = false;
            let srv = &self.servers[s];
            let st = &mut self.states[s];
            st.live = Some(LiveView { queue_depth: srv.queued(), gpu_free_s: srv.gpu_free_s });
            let cost = live_queue_cost_s(self.delay, srv.queued(), st.speed);
            self.index.publish_live(s, st.alive, srv.gpu_free_s, cost);
        }
    }

    fn handle_arrival(&mut self) {
        let a = self.trace.arrivals[self.next_arrival];
        self.next_arrival += 1;
        self.refresh_states();
        if !self.states.iter().any(|st| st.alive) {
            // The whole fleet is down: park until a recovery. The
            // arrival is anchored on server 0's timeline — it never
            // reached any server.
            self.tracer.emit(a.t_s, 0, a.id, EventKind::Arrived);
            self.unroutable.push_back(Pending::from_arrival(&a));
            return;
        }
        let choice = if self.scan_routing {
            self.router.route(&a, &self.states, &self.ctx)
        } else {
            self.router.route_indexed(&a, &self.states, &self.ctx, &mut self.index)
        };
        let name = self.router.name();
        assert!(self.states[choice].alive, "router {name} picked failed server {choice}");
        let service_est_s = self.delay.g(1) / self.states[choice].speed;
        self.states[choice].advance(a.t_s);
        self.states[choice].assign(a.t_s, service_est_s);
        self.index.touch(&self.states[choice]);
        self.mark_dirty(choice);
        self.assignment[a.id] = choice;
        self.tracer.emit(a.t_s, choice, a.id, EventKind::Arrived);
        self.tracer.emit(a.t_s, choice, a.id, EventKind::Routed { server: choice, score: 0.0 });
        self.servers[choice].assigned_ids.push(a.id);
        let mut p = Pending::from_arrival(&a);
        if let Some(caches) = self.caches.as_mut() {
            if !a.mark.is_zero() {
                if let Some(steps) = caches[choice].lookup(a.mark) {
                    self.serve_from_cache(&a, choice, steps);
                    return;
                }
                // Miss on a non-resident model: the load/swap stalls
                // the request, tightening its residual budget (elapsed
                // time is never refunded). Mirrors `sim::dynamic`.
                p.deadline_s -= caches[choice].ensure_resident(a.mark.model);
                p.abs_deadline_s = a.t_s + p.deadline_s;
            }
        }
        let epoch_policy = self.dynamic.epoch;
        self.servers[choice].ingest(p, a.t_s, &epoch_policy);
        self.touch(choice);
    }

    /// A generation-cache hit: the request bypasses the epoch batch
    /// entirely and pays only the paper's transmission phase over the
    /// full band, charged at the cached entry's step-count quality. It
    /// never joins an epoch, so it neither counts toward the
    /// batch-close rule nor consumes GPU time; `Delivered` is emitted
    /// with every other delivery in [`Engine::emit_deliveries`].
    fn serve_from_cache(&mut self, a: &Arrival, choice: usize, steps: u32) {
        let e2e = a.link.tx_delay(self.ctx.content_bits, self.ctx.total_bandwidth_hz);
        let completion = a.t_s + e2e;
        let met = e2e <= a.deadline_s;
        let quality = self.quality.quality(steps);
        self.tracer.emit(a.t_s, choice, a.id, EventKind::CacheHit { steps: steps as usize });
        let w = &mut self.servers[choice].windows;
        w.record_arrival(a.t_s);
        w.record_served(a.t_s, e2e, quality, met);
        let outcome = RequestOutcome {
            id: a.id,
            arrival_s: a.t_s,
            deadline_s: a.deadline_s,
            disposition: Disposition::ServedFromCache,
            steps,
            quality,
            e2e_s: e2e,
            wait_s: 0.0,
            deferrals: 0,
            epoch: self.servers[choice].epochs.len(),
            met,
            resolved_s: completion,
            recovered_steps: 0,
        };
        self.resolve(a.id, outcome, choice);
        self.horizon = self.horizon.max(completion);
    }

    /// Hand a request back through the router at instant `t`, with its
    /// elapsed deadline budget preserved.
    fn reroute(&mut self, p: Pending, t: f64, reason: MigrationReason, from: Option<usize>) {
        self.refresh_states();
        if !self.states.iter().any(|st| st.alive) {
            self.migrations.push(MigrationRecord { id: p.id, from, to: None, t_s: t, reason });
            self.unroutable.push_back(p);
            return;
        }
        // The router sees the *residual* budget — migration never
        // refunds elapsed time — and, for a checkpointed partial, the
        // steps already in hand (`route_resume` is the identity on
        // `done_steps == 0`, so the legacy paths are untouched).
        let view = Arrival {
            id: p.id,
            t_s: t,
            deadline_s: p.abs_deadline_s - t,
            link: p.link,
            mark: p.mark,
        };
        let choice = if self.scan_routing {
            self.router.route_resume(&view, p.done_steps, &self.states, &self.ctx)
        } else {
            self.router.route_resume_indexed(
                &view,
                p.done_steps,
                &self.states,
                &self.ctx,
                &mut self.index,
            )
        };
        let name = self.router.name();
        assert!(self.states[choice].alive, "router {name} picked failed server {choice}");
        let service_est_s = self.delay.g(1) / self.states[choice].speed;
        self.states[choice].advance(t);
        self.states[choice].assign(t, service_est_s);
        self.index.touch(&self.states[choice]);
        self.mark_dirty(choice);
        self.migrations.push(MigrationRecord { id: p.id, from, to: Some(choice), t_s: t, reason });
        self.tracer.emit(t, choice, p.id, EventKind::Routed { server: choice, score: 0.0 });
        if reason == MigrationReason::Checkpoint {
            self.tracer.emit(t, choice, p.id, EventKind::Resumed { server: choice });
        }
        if self.assignment[p.id] == UNROUTED {
            self.assignment[p.id] = choice;
            self.servers[choice].assigned_ids.push(p.id);
        }
        let epoch_policy = self.dynamic.epoch;
        let landed = Pending { enqueued_s: t, recorded: false, ..p };
        self.servers[choice].ingest(landed, t, &epoch_policy);
        self.touch(choice);
    }

    /// Hand a solve's carry-over to the router under steal-when-idle.
    /// Unlike a death hand-off, the source is still alive, so the
    /// router may keep the request home — that is a local carry-over,
    /// not a migration (no record, no fresh virtual-queue charge).
    fn steal_hand_off(&mut self, p: Pending, t: f64, from: usize) {
        self.refresh_states();
        let reason = MigrationReason::StealWhenIdle;
        if !self.states.iter().any(|st| st.alive) {
            let record = MigrationRecord { id: p.id, from: Some(from), to: None, t_s: t, reason };
            self.migrations.push(record);
            self.unroutable.push_back(p);
            return;
        }
        let view = Arrival {
            id: p.id,
            t_s: t,
            deadline_s: p.abs_deadline_s - t,
            link: p.link,
            mark: p.mark,
        };
        let choice = if self.scan_routing {
            self.router.route_resume(&view, p.done_steps, &self.states, &self.ctx)
        } else {
            self.router.route_resume_indexed(
                &view,
                p.done_steps,
                &self.states,
                &self.ctx,
                &mut self.index,
            )
        };
        let name = self.router.name();
        assert!(self.states[choice].alive, "router {name} picked failed server {choice}");
        let epoch_policy = self.dynamic.epoch;
        if choice == from {
            self.servers[from].ingest(Pending { enqueued_s: t, ..p }, t, &epoch_policy);
            self.mark_dirty(from);
            self.touch(from);
            return;
        }
        let service_est_s = self.delay.g(1) / self.states[choice].speed;
        self.states[choice].advance(t);
        self.states[choice].assign(t, service_est_s);
        self.index.touch(&self.states[choice]);
        self.mark_dirty(choice);
        let record = MigrationRecord {
            id: p.id,
            from: Some(from),
            to: Some(choice),
            t_s: t,
            reason,
        };
        self.migrations.push(record);
        self.tracer.emit(t, choice, p.id, EventKind::Routed { server: choice, score: 0.0 });
        let landed = Pending { enqueued_s: t, recorded: false, ..p };
        self.servers[choice].ingest(landed, t, &epoch_policy);
        self.touch(choice);
    }

    fn handle_server_event(&mut self, idx: usize) {
        let ready = match self.servers[idx].epoch.as_mut() {
            Some(e) if !e.frozen() => {
                // The epoch timer fired with no batch-fill: freeze
                // membership at the scheduled close. The solve instant
                // and batch start are fixed from here (`SolveTiming`).
                let close = e.close_s;
                e.freeze(close);
                false
            }
            Some(_) => true,
            None => unreachable!("server event with no epoch"),
        };
        if ready {
            self.solve_server(idx, None);
        } else {
            // The freeze moved this server's next event from the epoch
            // timer to its batch start — re-index.
            self.touch(idx);
        }
    }

    /// Servers (ascending id from `idx`) with a *frozen* epoch whose
    /// batch starts exactly at `t` — the fan-out set for one shared
    /// freeze instant. Scanning stops at the first same-instant server
    /// still `Building` (its timer freeze must run in event order:
    /// an earlier solve's steal hand-off can still join that epoch).
    /// Returns a single-element batch when fan-out is off, the batch
    /// would be trivial, or the involved allocators cannot safely solve
    /// concurrently (one shared stateful instance).
    fn coincident_ready_solves(&self, t: f64, idx: usize) -> Vec<usize> {
        if self.dynamic.threads == 1 {
            return vec![idx];
        }
        let mut batch = Vec::new();
        for s in &self.servers[idx..] {
            if s.next_event_time() != Some(t) {
                continue;
            }
            match &s.epoch {
                Some(e) if e.frozen() => batch.push(s.id),
                _ => break,
            }
        }
        if batch.is_empty() {
            // The head event at `t` is a timer freeze, not a solve.
            return vec![idx];
        }
        debug_assert_eq!(batch[0], idx);
        let allocs: Vec<&dyn Allocator> = batch.iter().map(|&i| self.allocators[i]).collect();
        let safe = allocs.iter().all(|a| a.parallel_replay_safe())
            || crate::bandwidth::distinct_instances(&allocs);
        if !safe {
            return vec![idx];
        }
        batch
    }

    /// Solve a shared-freeze-instant batch: gather every server's (P0)
    /// input read-only, run the expensive `solve_joint`s concurrently,
    /// then apply the results in ascending server id — the exact order
    /// the serial event loop would have used. Applying server i's
    /// result cannot change server j's frozen solve input (steal
    /// hand-offs land in j's backlog, not its frozen queue), so this is
    /// bit-identical to the serial path.
    fn solve_batch(&mut self, t: f64, batch: Vec<usize>) {
        let scheduler = self.scheduler;
        let quality = self.quality;
        let jobs: Vec<(BatchDelayModel, &dyn Allocator, Option<Workload>)> = batch
            .iter()
            .map(|&i| (self.servers[i].delay, self.allocators[i], self.solve_input(i)))
            .collect();
        let sols = par_map(self.dynamic.threads, &jobs, |_, (scaled, allocator, input)| {
            input.as_ref().map(|w| solve_joint(w, scheduler, *allocator, scaled, quality))
        });
        for (&idx, sol) in batch.iter().zip(sols) {
            // An already-applied member can have opened AND re-frozen a
            // degenerate next epoch whose event lands at or before
            // `(t, idx)` (empty admissions leave `gpu_free` behind the
            // clock). The serial loop would process those events here;
            // they cannot touch the remaining members' frozen solve
            // inputs (cross-server effects only push into backlogs), so
            // the gathered solutions stay valid — but the events must
            // run in their serial position.
            self.drain_server_events_before(t, idx);
            self.solve_server(idx, sol);
        }
    }

    /// Process (serially) every pending server event strictly ordered
    /// before `(t, idx)` — see `solve_batch`. Fault/arrival events need
    /// no draining: everything at or before `t` was consumed before the
    /// batch was selected.
    fn drain_server_events_before(&mut self, t: f64, idx: usize) {
        loop {
            let mut first: Option<(f64, usize)> = None;
            for s in &self.servers {
                if let Some(te) = s.next_event_time() {
                    let cand = (te, s.id);
                    if cand < (t, idx) && first.map_or(true, |b| cand < b) {
                        first = Some(cand);
                    }
                }
            }
            let Some((_, sid)) = first else { break };
            self.handle_server_event(sid);
        }
    }

    /// Whether a queued request survives admission for a batch starting
    /// at `t0` — the single admission rule `solve_input` and
    /// `solve_server` share, so a pre-gathered workload always matches
    /// the partition the apply step replays.
    fn admit(&self, q: &Pending, t0: f64, scaled: &BatchDelayModel) -> bool {
        let residual = q.abs_deadline_s - t0;
        let min_tx = if self.dynamic.admission {
            q.link.tx_delay(self.trace.content_bits, self.trace.total_bandwidth_hz)
        } else {
            0.0
        };
        residual >= scaled.g(1) + min_tx
    }

    /// Read-only gather of one frozen epoch's (P0) problem: the
    /// admitted requests' residual deadlines at the batch start,
    /// horizon-clamped — exactly the workload `solve_server` would
    /// build. `None` when admission drops the whole queue.
    fn solve_input(&self, idx: usize) -> Option<Workload> {
        let s = &self.servers[idx];
        let e = s.epoch.as_ref().expect("frozen epoch to gather");
        debug_assert!(e.frozen());
        let t0 = s.solve_timing(e).batch_start_s;
        let scaled = s.delay;
        let plan_horizon = self.dynamic.effective_plan_horizon(e.queue.len());
        let mut devices: Vec<DeviceRequest> = Vec::new();
        for q in &e.queue {
            if self.admit(q, t0, &scaled) {
                devices.push(DeviceRequest {
                    id: devices.len(),
                    deadline: (q.abs_deadline_s - t0).min(plan_horizon),
                    link: q.link,
                });
            }
        }
        if devices.is_empty() {
            return None;
        }
        Some(Workload {
            devices,
            total_bandwidth_hz: self.trace.total_bandwidth_hz,
            content_bits: self.trace.content_bits,
        })
    }

    /// One frozen epoch's (P0) solve — simulate_dynamic's loop body,
    /// op-for-op, against this server's speed-scaled delay model. The
    /// engine reaches this event at the epoch's *batch start*; the
    /// solve itself ran during `[solve_begin, solve_end]` (overlapped
    /// with the previous batch under the pipelined mode), so the plan
    /// is evaluated against residual deadlines at the batch start —
    /// the instant it targets. `presolved` carries the `solve_joint`
    /// result when `solve_batch` already computed it concurrently (its
    /// input came from `solve_input`, which gathers the identical
    /// workload).
    fn solve_server(&mut self, idx: usize, presolved: Option<JointSolution>) {
        let cfg = self.dynamic;
        let mut e = self.servers[idx].epoch.take().expect("frozen epoch to solve");
        // Queue depth and (later) `gpu_free` change across the solve;
        // no dispatch can interleave before both are final, so one
        // mark up front covers the whole event.
        self.mark_dirty(idx);
        let timing = self.servers[idx].solve_timing(&e);
        // Walk the remaining lifecycle explicitly: the solve finished
        // (PlanPending → Solved) and the batch is now starting
        // (Solved → Executing); it retires Closed once committed.
        e.phase = e.phase.advance();
        debug_assert_eq!(e.phase, EpochPhase::Solved);
        e.phase = e.phase.advance();
        debug_assert_eq!(e.phase, EpochPhase::Executing);
        let t0 = timing.batch_start_s;
        let epoch_index = self.servers[idx].epochs.len();
        let queue_depth = e.queue.len();
        let scaled = self.servers[idx].delay;
        self.mark(e.close_s, idx, EventKind::EpochFrozen { epoch: epoch_index });
        self.mark(timing.solve_begin_s, idx, EventKind::SolveStart { epoch: epoch_index });
        self.mark(timing.solve_end_s, idx, EventKind::SolveDone { epoch: epoch_index });

        // ---- admission control ----
        let mut admitted: Vec<Pending> = Vec::new();
        let mut dropped_now = 0usize;
        for q in e.queue {
            if !self.admit(&q, t0, &scaled) {
                let disposition = if q.deferrals == 0 {
                    Disposition::RejectedOnArrival
                } else {
                    Disposition::ExpiredInQueue
                };
                let kind = if q.deferrals == 0 { EventKind::Rejected } else { EventKind::Expired };
                self.tracer.emit(t0, idx, q.id, kind);
                self.servers[idx].windows.record_dropped(t0, self.outage_q);
                let outcome = RequestOutcome {
                    id: q.id,
                    arrival_s: q.arrival_s,
                    deadline_s: q.deadline_s,
                    disposition,
                    steps: 0,
                    quality: self.outage_q,
                    e2e_s: 0.0,
                    wait_s: t0 - q.arrival_s,
                    deferrals: q.deferrals,
                    epoch: epoch_index,
                    met: false,
                    resolved_s: t0,
                    recovered_steps: 0,
                };
                self.resolve(q.id, outcome, idx);
                self.horizon = self.horizon.max(t0);
                dropped_now += 1;
            } else {
                self.tracer.emit(t0, idx, q.id, EventKind::Admitted { epoch: epoch_index });
                admitted.push(q);
            }
        }

        if admitted.is_empty() {
            self.mark(t0, idx, EventKind::EpochDone { epoch: epoch_index });
            let w = &mut self.servers[idx].windows;
            w.record_solve(t0, cfg.solve_latency_s, timing.hidden_s);
            w.prune(t0);
            let rec = self.epoch_rec(
                idx,
                epoch_index,
                t0,
                queue_depth,
                0,
                0,
                0,
                dropped_now,
                0.0,
                timing.hidden_s,
            );
            self.servers[idx].epochs.push(rec);
            self.open_after_solve(idx, t0, Vec::new());
            self.touch(idx);
            return;
        }

        // ---- one (P0) solve over residual deadlines ----
        let sol = match presolved {
            Some(sol) => sol,
            None => {
                let plan_horizon = cfg.effective_plan_horizon(queue_depth);
                let devices: Vec<DeviceRequest> = admitted
                    .iter()
                    .enumerate()
                    .map(|(i, q)| DeviceRequest {
                        id: i,
                        deadline: (q.abs_deadline_s - t0).min(plan_horizon),
                        link: q.link,
                    })
                    .collect();
                let workload = Workload {
                    devices,
                    total_bandwidth_hz: self.trace.total_bandwidth_hz,
                    content_bits: self.trace.content_bits,
                };
                solve_joint(&workload, self.scheduler, self.allocators[idx], &scaled, self.quality)
            }
        };
        let makespan = sol.outcome.schedule.makespan();
        emit_batches(self.tracer, idx, t0, &sol.outcome.schedule);

        // Track the committed batch only while fault events remain: a
        // later death may cut it, and zero-fault runs must not pay (or
        // perturb) anything for the bookkeeping.
        let mut in_flight = (self.next_fault < self.fault_events.len()).then(|| InFlight {
            start_s: t0,
            gen_end_s: t0 + makespan,
            schedule: sol.outcome.schedule.clone(),
            requests: Vec::new(),
        });

        // ---- resolve served requests; collect carry-overs ----
        let mut served_now = 0usize;
        let mut deferred: Vec<Pending> = Vec::new();
        for (i, q) in admitted.into_iter().enumerate() {
            let svc = sol.outcome.services[i];
            if svc.steps > 0 {
                let completion = t0 + svc.e2e_delay;
                let e2e = completion - q.arrival_s;
                let met = svc.met;
                // A checkpointed partial delivers its salvaged steps on
                // top of this solve's plan: the latent arrived
                // `done_steps` deep, so the content ships at the
                // combined step count's quality.
                let (disposition, steps, quality) = if q.done_steps > 0 {
                    let total = svc.steps + q.done_steps;
                    (Disposition::ResumedElsewhere, total, self.quality.quality(total))
                } else {
                    (Disposition::Served, svc.steps, svc.quality)
                };
                self.servers[idx].windows.record_served(t0, e2e, quality, met);
                if let Some(fl) = in_flight.as_mut() {
                    fl.requests.push(InFlightReq {
                        pending: q,
                        completion_s: completion,
                        service_slot: i,
                    });
                }
                let outcome = RequestOutcome {
                    id: q.id,
                    arrival_s: q.arrival_s,
                    deadline_s: q.deadline_s,
                    disposition,
                    steps,
                    quality,
                    e2e_s: e2e,
                    wait_s: t0 - q.arrival_s,
                    deferrals: q.deferrals,
                    epoch: epoch_index,
                    met,
                    resolved_s: completion,
                    recovered_steps: q.done_steps,
                };
                self.resolve(q.id, outcome, idx);
                // A fresh full generation populates this server's
                // cache (resumes ship a partial latent — not reusable
                // content — so they never seed an entry).
                if q.done_steps == 0 && !q.mark.is_zero() {
                    if let Some(caches) = self.caches.as_mut() {
                        caches[idx].insert(q.mark, svc.steps);
                    }
                }
                self.horizon = self.horizon.max(completion);
                served_now += 1;
            } else {
                deferred.push(Pending { deferrals: q.deferrals + 1, ..q });
            }
        }
        self.servers[idx].in_flight = in_flight;

        self.servers[idx].gpu_free_s = t0 + makespan;
        self.mark(t0 + makespan, idx, EventKind::EpochDone { epoch: epoch_index });
        self.horizon = self.horizon.max(self.servers[idx].gpu_free_s);
        let w = &mut self.servers[idx].windows;
        w.record_solve(t0, cfg.solve_latency_s, timing.hidden_s);
        w.prune(t0);
        let admitted_n = served_now + deferred.len();
        let rec = self.epoch_rec(
            idx,
            epoch_index,
            t0,
            queue_depth,
            admitted_n,
            served_now,
            deferred.len(),
            dropped_now,
            makespan,
            timing.hidden_s,
        );
        self.servers[idx].epochs.push(rec);

        // ---- carry-over placement: local, or stolen to idle capacity ----
        if !deferred.is_empty()
            && self.policy.steal_when_idle()
            && self.servers.iter().any(|s| s.id != idx && s.alive && s.is_idle(t0))
        {
            self.open_after_solve(idx, t0, Vec::new());
            for p in deferred {
                self.steal_hand_off(p, t0, idx);
            }
        } else {
            self.open_after_solve(idx, t0, deferred);
        }
        self.touch(idx);
    }

    /// Open the server's next epoch after a solve at `t0`, replaying
    /// simulate_dynamic's epoch-opening rules over the carry-overs and
    /// the backlog of requests routed here while the epoch was frozen.
    fn open_after_solve(&mut self, idx: usize, t0: f64, deferred: Vec<Pending>) {
        let policy = self.dynamic.epoch;
        let s = &mut self.servers[idx];
        debug_assert!(s.epoch.is_none());
        if !deferred.is_empty() {
            // Carry-overs have been waiting since the solve: the next
            // epoch opens immediately (simulate_dynamic: open = clock)
            // and already-routed requests join it unconditionally,
            // like backlogged trace arrivals with t ≤ open.
            let mut e = Epoch {
                open_s: t0,
                close_s: policy.close_deadline(t0),
                phase: EpochPhase::Building,
                queue: deferred,
            };
            while let Some(mut p) = s.backlog.pop_front() {
                debug_assert!(p.enqueued_s <= t0);
                ServerSim::note_arrival(&mut s.windows, &mut p);
                e.queue.push(p);
            }
            s.epoch = Some(e);
            return;
        }
        let Some(first) = s.backlog.front().copied() else { return };
        // No carry-overs: the epoch opens with the earliest waiting
        // request — simulate_dynamic's "open = next arrival" rule.
        let open = first.enqueued_s;
        let mut e = Epoch {
            open_s: open,
            close_s: policy.close_deadline(open),
            phase: EpochPhase::Building,
            queue: Vec::new(),
        };
        while let Some(p) = s.backlog.front().copied() {
            if p.enqueued_s > open {
                break;
            }
            let mut p = s.backlog.pop_front().unwrap();
            ServerSim::note_arrival(&mut s.windows, &mut p);
            e.queue.push(p);
        }
        // Later waiters replay the timed ingest loop: join up to the
        // close, with the batch rule possibly freezing the epoch early
        // (any leftovers then seed the epoch after next).
        while !e.frozen() {
            let Some(p) = s.backlog.front().copied() else { break };
            if p.enqueued_s > e.close_s {
                let close = e.close_s;
                e.freeze(close);
                break;
            }
            let mut p = s.backlog.pop_front().unwrap();
            ServerSim::note_arrival(&mut s.windows, &mut p);
            e.queue.push(p);
            if policy.should_close(e.queue.len(), p.enqueued_s - open) {
                e.freeze(p.enqueued_s);
            }
        }
        s.epoch = Some(e);
    }

    #[allow(clippy::too_many_arguments)]
    fn epoch_rec(
        &self,
        idx: usize,
        index: usize,
        t0: f64,
        queue_depth: usize,
        admitted: usize,
        served: usize,
        deferred: usize,
        dropped: usize,
        makespan_s: f64,
        solve_hidden_s: f64,
    ) -> EpochRecord {
        let w = &self.servers[idx].windows;
        let [p50_e2e_w, p95_e2e_w, p99_e2e_w] = w.e2e_s.percentiles([50.0, 95.0, 99.0]);
        EpochRecord {
            index,
            t_solve_s: t0,
            queue_depth,
            admitted,
            served,
            deferred,
            dropped,
            makespan_s,
            solve_hidden_s,
            arrival_rate_hz: w.arrivals.rate_hz(),
            mean_quality_w: w.quality.mean(),
            outage_rate_w: w.outage_rate(),
            p50_e2e_w,
            p95_e2e_w,
            p99_e2e_w,
            solve_overlap_w: w.solve_overlap_fraction(),
        }
    }

    fn resolve(&mut self, id: usize, outcome: RequestOutcome, server: usize) {
        debug_assert!(self.outcomes[id].is_none(), "request {id} resolved twice");
        self.outcomes[id] = Some(outcome);
        let sv = &mut self.servers[server];
        if self.next_fault < self.fault_events.len() {
            // A later death may retract this resolution — remember its
            // position so the retraction is O(1). Zero-fault runs (and
            // the tail past the last fault) skip the bookkeeping
            // entirely, like the in-flight tracking.
            sv.resolved_pos.insert(id, sv.resolved_ids.len());
        }
        sv.resolved_ids.push(id);
    }

    /// Drop a request its dead server stranded (no migration, or no
    /// alive target anywhere).
    fn resolve_lost(&mut self, p: Pending, t: f64, server: Option<usize>) {
        if let Some(s) = server {
            self.servers[s].windows.record_dropped(t, self.outage_q);
        }
        let epoch = server.map(|s| self.servers[s].epochs.len()).unwrap_or(0);
        let outcome = RequestOutcome {
            id: p.id,
            arrival_s: p.arrival_s,
            deadline_s: p.deadline_s,
            disposition: Disposition::LostToFailure,
            steps: 0,
            quality: self.outage_q,
            e2e_s: 0.0,
            wait_s: t - p.arrival_s,
            deferrals: p.deferrals,
            epoch,
            met: false,
            resolved_s: t,
            recovered_steps: 0,
        };
        debug_assert!(self.outcomes[p.id].is_none(), "request {} resolved twice", p.id);
        self.outcomes[p.id] = Some(outcome);
        // `t` can be a backdated absolute deadline (a parked request
        // expires at its deadline, discovered only at the next recovery
        // or at drain) — the one place the recorder mirrors a
        // resolution instant that may precede already-emitted events.
        // `obs::audit` exempts `Lost` from the per-request monotonicity
        // rule for exactly this reason.
        self.tracer.emit(t, server.unwrap_or(0), p.id, EventKind::Lost);
        if let Some(s) = server {
            self.servers[s].resolved_ids.push(p.id);
        }
        self.horizon = self.horizon.max(t);
    }

    /// No server will ever come back for these: they expire at their
    /// absolute deadlines.
    fn drain_unroutable(&mut self) {
        let parked: Vec<Pending> = self.unroutable.drain(..).collect();
        for p in parked {
            self.resolve_lost(p, p.abs_deadline_s, None);
        }
    }

    /// Emit the `Delivered` events for every outcome still standing.
    /// Deliveries are deferred to the end of the run because a
    /// committed batch member's optimistic completion can be retracted
    /// by a later death — and a flight recorder never un-records. Once
    /// the event stream is drained, every served outcome is final.
    /// Iteration is servers-in-order × resolution-order: deterministic.
    fn emit_deliveries(&mut self) {
        if !self.tracer.enabled() {
            return;
        }
        for s in 0..self.servers.len() {
            for i in 0..self.servers[s].resolved_ids.len() {
                let id = self.servers[s].resolved_ids[i];
                if id == RETRACTED {
                    continue;
                }
                let o = self.outcomes[id].expect("resolved id has an outcome");
                if o.disposition.is_served() {
                    let kind = EventKind::Delivered { steps: o.steps as usize };
                    self.tracer.emit(o.resolved_s, s, id, kind);
                }
            }
        }
    }

    fn finish(mut self) -> EventReport {
        self.emit_deliveries();
        let horizon = self.horizon;
        let fault_events = self.fault_events;
        let caches = self.caches;
        let outcomes: Vec<RequestOutcome> = self
            .outcomes
            .into_iter()
            .map(|o| o.expect("every request routed and resolved"))
            .collect();
        let servers = self
            .servers
            .into_iter()
            .map(|s| {
                // A server still down at the end was down until the
                // simulated span ended — or until its scheduled
                // recovery, if the run finished before that event
                // ever fired.
                let tail = s
                    .down_since
                    .map(|since| {
                        let recovery = fault_events
                            .iter()
                            .filter(|e| e.server == s.id && e.kind == FaultKind::Up)
                            .map(|e| e.t_s)
                            .find(|&t| t >= since)
                            .unwrap_or(f64::INFINITY);
                        horizon.min(recovery).max(since) - since
                    })
                    .unwrap_or(0.0);
                // Tombstones never escape: retracted slots are cut
                // here, preserving the resolution order of the rest.
                let mut resolved_ids = s.resolved_ids;
                resolved_ids.retain(|&id| id != RETRACTED);
                EventServerReport {
                    server: s.id,
                    speed: s.speed,
                    assigned_ids: s.assigned_ids,
                    resolved_ids,
                    epochs: s.epochs,
                    downtime_s: s.downtime_s + tail,
                    cache_stats: caches.as_ref().map(|c| c[s.id].stats()).unwrap_or_default(),
                }
            })
            .collect();
        EventReport {
            outcomes,
            assignment: self.assignment,
            servers,
            migrations: self.migrations,
            fault_log: self.fault_log,
            horizon_s: horizon,
        }
    }
}

/// Run the fault-aware shared-clock cluster simulation of `trace` with
/// one shared allocator instance (the legacy entry point).
///
/// `delay` is the reference (speed-1.0) batch-delay model; each server
/// solves under `g(X)/speed`. With an empty [`FaultScript`] and
/// [`MigrationPolicyKind::None`] this reproduces
/// [`simulate_cluster`](super::simulate_cluster) bit-for-bit
/// (stateless allocators; per-server instances via
/// [`simulate_event_cluster_pooled`] extend the bit-identity to
/// warm-start PSO).
pub fn simulate_event_cluster(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &EventClusterConfig,
) -> EventReport {
    simulate_event_cluster_traced(trace, scheduler, allocator, delay, quality, cfg, &mut NullSink)
}

/// [`simulate_event_cluster`] with a flight recorder attached: the
/// full fault-aware lifecycle — routing, retraction, checkpoint
/// transfer, resume — streams into `tracer`. Like
/// [`simulate_dynamic_traced`](super::simulate_dynamic_traced), the
/// sink only observes; outputs are bit-identical for any sink.
pub fn simulate_event_cluster_traced(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &EventClusterConfig,
    tracer: &mut dyn TraceSink,
) -> EventReport {
    let allocators = vec![allocator; cfg.servers().max(1)];
    run_event_cluster(trace, scheduler, allocators, delay, quality, cfg, tracer, false)
}

/// [`simulate_event_cluster`] forced onto the O(N)-scan routing path:
/// every dispatch runs the routers' full-fleet reference scans instead
/// of the [`FleetIndex`] fast paths (the index is still maintained, so
/// engine state evolves identically). The decision-identity contract
/// makes the two entry points bitwise interchangeable —
/// `benches/fig_fleet.rs` and `tests/routing_index.rs` gate exactly
/// that.
pub fn simulate_event_cluster_scan(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &EventClusterConfig,
) -> EventReport {
    let allocators = vec![allocator; cfg.servers().max(1)];
    run_event_cluster(trace, scheduler, allocators, delay, quality, cfg, &mut NullSink, true)
}

/// [`simulate_event_cluster`] with per-server allocator instances from
/// an [`AllocatorPool`] — PSO warm-start state stays on its server.
pub fn simulate_event_cluster_pooled(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    pool: &AllocatorPool,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &EventClusterConfig,
) -> EventReport {
    let allocators = pool.refs(cfg.servers().max(1));
    run_event_cluster(trace, scheduler, allocators, delay, quality, cfg, &mut NullSink, false)
}

/// [`simulate_event_cluster_pooled`] with a flight recorder attached.
pub fn simulate_event_cluster_pooled_traced(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    pool: &AllocatorPool,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &EventClusterConfig,
    tracer: &mut dyn TraceSink,
) -> EventReport {
    let allocators = pool.refs(cfg.servers().max(1));
    run_event_cluster(trace, scheduler, allocators, delay, quality, cfg, tracer, false)
}

fn run_event_cluster(
    trace: &ArrivalTrace,
    scheduler: &dyn BatchScheduler,
    allocators: Vec<&dyn Allocator>,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    cfg: &EventClusterConfig,
    tracer: &mut dyn TraceSink,
    scan_routing: bool,
) -> EventReport {
    let n_servers = cfg.servers();
    let cache = cfg.dynamic.cache;
    assert!(n_servers >= 1, "cluster needs at least one server");
    assert_eq!(allocators.len(), n_servers, "one allocator reference per server");
    cfg.faults.validate_servers(n_servers).expect("fault script must fit the fleet");

    let states = ServerState::fleet(cfg.speeds);
    let index = FleetIndex::new(&states);
    let mut engine = Engine {
        trace,
        scheduler,
        allocators,
        delay,
        quality,
        dynamic: cfg.dynamic,
        policy: cfg.migration.build(),
        router: cfg.router.build_with_cache(*delay, cache),
        states,
        index,
        scan_routing,
        // Everything starts dirty: the first dispatch publishes the
        // whole fleet, exactly like the old publish-all loop did.
        live_dirty: vec![true; n_servers],
        dirty: (0..n_servers).collect(),
        ctx: RouteContext {
            total_bandwidth_hz: trace.total_bandwidth_hz,
            content_bits: trace.content_bits,
        },
        servers: cfg
            .speeds
            .iter()
            .enumerate()
            .map(|(i, &speed)| ServerSim::new(i, speed, delay, &cfg.dynamic))
            .collect(),
        fault_events: cfg.faults.events(),
        next_fault: 0,
        next_arrival: 0,
        unroutable: VecDeque::new(),
        resume_q: VecDeque::new(),
        transfer_s: cfg.resume_transfer_s,
        caches: cache.enabled.then(|| ServerCache::fleet(&cache, n_servers)),
        server_events: BinaryHeap::new(),
        outcomes: vec![None; trace.len()],
        assignment: vec![UNROUTED; trace.len()],
        migrations: Vec::new(),
        fault_log: Vec::new(),
        horizon: 0.0,
        outage_q: quality.outage(),
        tracer,
    };
    engine.run();
    engine.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::EqualAllocator;
    use crate::cache::CacheSettings;
    use crate::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
    use crate::faults::DownInterval;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::Stacking;
    use crate::sim::cluster::{server_speeds, simulate_cluster};

    fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 1,
            zipf_s: 1.0,
            models: 1,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    /// A trace whose arrivals carry Zipf prompt marks over a small,
    /// skewed universe — plenty of repeats for the cache to hit.
    fn marked_trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
        let cfg = ExperimentConfig::paper();
        let arrival = ArrivalSettings {
            process: ArrivalProcessKind::Poisson,
            rate_hz: rate,
            burst_rate_hz: rate,
            period_s: 60.0,
            duty: 0.5,
            horizon_s: horizon,
            max_requests: 0,
            prompt_universe: 12,
            zipf_s: 1.5,
            models: 2,
        };
        ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
    }

    fn enabled_cache() -> CacheSettings {
        CacheSettings { enabled: true, capacity: 32, ..CacheSettings::default() }
    }

    /// One unmarked arrival on the reference 7.0 dB link.
    fn one(id: usize, t_s: f64, deadline_s: f64) -> Arrival {
        Arrival { id, t_s, deadline_s, link: Link::new(7.0), mark: PromptMark::ZERO }
    }

    fn run(trace: &ArrivalTrace, cfg: &EventClusterConfig) -> EventReport {
        simulate_event_cluster(
            trace,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            cfg,
        )
    }

    fn run_scan(trace: &ArrivalTrace, cfg: &EventClusterConfig) -> EventReport {
        simulate_event_cluster_scan(
            trace,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            cfg,
        )
    }

    /// Owned fleet inputs behind the borrowing `EventClusterConfig`:
    /// tests build one of these (mutating `dynamic` freely) and hand
    /// `view()` to the engine.
    struct OwnedCfg {
        speeds: Vec<f64>,
        faults: FaultScript,
        dynamic: DynamicConfig,
        router: RouterKind,
        migration: MigrationPolicyKind,
        transfer_s: f64,
    }

    impl OwnedCfg {
        fn view(&self) -> EventClusterConfig<'_> {
            EventClusterConfig {
                speeds: &self.speeds,
                router: self.router,
                dynamic: self.dynamic,
                faults: &self.faults,
                migration: self.migration,
                resume_transfer_s: self.transfer_s,
            }
        }
    }

    fn cfg(speeds: Vec<f64>, faults: FaultScript, migration: MigrationPolicyKind) -> OwnedCfg {
        OwnedCfg {
            speeds,
            faults,
            dynamic: DynamicConfig::default(),
            router: RouterKind::JoinShortestQueue,
            migration,
            transfer_s: 0.0,
        }
    }

    fn down(server: usize, from: f64, until: f64) -> DownInterval {
        DownInterval::new(server, from, until).unwrap()
    }

    #[test]
    fn zero_fault_engine_matches_sequential_cluster_bitwise() {
        let t = trace(6.0, 50.0, 7);
        for router in RouterKind::all() {
            let cluster = ClusterConfig {
                speeds: server_speeds(3, 0.5, 1.5),
                router,
                dynamic: DynamicConfig::default(),
            };
            let seq = simulate_cluster(
                &t,
                &Stacking::default(),
                &EqualAllocator,
                &BatchDelayModel::paper(),
                &PowerLawQuality::paper(),
                &cluster,
            );
            let ev = run(&t, &EventClusterConfig::fault_free(&cluster));
            assert_eq!(ev.assignment, seq.assignment, "{}", router.name());
            assert_eq!(ev.horizon_s.to_bits(), seq.horizon_s.to_bits(), "{}", router.name());
            for (a, b) in ev.outcomes.iter().zip(&seq.outcomes) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.disposition, b.disposition, "request {}", a.id);
                assert_eq!(a.steps, b.steps, "request {}", a.id);
                assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "request {}", a.id);
                assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
                assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "request {}", a.id);
                assert_eq!(a.epoch, b.epoch, "request {}", a.id);
                assert_eq!(a.deferrals, b.deferrals, "request {}", a.id);
            }
            assert!(ev.migrations.is_empty() && ev.fault_log.is_empty());
        }
    }

    #[test]
    fn accumulator_fleet_stats_match_exact_and_bound_sketch() {
        let t = trace(5.0, 60.0, 3);
        let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
        let c = cfg(server_speeds(3, 0.5, 1.5), script, MigrationPolicyKind::RequeueOnDeath);
        let report = run(&t, &c.view());
        let exact = report.fleet_stats();
        // The exact accumulator pushes in id order — the same fold
        // `from_samples` runs — so the whole summary is bit-identical.
        assert_eq!(report.fleet_stats_with(MetricsMode::Exact, 0.01), exact);
        // Sketch-backed summary: scalar aggregates identical, e2e
        // percentiles within the sketch's rank bound.
        let eps = 0.02;
        let sk = report.fleet_stats_with(MetricsMode::Streaming, eps);
        assert_eq!(sk.count, exact.count);
        assert_eq!(sk.served, exact.served);
        assert_eq!(sk.mean_quality.to_bits(), exact.mean_quality.to_bits());
        assert_eq!(sk.mean_wait_s.to_bits(), exact.mean_wait_s.to_bits());
        let mut served: Vec<f64> = report
            .outcomes
            .iter()
            .filter(|o| o.disposition.is_served())
            .map(|o| o.e2e_s)
            .collect();
        served.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = served.len() as f64;
        let budget = (eps * n).ceil() as i64 + 1;
        for (p, g) in [(50.0, sk.p50_e2e_s), (95.0, sk.p95_e2e_s), (99.0, sk.p99_e2e_s)] {
            let target = (p / 100.0 * n).ceil().max(1.0) as i64;
            let rank = served.iter().filter(|&&v| v <= g).count() as i64;
            assert!((rank - target).abs() <= budget, "p{p}: rank {rank} target {target}");
        }
    }

    #[test]
    fn faulted_run_conserves_and_replays() {
        let t = trace(5.0, 60.0, 3);
        for policy in MigrationPolicyKind::all() {
            let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
            let c = cfg(server_speeds(3, 0.5, 1.5), script, policy);
            let a = run(&t, &c.view());
            assert_eq!(a.outcomes.len(), t.len(), "{}", policy.name());
            for (i, o) in a.outcomes.iter().enumerate() {
                assert_eq!(o.id, i, "{}", policy.name());
            }
            assert_eq!(a.served() + a.dropped(), t.len());
            // resolved exactly once across servers (+ unrouted drops)
            let mut counts = vec![0usize; t.len()];
            for s in &a.servers {
                for &id in &s.resolved_ids {
                    counts[id] += 1;
                }
            }
            assert!(counts.iter().all(|&c| c <= 1), "{}: double resolution", policy.name());
            // bit-identical replay
            let b = run(&t, &c.view());
            assert_eq!(a.migrations.len(), b.migrations.len());
            assert_eq!(a.assignment, b.assignment);
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.disposition, y.disposition);
                assert_eq!(x.quality.to_bits(), y.quality.to_bits());
                assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits());
            }
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
        }
    }

    /// The whole observable engine output, bit for bit — what the
    /// indexed-vs-scan gates compare.
    fn assert_reports_bitwise(a: &EventReport, b: &EventReport, tag: &str) {
        assert_eq!(a.assignment, b.assignment, "{tag}: assignment");
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits(), "{tag}: horizon");
        assert_eq!(a.fault_log.len(), b.fault_log.len(), "{tag}: fault log");
        assert_eq!(a.migrations.len(), b.migrations.len(), "{tag}: migrations");
        for (x, y) in a.migrations.iter().zip(&b.migrations) {
            assert_eq!((x.id, x.from, x.to, x.reason), (y.id, y.from, y.to, y.reason), "{tag}");
            assert_eq!(x.t_s.to_bits(), y.t_s.to_bits(), "{tag}: migration instant");
        }
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.id, y.id, "{tag}");
            assert_eq!(x.disposition, y.disposition, "{tag}: request {}", x.id);
            assert_eq!(x.steps, y.steps, "{tag}: request {}", x.id);
            assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "{tag}: request {}", x.id);
            assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits(), "{tag}: request {}", x.id);
            assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits(), "{tag}: request {}", x.id);
            assert_eq!(x.epoch, y.epoch, "{tag}: request {}", x.id);
            assert_eq!(x.deferrals, y.deferrals, "{tag}: request {}", x.id);
        }
    }

    /// The tentpole contract at engine level: indexed dispatch and the
    /// O(N) scan produce bitwise-identical runs — every router × every
    /// migration policy, under a fault script exercising death
    /// reroutes, steals, checkpoint resumes and whole-fleet outages,
    /// and (for cache-aware) with the engine caches live.
    #[test]
    fn indexed_routing_matches_scan_engine_bitwise_under_faults() {
        let t = marked_trace(6.0, 60.0, 13);
        for policy in MigrationPolicyKind::all() {
            for router in RouterKind::with_live() {
                let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
                let mut c = cfg(server_speeds(3, 0.5, 1.5), script, policy);
                c.router = router;
                let a = run(&t, &c.view());
                let b = run_scan(&t, &c.view());
                let tag = format!("{}/{}", router.name(), policy.name());
                assert_reports_bitwise(&a, &b, &tag);
            }
            let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
            let mut c = cfg(server_speeds(3, 0.5, 1.5), script, policy);
            c.router = RouterKind::CacheAware;
            c.dynamic.cache = enabled_cache();
            let a = run(&t, &c.view());
            let b = run_scan(&t, &c.view());
            let tag = format!("cache-aware/{}", policy.name());
            assert_reports_bitwise(&a, &b, &tag);
        }
    }

    #[test]
    fn no_migration_loses_the_dead_servers_queue() {
        // Deterministic by construction: four simultaneous arrivals at
        // t = 14.9 split 2/2 under JSQ (ties to the lower id), then
        // server 1 dies at t = 15 with its epoch still open — exactly
        // two requests are stranded.
        let mk = |id, t| one(id, t, 20.0);
        let arrivals = vec![mk(0, 1.0), mk(1, 14.9), mk(2, 14.9), mk(3, 14.9), mk(4, 14.9)];
        let t = ArrivalTrace { arrivals, total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 };
        let script = FaultScript::scheduled(vec![down(1, 15.0, 1000.0)]).unwrap();
        let none = run(&t, &cfg(vec![1.0, 1.0], script.clone(), MigrationPolicyKind::None).view());
        assert_eq!(none.lost_to_failure(), 2, "the dead server's open epoch is lost");
        assert_eq!(none.migrated(), 0);
        assert_eq!(none.served(), 3);
        let requeue =
            run(&t, &cfg(vec![1.0, 1.0], script, MigrationPolicyKind::RequeueOnDeath).view());
        assert_eq!(requeue.lost_to_failure(), 0, "requeue must not strand anything");
        assert_eq!(requeue.migrated(), 2, "both orphans move to the surviving server");
        assert_eq!(requeue.served(), 5, "migration recovers the stranded requests");
        // migrated requests keep their identity and deadlines
        for m in &requeue.migrations {
            assert_eq!(m.from, Some(1));
            assert_eq!(m.to, Some(0));
            assert_eq!(m.reason, MigrationReason::DeadServer);
            let o = &requeue.outcomes[m.id];
            assert_eq!(o.id, m.id);
            assert_eq!(o.arrival_s.to_bits(), t.arrivals[m.id].t_s.to_bits());
            assert_eq!(o.deadline_s.to_bits(), t.arrivals[m.id].deadline_s.to_bits());
        }
    }

    #[test]
    fn whole_fleet_outage_parks_and_recovers() {
        let arrivals = vec![one(0, 1.0, 30.0), one(1, 2.0, 30.0)];
        let t = ArrivalTrace { arrivals, total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 };
        let script = FaultScript::scheduled(vec![down(0, 0.5, 10.0)]).unwrap();
        let report = run(&t, &cfg(vec![1.0], script, MigrationPolicyKind::RequeueOnDeath).view());
        assert_eq!(report.outcomes.len(), 2);
        // both arrivals landed while no server was alive, then were
        // re-dispatched at the recovery and served within deadline
        assert_eq!(report.served(), 2, "{:?}", report.outcomes);
        for o in &report.outcomes {
            assert!(o.resolved_s >= 10.0, "served only after the recovery: {o:?}");
            assert!(o.met, "{o:?}");
        }
        assert_eq!(report.migrations.len(), 2);
        assert!(report.migrations.iter().all(|m| m.reason == MigrationReason::Recovery));
        // the recovery stats see exactly one failure
        let rs = report.recovery_stats(30.0);
        assert_eq!(rs.failures, 1);
        assert_eq!(rs.migrated, 2);
    }

    #[test]
    fn permanent_total_outage_drops_everything_as_lost() {
        let arrivals = vec![one(0, 1.0, 5.0)];
        let t = ArrivalTrace { arrivals, total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 };
        let script = FaultScript::scheduled(vec![down(0, 0.0, 1e9)]).unwrap();
        let report = run(&t, &cfg(vec![1.0], script, MigrationPolicyKind::RequeueOnDeath).view());
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.outcomes[0].disposition, Disposition::LostToFailure);
        assert_eq!(report.assignment[0], UNROUTED);
        assert_eq!(report.served(), 0);
    }

    #[test]
    fn steal_when_idle_migrates_carry_overs_under_skew() {
        // A slow and a fast server: the slow one defers under pressure
        // while the fast one drains — stealing should move work.
        let t = trace(10.0, 50.0, 9);
        let epoch = EpochPolicy::new(0.25, 4);
        let dynamic = DynamicConfig { epoch, ..DynamicConfig::default() };
        let speeds = vec![0.3, 2.0];
        let c = EventClusterConfig {
            speeds: &speeds,
            router: RouterKind::RoundRobin,
            dynamic,
            faults: &crate::faults::NO_FAULTS,
            migration: MigrationPolicyKind::StealWhenIdle,
            resume_transfer_s: 0.0,
        };
        let report = run(&t, &c);
        assert_eq!(report.outcomes.len(), t.len());
        // conservation still holds under stealing
        assert_eq!(report.served() + report.dropped(), t.len());
        // replay is bit-identical
        let again = run(&t, &c);
        assert_eq!(report.migrations.len(), again.migrations.len());
        for (x, y) in report.outcomes.iter().zip(&again.outcomes) {
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
        }
    }

    #[test]
    fn empty_trace_is_empty_report() {
        let t = ArrivalTrace {
            arrivals: vec![],
            total_bandwidth_hz: 40_000.0,
            content_bits: 24_000.0,
        };
        let script = FaultScript::scheduled(vec![down(0, 1.0, 2.0)]).unwrap();
        let report =
            run(&t, &cfg(vec![1.0, 1.0], script, MigrationPolicyKind::RequeueOnDeath).view());
        assert!(report.outcomes.is_empty());
        assert_eq!(report.total_epochs(), 0);
        assert_eq!(report.mean_quality(), 0.0);
    }

    #[test]
    fn zero_solve_latency_modes_match_bitwise_even_under_faults() {
        let t = trace(5.0, 50.0, 3);
        let script = FaultScript::random(3, 50.0, 20.0, 8.0, 11);
        for policy in MigrationPolicyKind::all() {
            let mut c = cfg(server_speeds(3, 0.5, 1.5), script.clone(), policy);
            c.dynamic.solve_mode = SolveMode::Pipelined;
            let pipelined = run(&t, &c.view());
            c.dynamic.solve_mode = SolveMode::Synchronous;
            let sync = run(&t, &c.view());
            assert_eq!(pipelined.assignment, sync.assignment, "{}", policy.name());
            for (a, b) in pipelined.outcomes.iter().zip(&sync.outcomes) {
                assert_eq!(a.disposition, b.disposition, "{} request {}", policy.name(), a.id);
                assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "{}", policy.name());
                assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "{}", policy.name());
            }
            assert_eq!(pipelined.horizon_s.to_bits(), sync.horizon_s.to_bits());
            assert_eq!(pipelined.solve_hidden_s(), 0.0, "nothing to hide at zero latency");
        }
    }

    #[test]
    fn pipelined_hides_solve_latency_and_beats_synchronous_under_load() {
        let t = trace(10.0, 60.0, 9);
        let mut c = cfg(vec![1.0, 1.0], FaultScript::empty(), MigrationPolicyKind::None);
        c.dynamic.solve_latency_s = 0.3;
        c.dynamic.solve_mode = SolveMode::Pipelined;
        let pipelined = run(&t, &c.view());
        c.dynamic.solve_mode = SolveMode::Synchronous;
        let sync = run(&t, &c.view());
        assert!(pipelined.solve_hidden_s() > 0.0, "overload must hide some solve time");
        assert_eq!(sync.solve_hidden_s(), 0.0, "synchronous solves are never hidden");
        assert!(
            pipelined.mean_e2e_censored_s() < sync.mean_e2e_censored_s(),
            "pipelined {} vs synchronous {}",
            pipelined.mean_e2e_censored_s(),
            sync.mean_e2e_censored_s()
        );
        // the per-window gauge surfaces the hiding on at least one server
        let gauge_fired =
            pipelined.servers.iter().any(|s| s.epochs.iter().any(|e| e.solve_overlap_w > 0.0));
        assert!(gauge_fired, "the windowed overlap gauge must report the hiding");
    }

    #[test]
    fn live_router_serves_conserves_and_replays() {
        let t = trace(8.0, 50.0, 5);
        let speeds = server_speeds(3, 0.5, 2.0);
        let c = EventClusterConfig {
            speeds: &speeds,
            router: RouterKind::LiveState,
            dynamic: DynamicConfig::default(),
            faults: &crate::faults::NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        let a = run(&t, &c);
        assert_eq!(a.outcomes.len(), t.len());
        assert_eq!(a.served() + a.dropped(), t.len());
        assert!(a.assignment.iter().all(|&s| s < 3));
        let b = run(&t, &c);
        assert_eq!(a.assignment, b.assignment, "live routing must replay bit-identically");
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    }

    #[test]
    fn checkpoint_salvages_in_flight_steps_other_policies_lose_them() {
        // One request, two reference-speed servers. JSQ sends it to
        // server 0 (tie to the lower id); its epoch closes at 1.0 and
        // the batch commits immediately (free GPU). With the paper
        // delay model g(1) ≈ 0.3783 and the 2 s plan horizon the plan
        // runs several singleton batches, so at the death instant
        // t = 1.5 — 0.5 s into execution — exactly one step boundary
        // has passed (batch 1 ends ≈ 1.378, batch 2 ≈ 1.757).
        let arrivals = vec![one(0, 0.0, 10.0)];
        let t = ArrivalTrace { arrivals, total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 };
        let script = FaultScript::scheduled(vec![down(0, 1.5, 100.0)]).unwrap();

        let mut ck = cfg(vec![1.0, 1.0], script.clone(), MigrationPolicyKind::Checkpoint);
        ck.transfer_s = 0.25;
        let checkpoint = run(&t, &ck.view());
        assert_eq!(checkpoint.served(), 1, "{:?}", checkpoint.outcomes);
        let o = &checkpoint.outcomes[0];
        assert_eq!(o.disposition, Disposition::ResumedElsewhere);
        assert_eq!(o.recovered_steps, 1, "exactly one step boundary passed before the death");
        assert!(o.steps > o.recovered_steps, "the resume must add fresh steps on server 1");
        assert!(o.met, "deadline 10 s leaves ample room after the resume: {o:?}");
        assert!(
            o.resolved_s > 1.75,
            "delivery happens after the 1.5 + 0.25 s latent transfer: {o:?}"
        );
        assert_eq!(checkpoint.resumed_elsewhere(), 1);
        assert_eq!(checkpoint.recovered_steps(), 1);
        assert!(
            checkpoint
                .migrations
                .iter()
                .any(|m| m.reason == MigrationReason::Checkpoint && m.to == Some(1)),
            "{:?}",
            checkpoint.migrations
        );
        let rs = checkpoint.recovery_stats(30.0);
        assert_eq!(rs.resumed, 1);
        assert_eq!(rs.recovered_steps, 1);

        // Every non-checkpoint policy loses the cut batch outright —
        // the strict dominance the checkpoint exists to provide.
        for policy in [MigrationPolicyKind::None, MigrationPolicyKind::RequeueOnDeath] {
            let report = run(&t, &cfg(vec![1.0, 1.0], script.clone(), policy).view());
            assert_eq!(report.served(), 0, "{}: {:?}", policy.name(), report.outcomes);
            assert_eq!(report.outcomes[0].disposition, Disposition::LostToFailure);
            assert_eq!(report.outcomes[0].recovered_steps, 0);
            assert!(checkpoint.served() > report.served(), "{}", policy.name());
        }
    }

    #[test]
    fn checkpoint_resume_expires_when_deadline_passes_in_transit() {
        // Same shape, but the transfer is so slow the absolute deadline
        // (10 s) passes mid-transit: the victim expires at its
        // deadline, not at the transfer's end.
        let arrivals = vec![one(0, 0.0, 10.0)];
        let t = ArrivalTrace { arrivals, total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 };
        let script = FaultScript::scheduled(vec![down(0, 1.5, 100.0)]).unwrap();
        let mut c = cfg(vec![1.0, 1.0], script, MigrationPolicyKind::Checkpoint);
        c.transfer_s = 50.0;
        let report = run(&t, &c.view());
        let o = &report.outcomes[0];
        assert_eq!(o.disposition, Disposition::LostToFailure, "{o:?}");
        assert_eq!(o.resolved_s.to_bits(), 10.0f64.to_bits(), "expired at the deadline: {o:?}");
        assert_eq!(report.served(), 0);
    }

    #[test]
    fn zero_fault_checkpoint_degenerates_to_none_bitwise() {
        // With no faults the checkpoint machinery must never engage:
        // the engine tracks nothing, and the run is bit-identical to
        // the plain no-migration engine (and hence to the sequential
        // cluster, by transitivity with the equivalence test above).
        let t = trace(6.0, 50.0, 7);
        for router in RouterKind::all() {
            let mut base =
                cfg(server_speeds(3, 0.5, 1.5), FaultScript::empty(), MigrationPolicyKind::None);
            base.router = router;
            let plain = run(&t, &base.view());
            base.migration = MigrationPolicyKind::Checkpoint;
            base.transfer_s = 0.8;
            let ck = run(&t, &base.view());
            assert_eq!(plain.assignment, ck.assignment, "{}", router.name());
            assert_eq!(plain.horizon_s.to_bits(), ck.horizon_s.to_bits(), "{}", router.name());
            for (a, b) in plain.outcomes.iter().zip(&ck.outcomes) {
                assert_eq!(a.disposition, b.disposition, "request {}", a.id);
                assert_eq!(a.steps, b.steps, "request {}", a.id);
                assert_eq!(a.recovered_steps, 0, "request {}", a.id);
                assert_eq!(b.recovered_steps, 0, "request {}", a.id);
                assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "request {}", a.id);
                assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
                assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "request {}", a.id);
            }
            assert!(ck.migrations.is_empty() && ck.fault_log.is_empty());
        }
    }

    #[test]
    fn pooled_per_server_allocators_replay_bitwise() {
        use crate::bandwidth::{AllocatorPool, PsoAllocator, PsoConfig};
        let t = trace(6.0, 40.0, 2);
        let c = cfg(server_speeds(2, 0.8, 1.2), FaultScript::empty(), MigrationPolicyKind::None);
        let view = c.view();
        let fresh_pool = || {
            AllocatorPool::per_server(2, |_| {
                Box::new(PsoAllocator::new(PsoConfig {
                    particles: 6,
                    iterations: 6,
                    patience: 3,
                    warm_start: true,
                    ..Default::default()
                })) as Box<dyn crate::bandwidth::Allocator>
            })
        };
        let run_pooled = |pool: &AllocatorPool| {
            simulate_event_cluster_pooled(
                &t,
                &Stacking::default(),
                pool,
                &BatchDelayModel::paper(),
                &PowerLawQuality::paper(),
                &view,
            )
        };
        let a = run_pooled(&fresh_pool());
        let b = run_pooled(&fresh_pool());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
            assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits());
        }
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    }

    #[test]
    fn traced_faulted_run_is_bit_identical_and_audits_clean() {
        let t = trace(6.0, 60.0, 9);
        let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
        let mut c = cfg(server_speeds(3, 0.5, 1.5), script, MigrationPolicyKind::Checkpoint);
        c.transfer_s = 0.5;
        let plain = run(&t, &c.view());
        let capture = |rec: &mut crate::obs::Recorder| {
            simulate_event_cluster_traced(
                &t,
                &Stacking::default(),
                &EqualAllocator,
                &BatchDelayModel::paper(),
                &PowerLawQuality::paper(),
                &c.view(),
                rec,
            )
        };
        let mut rec = crate::obs::Recorder::new();
        let traced = capture(&mut rec);
        assert_eq!(plain.assignment, traced.assignment);
        assert_eq!(plain.horizon_s.to_bits(), traced.horizon_s.to_bits());
        for (a, b) in plain.outcomes.iter().zip(&traced.outcomes) {
            assert_eq!(a.disposition, b.disposition, "request {}", a.id);
            assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "request {}", a.id);
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
            assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "request {}", a.id);
        }
        let audit = crate::obs::audit::audit_expecting(&rec.events, t.len());
        assert!(audit.is_clean(), "{}", audit.render());
        // ...and the capture itself replays bit-identically.
        let mut rec2 = crate::obs::Recorder::new();
        capture(&mut rec2);
        assert_eq!(rec.events.len(), rec2.events.len());
        for (x, y) in rec.events.iter().zip(&rec2.events) {
            assert_eq!(x.t_s.to_bits(), y.t_s.to_bits());
            assert_eq!((x.server, x.request, x.kind), (y.server, y.request, y.kind));
        }
    }

    #[test]
    fn marked_trace_with_cache_disabled_is_bitwise_identical() {
        // Prompt marks ride along in the trace but a cache-disabled
        // run must never read them: bitwise identical to the same
        // trace with every mark stripped, even under faults.
        let marked = marked_trace(6.0, 60.0, 9);
        let mut stripped = marked.clone();
        for a in &mut stripped.arrivals {
            a.mark = PromptMark::ZERO;
        }
        let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
        let c = cfg(server_speeds(3, 0.5, 1.5), script, MigrationPolicyKind::Checkpoint);
        let a = run(&marked, &c.view());
        let b = run(&stripped, &c.view());
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.disposition, y.disposition, "request {}", x.id);
            assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "request {}", x.id);
            assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits(), "request {}", x.id);
        }
        assert_eq!(a.served_from_cache(), 0);
        assert_eq!(a.cache_stats(), crate::cache::CacheStats::default());
    }

    #[test]
    fn cache_enabled_run_hits_conserves_replays_and_audits_clean() {
        let t = marked_trace(6.0, 60.0, 9);
        let script = FaultScript::random(3, 60.0, 25.0, 8.0, 11);
        let mut c = cfg(server_speeds(3, 0.5, 1.5), script, MigrationPolicyKind::Checkpoint);
        c.router = RouterKind::CacheAware;
        c.dynamic.cache = enabled_cache();
        c.transfer_s = 0.5;
        let report = run(&t, &c.view());
        assert_eq!(report.outcomes.len(), t.len());
        assert_eq!(report.served() + report.dropped(), t.len(), "census conservation");
        let hits = report.served_from_cache();
        assert!(hits > 0, "a skewed Zipf trace must produce cache hits");
        assert_eq!(report.cache_stats().hits, hits as u64);
        for o in &report.outcomes {
            if o.disposition == Disposition::ServedFromCache {
                assert_eq!(o.wait_s, 0.0, "hits bypass the epoch queue: {o:?}");
                assert!(o.steps > 0, "{o:?}");
                assert!(o.met, "transmission alone fits the paper deadlines: {o:?}");
            }
        }
        // Bit-identical replay, and the flight recorder agrees.
        let again = run(&t, &c.view());
        assert_eq!(report.assignment, again.assignment);
        assert_eq!(report.horizon_s.to_bits(), again.horizon_s.to_bits());
        for (x, y) in report.outcomes.iter().zip(&again.outcomes) {
            assert_eq!(x.disposition, y.disposition);
            assert_eq!(x.quality.to_bits(), y.quality.to_bits());
            assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits());
        }
        let mut rec = crate::obs::Recorder::new();
        let traced = simulate_event_cluster_traced(
            &t,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &c.view(),
            &mut rec,
        );
        assert_eq!(traced.horizon_s.to_bits(), report.horizon_s.to_bits());
        let cache_hits = rec
            .events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::CacheHit { .. }))
            .count();
        assert_eq!(cache_hits, hits, "one CacheHit event per cache-served request");
        let audit = crate::obs::audit::audit_expecting(&rec.events, t.len());
        assert!(audit.is_clean(), "{}", audit.render());
    }

    #[test]
    fn model_swaps_tighten_deadlines_in_placement_only_mode() {
        // capacity 0 keeps the model catalog but never stores content:
        // no hits, only load/swap charges on the two-model trace.
        let t = marked_trace(6.0, 50.0, 5);
        let mut c =
            cfg(server_speeds(2, 0.8, 1.2), FaultScript::empty(), MigrationPolicyKind::None);
        c.dynamic.cache = CacheSettings { capacity: 0, ..enabled_cache() };
        let report = run(&t, &c.view());
        assert_eq!(report.served_from_cache(), 0, "nothing can hit a zero-capacity cache");
        assert!(report.cache_stats().swaps > 0, "two models on one slot must swap");
        assert!(
            report.outcomes.iter().zip(&t.arrivals).any(|(o, a)| o.deadline_s < a.deadline_s),
            "some residual deadline must be tightened by a model load"
        );
    }
}
