//! Fixed-size batching baseline: batches of ⌊K/2⌋, tighter deadlines
//! first, shrinking only when fewer services remain.

use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;

use super::types::{Batch, BatchScheduler, Schedule, Service, TaskRef};

#[derive(Debug, Clone, Copy, Default)]
pub struct FixedSizeBatching {
    /// Batch size; 0 means the paper's default ⌊K/2⌋.
    pub batch_size: u32,
}

impl FixedSizeBatching {
    pub fn new(batch_size: u32) -> Self {
        Self { batch_size }
    }
}

impl BatchScheduler for FixedSizeBatching {
    fn name(&self) -> &'static str {
        "fixed-size-batching"
    }

    fn schedule(
        &self,
        services: &[Service],
        delay: &BatchDelayModel,
        _quality: &dyn QualityModel,
    ) -> Schedule {
        let max_steps = 1000u32;
        let size = if self.batch_size == 0 {
            ((services.len() / 2) as u32).max(1)
        } else {
            self.batch_size
        };
        let mut schedule = Schedule::empty(services.len());
        let mut tau: Vec<f64> = services.iter().map(|s| s.gen_budget).collect();
        let mut active: Vec<usize> = (0..services.len()).collect();
        let mut now = 0.0;

        while !active.is_empty() {
            // Prioritize tighter remaining budgets.
            active.sort_by(|&x, &y| tau[x].partial_cmp(&tau[y]).unwrap());
            let x_n = (size as usize).min(active.len());
            let gx = delay.g(x_n as u32);
            // Discard services in this batch window that cannot fit it.
            let violating: Vec<usize> =
                active[..x_n].iter().copied().filter(|&k| tau[k] < gx).collect();
            if !violating.is_empty() {
                active.retain(|k| !violating.contains(k));
                continue;
            }
            let packed: Vec<usize> = active[..x_n].to_vec();
            let tasks: Vec<TaskRef> = packed
                .iter()
                .map(|&k| {
                    schedule.steps[k] += 1;
                    TaskRef { service: k, step: schedule.steps[k] }
                })
                .collect();
            // Time passes for everyone.
            for &k in &active {
                tau[k] -= gx;
            }
            for &k in &packed {
                schedule.completion[k] = now + gx;
            }
            schedule.batches.push(Batch { start: now, duration: gx, tasks });
            now += gx;
            active.retain(|&k| {
                tau[k] >= 0.0 && schedule.steps[k] < max_steps && tau[k] >= delay.g(1)
            });
        }
        schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::validate::validate_schedule;

    #[test]
    fn default_size_is_half_k() {
        let delay = BatchDelayModel::paper();
        let svcs: Vec<Service> = (0..10).map(|i| Service::new(i, 8.0)).collect();
        let s = FixedSizeBatching::default().schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert!(s.batches.iter().all(|b| b.size() <= 5));
        assert!(s.batches.iter().any(|b| b.size() == 5));
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn explicit_size_respected() {
        let delay = BatchDelayModel::paper();
        let svcs: Vec<Service> = (0..9).map(|i| Service::new(i, 6.0)).collect();
        let s = FixedSizeBatching::new(3).schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert!(s.batches.iter().all(|b| b.size() <= 3));
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn tight_service_prioritized() {
        let delay = BatchDelayModel::paper();
        let mut svcs = vec![Service::new(0, 1.2)];
        svcs.extend((1..8).map(|i| Service::new(i, 12.0)));
        let s = FixedSizeBatching::default().schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert!(s.steps[0] >= 1, "steps={:?}", s.steps);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn empty_input() {
        let s = FixedSizeBatching::default().schedule(
            &[],
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
        );
        assert!(s.batches.is_empty());
    }
}
