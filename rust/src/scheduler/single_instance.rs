//! Single-instance baseline [14]: no batching at all.
//!
//! Services are sorted by ascending delay requirement and processed one
//! at a time; each denoising task runs as a singleton batch (cost
//! g(1)). A service keeps denoising until its own remaining budget
//! cannot fit another task, then the server moves to the next service.
//! Services whose budget expires while waiting are dropped (outage).

use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;

use super::types::{Batch, BatchScheduler, Schedule, Service, TaskRef};

#[derive(Debug, Clone, Copy, Default)]
pub struct SingleInstance {
    /// Optional step cap per service (defaults to 1000, the DDIM
    /// training discretization).
    pub max_steps: u32,
}

impl SingleInstance {
    pub fn new(max_steps: u32) -> Self {
        Self { max_steps }
    }

    fn cap(&self) -> u32 {
        if self.max_steps == 0 {
            1000
        } else {
            self.max_steps
        }
    }
}

impl BatchScheduler for SingleInstance {
    fn name(&self) -> &'static str {
        "single-instance"
    }

    fn schedule(
        &self,
        services: &[Service],
        delay: &BatchDelayModel,
        _quality: &dyn QualityModel,
    ) -> Schedule {
        let mut order: Vec<usize> = (0..services.len()).collect();
        order.sort_by(|&x, &y| {
            services[x].gen_budget.partial_cmp(&services[y].gen_budget).unwrap()
        });

        let g1 = delay.g(1);
        let mut now = 0.0;
        let mut schedule = Schedule::empty(services.len());
        for &k in &order {
            // Wall clock has advanced while this service waited; its
            // remaining budget is gen_budget − now.
            let mut step = 0u32;
            while step < self.cap() && now + g1 <= services[k].gen_budget {
                step += 1;
                schedule.batches.push(Batch {
                    start: now,
                    duration: g1,
                    tasks: vec![TaskRef { service: k, step }],
                });
                now += g1;
            }
            schedule.steps[k] = step;
            schedule.completion[k] = if step > 0 { now } else { 0.0 };
        }
        schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::validate::validate_schedule;

    #[test]
    fn first_service_hogs_the_gpu() {
        let delay = BatchDelayModel::paper();
        let svcs: Vec<Service> = (0..5).map(|i| Service::new(i, 4.0)).collect();
        let s = SingleInstance::default().schedule(&svcs, &delay, &PowerLawQuality::paper());
        // Equal budgets: the first processed service exhausts nearly the
        // whole window, starving the rest — the pathology in Fig. 2b.
        assert!(s.steps[0] > 0);
        assert_eq!(s.steps[4], 0, "steps={:?}", s.steps);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn tightest_deadline_first() {
        let delay = BatchDelayModel::paper();
        let svcs = vec![Service::new(0, 10.0), Service::new(1, 1.0)];
        let s = SingleInstance::default().schedule(&svcs, &delay, &PowerLawQuality::paper());
        // Service 1 (tight) is processed first and completes ~2 steps;
        // service 0 then uses the remaining window.
        assert!(s.steps[1] >= 1);
        assert!(s.steps[0] >= 1);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn respects_cap() {
        let delay = BatchDelayModel::paper();
        let svcs = vec![Service::new(0, 100.0)];
        let s = SingleInstance::new(7).schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert_eq!(s.steps[0], 7);
    }

    #[test]
    fn all_batches_are_singletons() {
        let delay = BatchDelayModel::paper();
        let svcs: Vec<Service> = (0..4).map(|i| Service::new(i, 3.0 + i as f64)).collect();
        let s = SingleInstance::default().schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert!(s.batches.iter().all(|b| b.size() == 1));
        validate_schedule(&s, &svcs, &delay).unwrap();
    }
}
