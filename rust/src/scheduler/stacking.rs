//! The STACKING algorithm (Algorithm 1) — the paper's core contribution.
//!
//! Two empirical insights drive it (Figs. 1a/1b):
//!  1. `b ≫ a` in `g(X) = aX + b`: the fixed per-batch cost dominates,
//!     so batches should be as large as possible;
//!  2. early denoising steps improve quality far more than later ones,
//!     so step counts should be *balanced* across services.
//!
//! The algorithm iterates a clustering → packing → batching loop under
//! an auxiliary target `T*` (the desired per-service step count), then
//! grid-searches `T*` and keeps the best objective. It never evaluates
//! the quality function inside the loop — only at the end — which is
//! what makes it agnostic to the quality model's form.

use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;

use super::types::{mean_quality_of, Batch, BatchScheduler, Schedule, Service, TaskRef};

/// Tunables for [`Stacking`]. `Default` reproduces the paper's setup.
#[derive(Debug, Clone, Copy)]
pub struct StackingConfig {
    /// Upper bound of the `T*` grid search. `None` derives it from the
    /// largest generation budget: ⌈max τ'_k / (a+b)⌉ (no service can
    /// exceed that many steps even alone).
    pub t_star_max: Option<u32>,
    /// Hard cap on per-service steps (a DDIM chain cannot exceed the
    /// training discretization; also bounds runaway loops for huge
    /// budgets).
    pub max_steps: u32,
    /// Coarse-to-fine `T*` search: evaluate every `stride`-th `T*`, then
    /// refine the `stride − 1` neighbours around the coarse winner.
    /// 1 = exhaustive (the paper's grid). Measured in §Perf: stride 4
    /// gives ~2.4× fewer trials with no mean-FID change on the paper
    /// scenario (the objective is near-unimodal in `T*`).
    pub t_star_stride: u32,
}

impl Default for StackingConfig {
    fn default() -> Self {
        Self { t_star_max: None, max_steps: 1000, t_star_stride: 4 }
    }
}

/// The STACKING scheduler.
#[derive(Debug, Clone, Default)]
pub struct Stacking {
    pub config: StackingConfig,
}

impl Stacking {
    pub fn new(config: StackingConfig) -> Self {
        Self { config }
    }

    fn derive_t_star_max(&self, services: &[Service], delay: &BatchDelayModel) -> u32 {
        if let Some(cap) = self.config.t_star_max {
            return cap.max(1);
        }
        let per_task = delay.a + delay.b;
        let max_budget = services.iter().map(|s| s.gen_budget).fold(0.0_f64, f64::max);
        let bound = (max_budget / per_task).ceil() as u32;
        bound.clamp(1, self.config.max_steps)
    }
}

/// Result of one clustering→packing→batching round (internal).
struct Round {
    start: f64,
    duration: f64,
    /// Executed tasks (empty in dry runs).
    tasks: Vec<TaskRef>,
    /// Number of tasks executed (valid in dry runs too).
    size: u32,
}

/// Reusable buffers for the `T*` grid search — one allocation set per
/// `schedule` call instead of per trial. The grid runs dozens of dry
/// trials whose schedules are thrown away; re-allocating six vectors
/// per trial dominated the solve profile (§Perf), so every trial now
/// resets and reuses this scratch — `tests/hotpath_alloc.rs` pins the
/// allocation count as O(1) in the grid size.
#[derive(Debug, Default)]
struct TrialScratch {
    /// Remaining generation budget τ'_k (Eq. 15 subtracts each batch).
    tau: Vec<f64>,
    /// Completed steps T^c_k.
    done: Vec<u32>,
    /// Still-active service indices (positions into `services`).
    active: Vec<usize>,
    /// Services that finished during the current packing pass.
    drained: Vec<bool>,
    /// T^e_k per service, recomputed once per round (the sort
    /// comparator otherwise re-derives it O(K log K) times — §Perf).
    t_extra_cache: Vec<u32>,
    /// The candidate batch of the current round.
    packed: Vec<usize>,
}

impl TrialScratch {
    /// Re-initialize for a fresh trial over `services`. Every slot a
    /// trial reads is overwritten here, so reuse never leaks state
    /// between trials.
    fn reset(&mut self, services: &[Service], delay: &BatchDelayModel) {
        let n = services.len();
        self.tau.clear();
        self.tau.extend(services.iter().map(|s| s.gen_budget));
        self.done.clear();
        self.done.resize(n, 0);
        self.drained.clear();
        self.drained.resize(n, false);
        self.t_extra_cache.clear();
        self.t_extra_cache.resize(n, 0);
        // Services whose budget cannot fit even a singleton batch are
        // outages from the start.
        self.active.clear();
        let tau = &self.tau;
        self.active.extend((0..n).filter(|&k| tau[k] >= delay.g(1)));
        self.packed.clear();
    }
}

/// Mutable per-run state for one `T*` trial, borrowing the reusable
/// scratch.
struct Trial<'a> {
    delay: &'a BatchDelayModel,
    max_steps: u32,
    s: &'a mut TrialScratch,
}

impl<'a> Trial<'a> {
    fn new(
        scratch: &'a mut TrialScratch,
        services: &[Service],
        delay: &'a BatchDelayModel,
        max_steps: u32,
    ) -> Self {
        scratch.reset(services, delay);
        Self { delay, max_steps, s: scratch }
    }

    /// T^e_k (Eq. 16): tasks service k can still complete, assuming the
    /// best case of it running in minimal batches.
    #[inline]
    fn t_extra(&self, k: usize) -> u32 {
        let per = self.delay.a + self.delay.b;
        let raw = (self.s.tau[k] / per).floor();
        if raw <= 0.0 {
            0
        } else {
            (raw as u32).min(self.max_steps.saturating_sub(self.s.done[k]))
        }
    }

    /// T'_k (Eq. 17): ideal final step count. Hot paths read
    /// `done[k] + t_extra_cache[k]` instead (see `round`); kept for
    /// tests/documentation of the paper's quantity.
    #[inline]
    #[allow(dead_code)]
    fn t_ideal(&self, k: usize) -> u32 {
        self.s.done[k] + self.t_extra(k)
    }

    /// One clustering → packing → batching round. Returns the executed
    /// batch, or `None` when no progress is possible (drained services
    /// are removed from `active` as a side effect).
    fn round(&mut self, t_star: u32, now: f64, record: bool) -> Option<Round> {
        let delay = *self.delay;
        // Refresh the per-round T^e cache, then drop services that can no
        // longer run any task (their T_k is whatever they completed) or
        // that hit the step cap.
        let mut active = std::mem::take(&mut self.s.active);
        for &k in &active {
            self.s.t_extra_cache[k] = self.t_extra(k);
        }
        {
            let cache = &self.s.t_extra_cache;
            active.retain(|&k| cache[k] > 0);
        }
        if active.is_empty() {
            self.s.active = active;
            return None;
        }

        // -------- Clustering (Eqs. 16–18) --------
        // Sort ascending by T'_k; F = {k : T'_k ≤ T*}.
        {
            let cache = &self.s.t_extra_cache;
            let done = &self.s.done;
            let tau = &self.s.tau;
            active.sort_by(|&x, &y| {
                let tx = done[x] + cache[x];
                let ty = done[y] + cache[y];
                tx.cmp(&ty)
                    .then(tau[x].partial_cmp(&tau[y]).unwrap_or(std::cmp::Ordering::Equal))
            });
        }
        self.s.active = active;
        let f_len = {
            let cache = &self.s.t_extra_cache;
            let done = &self.s.done;
            self.s.active.iter().filter(|&&k| done[k] + cache[k] <= t_star).count()
        };
        let k_len = self.s.active.len();

        // -------- Packing (Eqs. 19–20) --------
        let mut x_n: usize = if f_len > 0 {
            // Case 1: prioritize F; optionally grow the batch with the
            // strictest K\F services, as long as no service in F loses a
            // step: need T^e_k · (a·X + b) ≤ τ'_k for all k ∈ F, i.e.
            // X ≤ (τ'^min − b·T^{e(max)}) / (a·T^{e(max)}).
            let te_max = self.s.active[..f_len]
                .iter()
                .map(|&k| self.s.t_extra_cache[k])
                .max()
                .unwrap_or(0) as f64;
            let tau_min = self.s.active[..f_len]
                .iter()
                .map(|&k| self.s.tau[k])
                .fold(f64::INFINITY, f64::min);
            let cap = if te_max > 0.0 {
                ((tau_min - delay.b * te_max) / (delay.a * te_max)).floor().max(0.0) as usize
            } else {
                f_len
            };
            f_len.max(cap.min(k_len))
        } else {
            // Case 2: no starving services; batch as large as possible
            // while every service can still reach T*:
            // (a·X + b)·T* ≤ (a+b)·T'_k  for all k, bounded by the min T'.
            let t_prime_min = self
                .s
                .active
                .iter()
                .map(|&k| self.s.done[k] + self.s.t_extra_cache[k])
                .min()
                .unwrap() as f64;
            let t_star_f = t_star as f64;
            let cap = (((delay.a + delay.b) * t_prime_min - delay.b * t_star_f)
                / (delay.a * t_star_f))
                .floor()
                .max(1.0) as usize;
            cap.min(k_len)
        };
        x_n = x_n.clamp(1, k_len);

        // -------- Batching --------
        // Pack the first X_n services (ascending T'_k). Any packed
        // service whose remaining budget is below the (shrinking) batch
        // delay has finished: remove it from the batch AND from K.
        // (In-place retain + a drained mark; the old two-vec partition +
        // per-drop O(n) active scan showed up in the §Perf profile. The
        // batch buffer itself is scratch, reused across rounds/trials.)
        let mut packed = std::mem::take(&mut self.s.packed);
        packed.clear();
        packed.extend_from_slice(&self.s.active[..x_n]);
        let mut any_drained = false;
        loop {
            let gx = delay.g(packed.len() as u32);
            let before = packed.len();
            let (tau, drained) = (&self.s.tau, &mut self.s.drained);
            packed.retain(|&k| {
                if tau[k] >= gx {
                    true
                } else {
                    // Completed: mark for removal from the active set.
                    drained[k] = true;
                    any_drained = true;
                    false
                }
            });
            if packed.len() == before || packed.is_empty() {
                break;
            }
        }
        if any_drained {
            let drained = &self.s.drained;
            self.s.active.retain(|&k| !drained[k]);
        }
        if packed.is_empty() {
            // Everyone we tried to pack was drained; the next round will
            // re-cluster the remainder.
            self.s.packed = packed;
            return if self.s.active.is_empty() {
                None
            } else {
                Some(Round { start: now, duration: 0.0, tasks: Vec::new(), size: 0 })
            };
        }

        let gx = delay.g(packed.len() as u32);
        let tasks: Vec<TaskRef> = if record {
            packed
                .iter()
                .map(|&k| {
                    self.s.done[k] += 1;
                    TaskRef { service: k, step: self.s.done[k] }
                })
                .collect()
        } else {
            // Dry run: only step counts matter for the (P2) objective;
            // skip the per-task allocation (§Perf: most T* trials lose
            // and their schedules are thrown away).
            for &k in &packed {
                self.s.done[k] += 1;
            }
            Vec::new()
        };

        // Time passes for every remaining service (Eq. 15).
        for &k in &self.s.active {
            self.s.tau[k] -= gx;
        }
        // Drop services that overran their budget (deadline violation) or
        // finished the step cap; their T_k stays at `done`.
        {
            let (tau, done) = (&self.s.tau, &self.s.done);
            let max_steps = self.max_steps;
            self.s.active.retain(|&k| tau[k] >= 0.0 && done[k] < max_steps);
        }

        let size = packed.len() as u32;
        self.s.packed = packed;
        Some(Round { start: now, duration: gx, tasks, size })
    }

    /// Run the full clustering-packing-batching loop for one `T*`
    /// without recording: only the per-service step counts (the (P2)
    /// objective) are computed, left in the scratch's `done` — no
    /// allocation at all (§Perf).
    fn run_dry(&mut self, t_star: u32, num_services: usize) {
        // Bound: every non-empty batch advances ≥1 task and tasks are
        // bounded by num_services * max_steps.
        let max_rounds = num_services * self.max_steps as usize + 8;
        let mut now = 0.0;
        for _ in 0..max_rounds {
            match self.round(t_star, now, false) {
                None => break,
                Some(round) => {
                    if round.size == 0 {
                        continue; // services drained during packing
                    }
                    now = round.start + round.duration;
                }
            }
        }
    }

    /// Run one `T*` with full recording: batches and completion times
    /// are materialized (the winner trial only).
    fn run_recorded(&mut self, t_star: u32, num_services: usize) -> Schedule {
        let mut batches: Vec<Batch> = Vec::new();
        let mut now = 0.0;
        let mut completion = vec![0.0; num_services];
        let max_rounds = num_services * self.max_steps as usize + 8;
        for _ in 0..max_rounds {
            match self.round(t_star, now, true) {
                None => break,
                Some(round) => {
                    if round.size == 0 {
                        continue; // services drained during packing
                    }
                    now = round.start + round.duration;
                    for t in &round.tasks {
                        completion[t.service] = now;
                    }
                    batches.push(Batch {
                        start: round.start,
                        duration: round.duration,
                        tasks: round.tasks,
                    });
                }
            }
        }
        // Completion time only meaningful for the *final* step of each
        // service — it already is: the last batch containing the service
        // set it.
        Schedule { batches, steps: self.s.done.clone(), completion }
    }
}

impl BatchScheduler for Stacking {
    fn name(&self) -> &'static str {
        "stacking"
    }

    fn schedule(
        &self,
        services: &[Service],
        delay: &BatchDelayModel,
        quality: &dyn QualityModel,
    ) -> Schedule {
        if services.is_empty() {
            return Schedule::empty(0);
        }
        let t_star_max = self.derive_t_star_max(services, delay);
        let stride = self.config.t_star_stride.max(1);
        let mut best: Option<(f64, u32)> = None;
        let mut scratch = TrialScratch::default();
        // Dry-run trials: only step counts are computed, into the one
        // reused scratch; the winning T* is re-run once with full
        // recording (§Perf).
        let try_t_star =
            |t_star: u32, best: &mut Option<(f64, u32)>, scratch: &mut TrialScratch| {
                let mut trial = Trial::new(scratch, services, delay, self.config.max_steps);
                trial.run_dry(t_star, services.len());
                let q = mean_quality_of(&trial.s.done, quality);
                let better = match best {
                    None => true,
                    Some((best_q, _)) => q < *best_q - 1e-12,
                };
                if better {
                    *best = Some((q, t_star));
                }
            };
        // Coarse pass.
        let mut t_star = 1;
        while t_star <= t_star_max {
            try_t_star(t_star, &mut best, &mut scratch);
            t_star += stride;
        }
        // Fine pass around the coarse winner.
        if stride > 1 {
            let center = best.as_ref().map(|(_, t)| *t).unwrap_or(1);
            let lo = center.saturating_sub(stride - 1).max(1);
            let hi = (center + stride - 1).min(t_star_max);
            for t in lo..=hi {
                if (t as i64 - 1) % stride as i64 != 0 {
                    try_t_star(t, &mut best, &mut scratch);
                }
            }
        }
        let (_, winner) = best.expect("at least one T* trial");
        let mut best_schedule = Trial::new(&mut scratch, services, delay, self.config.max_steps)
            .run_recorded(winner, services.len());
        let mut best_q = best_schedule.mean_quality(quality);

        // Dominance guard: the clustering/packing heuristic can lose to
        // a baseline on knife-edge workloads (e.g. several tight budgets
        // inside [g(1), g(2)) drain together, where serving them one by
        // one was feasible). Both baselines are in STACKING's search
        // space conceptually, so keep whichever schedule scores best —
        // this makes "stacking ≤ greedy/single-instance" hold on *every*
        // instance (pinned by tests/scheduler_properties.rs) and never
        // degrades quality.
        let single = super::single_instance::SingleInstance::new(self.config.max_steps)
            .schedule(services, delay, quality);
        let mut consider = |candidate: Schedule| {
            let q = candidate.mean_quality(quality);
            if q < best_q - 1e-12 {
                best_q = q;
                best_schedule = candidate;
            }
        };
        consider(single);
        let greedy = super::greedy::GreedyBatching.schedule(services, delay, quality);
        // Greedy caps steps at 1000 internally; only usable when that
        // respects this scheduler's configured cap.
        if greedy.steps.iter().all(|&t| t <= self.config.max_steps) {
            consider(greedy);
        }
        best_schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::validate::validate_schedule;

    fn paper_delay() -> BatchDelayModel {
        BatchDelayModel::paper()
    }

    fn quality() -> PowerLawQuality {
        PowerLawQuality::paper()
    }

    fn services_with_budgets(budgets: &[f64]) -> Vec<Service> {
        budgets.iter().enumerate().map(|(i, &b)| Service::new(i, b)).collect()
    }

    #[test]
    fn empty_input() {
        let s = Stacking::default().schedule(&[], &paper_delay(), &quality());
        assert_eq!(s.batches.len(), 0);
    }

    #[test]
    fn single_service_uses_full_budget() {
        let delay = paper_delay();
        let svcs = services_with_budgets(&[5.0]);
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        // Alone, every batch is size 1: floor(5.0 / g(1)) steps.
        let expect = (5.0 / delay.g(1)).floor() as u32;
        assert_eq!(s.steps[0], expect);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn infeasible_service_gets_zero_steps() {
        let delay = paper_delay();
        let svcs = services_with_budgets(&[0.1, 5.0]); // 0.1 < g(1)
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        assert_eq!(s.steps[0], 0);
        assert!(s.steps[1] > 0);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn negative_budget_handled() {
        let delay = paper_delay();
        let svcs = services_with_budgets(&[-1.0, 4.0]);
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        assert_eq!(s.steps[0], 0);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn equal_budgets_equal_steps() {
        let delay = paper_delay();
        let svcs = services_with_budgets(&[8.0; 10]);
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        let t0 = s.steps[0];
        assert!(t0 > 0);
        assert!(s.steps.iter().all(|&t| t == t0), "steps={:?}", s.steps);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn batching_beats_sequential_for_many_services() {
        // With K=20 and τ' = 8 s, batch denoising must yield far more
        // total steps than single-instance could (Fig. 2b's premise).
        let delay = paper_delay();
        let svcs = services_with_budgets(&[8.0; 20]);
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        let total: u32 = s.steps.iter().sum();
        // Single instance within 8 s: floor(8/0.3783) ≈ 21 tasks TOTAL.
        assert!(total > 100, "total steps = {total}");
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn tight_services_not_starved() {
        // One very tight and several loose services: the tight one must
        // still complete at least one step (clustering prioritizes it).
        let delay = paper_delay();
        let mut budgets = vec![1.0]; // fits ~2 singleton tasks
        budgets.extend(std::iter::repeat(15.0).take(9));
        let svcs = services_with_budgets(&budgets);
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        assert!(s.steps[0] >= 1, "tight service starved: {:?}", s.steps);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn heterogeneous_budgets_monotone_steps() {
        // More budget must never mean fewer steps (weak monotonicity over
        // the sorted order) — a fairness sanity check on the packing.
        let delay = paper_delay();
        let budgets: Vec<f64> = (1..=12).map(|i| i as f64 * 1.5).collect();
        let svcs = services_with_budgets(&budgets);
        let s = Stacking::default().schedule(&svcs, &delay, &quality());
        for w in s.steps.windows(2) {
            assert!(w[1] + 2 >= w[0], "steps={:?}", s.steps);
        }
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn respects_max_steps_cap() {
        let delay = paper_delay();
        let svcs = services_with_budgets(&[500.0]);
        let cfg = StackingConfig { t_star_max: Some(40), max_steps: 25, ..Default::default() };
        let s = Stacking::new(cfg).schedule(&svcs, &delay, &quality());
        assert_eq!(s.steps[0], 25);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn better_than_greedy_under_tight_mix() {
        // The motivating scenario: mixed deadlines. STACKING must beat
        // all-in-one-batch greedy on mean quality.
        use crate::scheduler::greedy::GreedyBatching;
        let delay = paper_delay();
        let q = quality();
        let budgets = [1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 10.0, 12.0, 15.0, 18.0];
        let svcs = services_with_budgets(&budgets);
        let stacking = Stacking::default().schedule(&svcs, &delay, &q);
        let greedy = GreedyBatching.schedule(&svcs, &delay, &q);
        assert!(
            stacking.mean_quality(&q) <= greedy.mean_quality(&q) + 1e-9,
            "stacking {} vs greedy {}",
            stacking.mean_quality(&q),
            greedy.mean_quality(&q)
        );
        validate_schedule(&stacking, &svcs, &delay).unwrap();
    }

    #[test]
    fn deterministic() {
        let delay = paper_delay();
        let svcs = services_with_budgets(&[3.0, 7.0, 11.0, 13.0]);
        let a = Stacking::default().schedule(&svcs, &delay, &quality());
        let b = Stacking::default().schedule(&svcs, &delay, &quality());
        assert_eq!(a, b);
    }
}
