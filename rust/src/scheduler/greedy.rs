//! Greedy batching baseline: every active service's next task goes into
//! one maximal batch, every round. Maximizes amortization but burns the
//! budget of tight-deadline services on batches sized by loose ones.

use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;

use super::types::{Batch, BatchScheduler, Schedule, Service, TaskRef};

#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyBatching;

impl BatchScheduler for GreedyBatching {
    fn name(&self) -> &'static str {
        "greedy-batching"
    }

    fn schedule(
        &self,
        services: &[Service],
        delay: &BatchDelayModel,
        _quality: &dyn QualityModel,
    ) -> Schedule {
        let max_steps = 1000u32;
        let mut schedule = Schedule::empty(services.len());
        let mut tau: Vec<f64> = services.iter().map(|s| s.gen_budget).collect();
        let mut active: Vec<usize> = (0..services.len()).collect();
        let mut now = 0.0;

        loop {
            // Terminate services that cannot fit the upcoming batch: the
            // batch is sized by everyone still active.
            loop {
                let gx = delay.g(active.len() as u32);
                let before = active.len();
                active.retain(|&k| tau[k] >= gx && schedule.steps[k] < max_steps);
                if active.len() == before {
                    break;
                }
            }
            if active.is_empty() {
                break;
            }
            let gx = delay.g(active.len() as u32);
            let tasks: Vec<TaskRef> = active
                .iter()
                .map(|&k| {
                    schedule.steps[k] += 1;
                    TaskRef { service: k, step: schedule.steps[k] }
                })
                .collect();
            for &k in &active {
                tau[k] -= gx;
                schedule.completion[k] = now + gx;
            }
            schedule.batches.push(Batch { start: now, duration: gx, tasks });
            now += gx;
        }
        schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quality::PowerLawQuality;
    use crate::scheduler::validate::validate_schedule;

    #[test]
    fn equal_budgets_full_batches() {
        let delay = BatchDelayModel::paper();
        let svcs: Vec<Service> = (0..10).map(|i| Service::new(i, 6.0)).collect();
        let s = GreedyBatching.schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert!(s.batches.iter().all(|b| b.size() == 10));
        let t = s.steps[0];
        assert!(t > 0);
        assert!(s.steps.iter().all(|&x| x == t));
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn tight_service_dropped_early() {
        let delay = BatchDelayModel::paper();
        // g(11) ≈ 0.62: the 0.5-budget service cannot fit even one batch
        // sized by all 11 services — greedy gives it zero steps, while a
        // smarter scheduler would start with a small batch.
        let mut svcs = vec![Service::new(0, 0.5)];
        svcs.extend((1..11).map(|i| Service::new(i, 10.0)));
        let s = GreedyBatching.schedule(&svcs, &delay, &PowerLawQuality::paper());
        assert_eq!(s.steps[0], 0, "steps={:?}", s.steps);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn shrinks_batches_as_services_finish() {
        let delay = BatchDelayModel::paper();
        let svcs = vec![Service::new(0, 1.0), Service::new(1, 5.0)];
        let s = GreedyBatching.schedule(&svcs, &delay, &PowerLawQuality::paper());
        let sizes: Vec<u32> = s.batches.iter().map(|b| b.size()).collect();
        assert!(sizes.windows(2).all(|w| w[1] <= w[0]), "sizes={sizes:?}");
        assert!(s.steps[1] > s.steps[0]);
        validate_schedule(&s, &svcs, &delay).unwrap();
    }

    #[test]
    fn empty_input() {
        let s = GreedyBatching.schedule(&[], &BatchDelayModel::paper(), &PowerLawQuality::paper());
        assert!(s.batches.is_empty());
    }
}
