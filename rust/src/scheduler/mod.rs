//! Batch-denoising schedulers — problem (P2) of the paper.
//!
//! * [`Stacking`] — the paper's contribution (Algorithm 1).
//! * [`SingleInstance`] / [`GreedyBatching`] / [`FixedSizeBatching`] —
//!   the three comparison baselines of Section IV.
//! * [`validate_schedule`] — machine-checks the constraint system
//!   (Eqs. 1, 2, 6, 7, 14) on any schedule.

pub mod fixed_size;
pub mod greedy;
pub mod single_instance;
pub mod stacking;
pub mod types;
pub mod validate;

pub use fixed_size::FixedSizeBatching;
pub use greedy::GreedyBatching;
pub use single_instance::SingleInstance;
pub use stacking::{Stacking, StackingConfig};
pub use types::{Batch, BatchScheduler, Schedule, Service, TaskRef};
pub use validate::{validate_schedule, ScheduleError};

/// All schedulers compared in the paper's Fig. 2, in presentation order.
pub fn all_schedulers() -> Vec<Box<dyn BatchScheduler>> {
    vec![
        Box::new(Stacking::default()),
        Box::new(SingleInstance::default()),
        Box::new(GreedyBatching),
        Box::new(FixedSizeBatching::default()),
    ]
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::delay::BatchDelayModel;
    use crate::prop_assert;
    use crate::quality::PowerLawQuality;
    use crate::util::prop::forall;

    fn random_services(g: &mut crate::util::prop::Gen) -> Vec<Service> {
        let k = g.usize_in(1, 24);
        (0..k).map(|i| Service::new(i, g.f64_in(-0.5, 20.0))).collect()
    }

    fn random_delay(g: &mut crate::util::prop::Gen) -> BatchDelayModel {
        BatchDelayModel::new(g.f64_in(0.005, 0.2), g.f64_in(0.05, 1.0))
    }

    /// Every scheduler must emit a constraint-satisfying schedule for any
    /// workload — the central invariant of the whole system.
    #[test]
    fn all_schedulers_produce_valid_schedules() {
        forall("schedulers produce valid schedules", 120, |g| {
            let services = random_services(g);
            let delay = random_delay(g);
            let quality = PowerLawQuality::paper();
            for sched in all_schedulers() {
                let s = sched.schedule(&services, &delay, &quality);
                let v = validate_schedule(&s, &services, &delay);
                prop_assert!(
                    g,
                    v.is_ok(),
                    "{}: {:?} (services={:?}, delay={:?})",
                    sched.name(),
                    v,
                    services,
                    delay
                );
                prop_assert!(
                    g,
                    s.steps.len() == services.len(),
                    "{}: steps len mismatch",
                    sched.name()
                );
            }
            true
        });
    }

    /// STACKING must never be worse than greedy or fixed-size batching:
    /// both are within its search space (greedy ≈ huge T*, and the
    /// T*-search keeps the best).
    #[test]
    fn stacking_dominates_naive_batching() {
        forall("stacking <= greedy & fixed", 60, |g| {
            let services = random_services(g);
            let delay = random_delay(g);
            let quality = PowerLawQuality::paper();
            let st =
                Stacking::default().schedule(&services, &delay, &quality).mean_quality(&quality);
            let gr = GreedyBatching.schedule(&services, &delay, &quality).mean_quality(&quality);
            // allow microscopic numeric slack
            prop_assert!(g, st <= gr * 1.02 + 1e-9, "stacking {st} > greedy {gr}");
            true
        });
    }

    /// Relaxing every deadline must not degrade STACKING's objective.
    #[test]
    fn stacking_monotone_in_budget() {
        forall("stacking monotone in budgets", 40, |g| {
            let services = random_services(g);
            let delay = random_delay(g);
            let quality = PowerLawQuality::paper();
            let widened: Vec<Service> = services
                .iter()
                .map(|s| Service::new(s.id, s.gen_budget + g.f64_in(0.5, 5.0)))
                .collect();

            let base =
                Stacking::default().schedule(&services, &delay, &quality).mean_quality(&quality);
            let wide =
                Stacking::default().schedule(&widened, &delay, &quality).mean_quality(&quality);
            prop_assert!(g, wide <= base + 1e-9, "widened {wide} > base {base}");
            true
        });
    }
}
