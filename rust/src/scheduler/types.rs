//! Core data types for batch-denoising scheduling (problem (P2)).

use crate::delay::BatchDelayModel;
use crate::quality::QualityModel;

/// A service as seen by the generation-phase scheduler: bandwidth
/// allocation has already fixed its transmission delay, leaving a
/// generation budget τ'_k = τ_k − D^ct_k (Eq. 14).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Service {
    /// Stable id; indexes into `Schedule::steps`.
    pub id: usize,
    /// Generation budget τ'_k in seconds. May be ≤ 0 (infeasible after
    /// transmission: the service can complete zero steps).
    pub gen_budget: f64,
}

impl Service {
    pub fn new(id: usize, gen_budget: f64) -> Self {
        Self { id, gen_budget }
    }
}

/// One denoising task: step `step` (1-based) of service `service`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TaskRef {
    pub service: usize,
    pub step: u32,
}

/// One executed batch `n`: starts at `start`, runs for `duration`
/// (= g(|tasks|)), and advances every listed task by one step.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub start: f64,
    pub duration: f64,
    pub tasks: Vec<TaskRef>,
}

impl Batch {
    pub fn size(&self) -> u32 {
        self.tasks.len() as u32
    }

    pub fn end(&self) -> f64 {
        self.start + self.duration
    }
}

/// A complete batch-denoising plan: the solution of (P2) for one set of
/// generation budgets. `steps[k]` is T_k (0 = outage), `completion[k]`
/// is D^cg_k (0 for zero steps).
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub batches: Vec<Batch>,
    pub steps: Vec<u32>,
    pub completion: Vec<f64>,
}

impl Schedule {
    pub fn empty(num_services: usize) -> Self {
        Self {
            batches: Vec::new(),
            steps: vec![0; num_services],
            completion: vec![0.0; num_services],
        }
    }

    /// Total wall-clock time of the generation phase.
    pub fn makespan(&self) -> f64 {
        self.batches.last().map(Batch::end).unwrap_or(0.0)
    }

    /// Total number of executed denoising tasks.
    pub fn total_tasks(&self) -> usize {
        self.batches.iter().map(|b| b.tasks.len()).sum()
    }

    /// Mean quality over all services — the objective of (P2)
    /// (services with zero steps are charged the outage quality).
    pub fn mean_quality(&self, quality: &dyn QualityModel) -> f64 {
        mean_quality_of(&self.steps, quality)
    }

    /// Number of services that completed zero steps.
    pub fn outages(&self) -> usize {
        self.steps.iter().filter(|&&t| t == 0).count()
    }

    /// Denoising steps of `service` completed strictly within `t_rel`
    /// seconds of the schedule's start: a step counts once its whole
    /// batch has finished (`end() <= t_rel`) — step boundaries are the
    /// only checkpointable instants, a half-executed batch contributes
    /// nothing. This is what a mid-batch server death can salvage.
    pub fn steps_completed_by(&self, service: usize, t_rel: f64) -> u32 {
        self.batches
            .iter()
            .filter(|b| b.end() <= t_rel)
            .flat_map(|b| b.tasks.iter())
            .filter(|task| task.service == service)
            .count() as u32
    }

    /// GPU busy fraction: Σ g(X_n) is the makespan by construction, so
    /// this reports the fraction of task-time vs. fixed overhead.
    pub fn amortization_ratio(&self, delay: &BatchDelayModel) -> f64 {
        let total: f64 = self.batches.iter().map(|b| delay.g(b.size())).sum();
        if total == 0.0 {
            return 0.0;
        }
        let task_time: f64 = self.batches.iter().map(|b| delay.a * b.size() as f64).sum();
        task_time / total
    }
}

/// Mean quality over raw step counts — the single (P2) objective
/// definition, shared by [`Schedule::mean_quality`] and STACKING's dry
/// `T*` trials (which score step counts without materializing a
/// schedule).
pub(crate) fn mean_quality_of(steps: &[u32], quality: &dyn QualityModel) -> f64 {
    if steps.is_empty() {
        return 0.0;
    }
    steps.iter().map(|&t| quality.quality(t)).sum::<f64>() / steps.len() as f64
}

/// Common interface for STACKING and the three baselines.
pub trait BatchScheduler: Send + Sync {
    fn name(&self) -> &'static str;

    /// Solve (P2): choose batches and per-service step counts that
    /// minimize mean quality subject to each service's generation budget.
    fn schedule(
        &self,
        services: &[Service],
        delay: &BatchDelayModel,
        quality: &dyn QualityModel,
    ) -> Schedule;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quality::PowerLawQuality;
    use crate::util::approx_eq;

    fn two_batch_schedule() -> Schedule {
        Schedule {
            batches: vec![
                Batch {
                    start: 0.0,
                    duration: 0.4,
                    tasks: vec![TaskRef { service: 0, step: 1 }, TaskRef { service: 1, step: 1 }],
                },
                Batch { start: 0.4, duration: 0.38, tasks: vec![TaskRef { service: 0, step: 2 }] },
            ],
            steps: vec![2, 1, 0],
            completion: vec![0.78, 0.4, 0.0],
        }
    }

    #[test]
    fn makespan_and_totals() {
        let s = two_batch_schedule();
        assert!(approx_eq(s.makespan(), 0.78, 1e-12));
        assert_eq!(s.total_tasks(), 3);
        assert_eq!(s.outages(), 1);
    }

    #[test]
    fn empty_schedule() {
        let s = Schedule::empty(3);
        assert_eq!(s.makespan(), 0.0);
        assert_eq!(s.total_tasks(), 0);
        assert_eq!(s.outages(), 3);
    }

    #[test]
    fn steps_completed_by_counts_whole_batches_only() {
        let s = two_batch_schedule();
        // nothing before the first batch ends
        assert_eq!(s.steps_completed_by(0, 0.0), 0);
        assert_eq!(s.steps_completed_by(0, 0.39), 0);
        // first batch (ends 0.4) gives each member one step; the
        // half-done second batch contributes nothing
        assert_eq!(s.steps_completed_by(0, 0.4), 1);
        assert_eq!(s.steps_completed_by(1, 0.5), 1);
        assert_eq!(s.steps_completed_by(0, 0.77), 1);
        // past the makespan every scheduled step is complete
        assert_eq!(s.steps_completed_by(0, s.makespan()), 2);
        assert_eq!(s.steps_completed_by(0, 10.0), s.steps[0]);
        // a service with zero scheduled steps never completes any
        assert_eq!(s.steps_completed_by(2, 10.0), 0);
    }

    #[test]
    fn mean_quality_counts_outages() {
        let s = two_batch_schedule();
        let q = PowerLawQuality::paper();
        let expect = (q.quality(2) + q.quality(1) + q.outage()) / 3.0;
        assert!(approx_eq(s.mean_quality(&q), expect, 1e-12));
    }

    #[test]
    fn amortization_ratio_increases_with_batching() {
        let delay = BatchDelayModel::paper();
        let batched = Schedule {
            batches: vec![Batch {
                start: 0.0,
                duration: delay.g(10),
                tasks: (0..10).map(|k| TaskRef { service: k, step: 1 }).collect(),
            }],
            steps: vec![1; 10],
            completion: vec![delay.g(10); 10],
        };
        let sequential = Schedule {
            batches: (0..10)
                .map(|k| Batch {
                    start: k as f64 * delay.g(1),
                    duration: delay.g(1),
                    tasks: vec![TaskRef { service: k, step: 1 }],
                })
                .collect(),
            steps: vec![1; 10],
            completion: (1..=10).map(|i| i as f64 * delay.g(1)).collect(),
        };
        assert!(batched.amortization_ratio(&delay) > sequential.amortization_ratio(&delay));
    }
}
