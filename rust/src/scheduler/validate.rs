//! Machine-checkable validation of the paper's constraints on any
//! produced [`Schedule`] — used by unit tests, property tests, and the
//! simulator's debug assertions.
//!
//! Checks constraints (1), (2), (6), (7) of (P0) and the generation-
//! budget form of the deadline (14), plus internal consistency between
//! recorded durations and the delay model.

use std::collections::HashMap;
use std::fmt;

use crate::delay::BatchDelayModel;

use super::types::{Schedule, Service};

/// A constraint violation, tagged with the paper's equation number.
/// (Display/Error are hand-implemented: the offline crate set has no
/// `thiserror`; messages match the former derive exactly.)
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleError {
    StepMultiplicity { service: usize, step: u32, count: usize },
    StepsMismatch { service: usize, steps: u32, executed: Vec<u32> },
    BatchOverlap { n: usize, prev: usize, start: f64, end: f64 },
    DependencyViolated { service: usize, step: u32, prev_step: u32, start: f64, end: f64 },
    BudgetExceeded { service: usize, finish: f64, budget: f64 },
    DurationMismatch { n: usize, duration: f64, size: u32, expected: f64 },
    DuplicateInBatch { n: usize, service: usize },
    CompletionMismatch { service: usize, recorded: f64, actual: f64 },
}

impl fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::StepMultiplicity { service, step, count } => write!(
                f,
                "eq(2): service {service} step {step} executed {count} times (must be exactly 1)"
            ),
            Self::StepsMismatch { service, steps, executed } => write!(
                f,
                "eq(2): service {service} reports T_k={steps} but executed steps {executed:?}"
            ),
            Self::BatchOverlap { n, prev, start, end } => write!(
                f,
                "eq(6): batch {n} starts at {start:.6} before batch {prev} ends at {end:.6}"
            ),
            Self::DependencyViolated { service, step, prev_step, start, end } => write!(
                f,
                "eq(7): service {service} step {step} starts at {start:.6} before step {prev_step} completes at {end:.6}"
            ),
            Self::BudgetExceeded { service, finish, budget } => write!(
                f,
                "eq(14): service {service} finishes generation at {finish:.6} > budget {budget:.6}"
            ),
            Self::DurationMismatch { n, duration, size, expected } => {
                write!(f, "batch {n} duration {duration:.6} != g({size}) = {expected:.6}")
            }
            Self::DuplicateInBatch { n, service } => {
                write!(f, "batch {n} contains service {service} more than once")
            }
            Self::CompletionMismatch { service, recorded, actual } => write!(
                f,
                "completion[{service}]={recorded:.6} but last batch of the service ends at {actual:.6}"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

const EPS: f64 = 1e-9;

/// Validate a schedule against the constraint system. Returns the first
/// violation found, or `Ok(())`.
pub fn validate_schedule(
    schedule: &Schedule,
    services: &[Service],
    delay: &BatchDelayModel,
) -> Result<(), ScheduleError> {
    // ---- durations consistent with g(X), batches sequential (6) ----
    let mut prev_end = 0.0;
    for (n, batch) in schedule.batches.iter().enumerate() {
        let expected = delay.g(batch.size());
        if (batch.duration - expected).abs() > EPS {
            return Err(ScheduleError::DurationMismatch {
                n,
                duration: batch.duration,
                size: batch.size(),
                expected,
            });
        }
        if n > 0 && batch.start + EPS < prev_end {
            return Err(ScheduleError::BatchOverlap {
                n,
                prev: n - 1,
                start: batch.start,
                end: prev_end,
            });
        }
        prev_end = batch.end();
        // no duplicate service within one batch
        let mut seen = Vec::with_capacity(batch.tasks.len());
        for t in &batch.tasks {
            if seen.contains(&t.service) {
                return Err(ScheduleError::DuplicateInBatch { n, service: t.service });
            }
            seen.push(t.service);
        }
    }

    // ---- per-service execution map ----
    // (service, step) -> (start, end)
    let mut exec: HashMap<(usize, u32), (f64, f64)> = HashMap::new();
    let mut counts: HashMap<(usize, u32), usize> = HashMap::new();
    for batch in &schedule.batches {
        for t in &batch.tasks {
            *counts.entry((t.service, t.step)).or_insert(0) += 1;
            exec.insert((t.service, t.step), (batch.start, batch.end()));
        }
    }
    for (&(service, step), &count) in &counts {
        if count != 1 {
            return Err(ScheduleError::StepMultiplicity { service, step, count });
        }
    }

    for (k, svc) in services.iter().enumerate() {
        let t_k = schedule.steps[k];
        // (2): steps 1..=T_k each executed exactly once, nothing beyond.
        let mut executed: Vec<u32> =
            exec.keys().filter(|(s, _)| *s == k).map(|(_, step)| *step).collect();
        executed.sort_unstable();
        let expected: Vec<u32> = (1..=t_k).collect();
        if executed != expected {
            return Err(ScheduleError::StepsMismatch { service: k, steps: t_k, executed });
        }
        // (7): dependency order.
        for step in 2..=t_k {
            let (start, _) = exec[&(k, step)];
            let (_, prev_end) = exec[&(k, step - 1)];
            if start + EPS < prev_end {
                return Err(ScheduleError::DependencyViolated {
                    service: k,
                    step,
                    prev_step: step - 1,
                    start,
                    end: prev_end,
                });
            }
        }
        // (14): generation completes within the budget.
        if t_k > 0 {
            let finish = exec[&(k, t_k)].1;
            if finish > svc.gen_budget + EPS {
                return Err(ScheduleError::BudgetExceeded {
                    service: k,
                    finish,
                    budget: svc.gen_budget,
                });
            }
            let recorded = schedule.completion[k];
            if (recorded - finish).abs() > EPS {
                return Err(ScheduleError::CompletionMismatch {
                    service: k,
                    recorded,
                    actual: finish,
                });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::types::{Batch, TaskRef};

    fn delay() -> BatchDelayModel {
        BatchDelayModel::new(0.1, 0.5)
    }

    fn service(budget: f64) -> Vec<Service> {
        vec![Service::new(0, budget)]
    }

    fn singleton_batch(start: f64, service: usize, step: u32) -> Batch {
        Batch { start, duration: 0.6, tasks: vec![TaskRef { service, step }] }
    }

    #[test]
    fn accepts_valid_schedule() {
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 1), singleton_batch(0.6, 0, 2)],
            steps: vec![2],
            completion: vec![1.2],
        };
        validate_schedule(&s, &service(2.0), &delay()).unwrap();
    }

    #[test]
    fn rejects_duplicate_step() {
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 1), singleton_batch(0.6, 0, 1)],
            steps: vec![1],
            completion: vec![1.2],
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::StepMultiplicity { .. }));
    }

    #[test]
    fn rejects_overlapping_batches() {
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 1), singleton_batch(0.3, 0, 2)],
            steps: vec![2],
            completion: vec![0.9],
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::BatchOverlap { .. }));
    }

    #[test]
    fn rejects_dependency_violation() {
        // step 2 in the first batch, step 1 in the second
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 2), singleton_batch(0.6, 0, 1)],
            steps: vec![2],
            completion: vec![1.2],
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::DependencyViolated { .. }));
    }

    #[test]
    fn rejects_budget_overrun() {
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 1)],
            steps: vec![1],
            completion: vec![0.6],
        };
        let err = validate_schedule(&s, &service(0.5), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::BudgetExceeded { .. }));
    }

    #[test]
    fn rejects_wrong_duration() {
        let s = Schedule {
            batches: vec![Batch {
                start: 0.0,
                duration: 0.7, // g(1) = 0.6
                tasks: vec![TaskRef { service: 0, step: 1 }],
            }],
            steps: vec![1],
            completion: vec![0.7],
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::DurationMismatch { .. }));
    }

    #[test]
    fn rejects_steps_gap() {
        // reports T_k = 2 but only step 2 executed
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 2)],
            steps: vec![2],
            completion: vec![0.6],
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::StepsMismatch { .. }));
    }

    #[test]
    fn rejects_duplicate_service_in_batch() {
        let s = Schedule {
            batches: vec![Batch {
                start: 0.0,
                duration: 0.7, // g(2) = 0.7
                tasks: vec![TaskRef { service: 0, step: 1 }, TaskRef { service: 0, step: 2 }],
            }],
            steps: vec![2],
            completion: vec![0.7],
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::DuplicateInBatch { .. }));
    }

    #[test]
    fn rejects_completion_mismatch() {
        let s = Schedule {
            batches: vec![singleton_batch(0.0, 0, 1)],
            steps: vec![1],
            completion: vec![0.9], // actual end is 0.6
        };
        let err = validate_schedule(&s, &service(5.0), &delay()).unwrap_err();
        assert!(matches!(err, ScheduleError::CompletionMismatch { .. }));
    }
}
