//! Deterministic content-addressed generation cache + model catalogs.
//!
//! Real AIGC edge fleets see heavy-tailed prompt popularity, so the
//! biggest latency win available is not a faster denoising loop but
//! skipping denoising entirely: a [`GenCache`] hit pays only the
//! paper's transmission phase, turning the (P1) generation cost into a
//! lookup. Entries are keyed on the arrival's
//! [`PromptMark`](crate::trace::PromptMark) `(model_id, prompt_id)`
//! and store the *best step count* generated so far — a re-generation
//! at higher quality upgrades the entry in place.
//!
//! Everything here is deterministic: eviction is either CLOCK
//! (second-chance, no randomness at all) or seeded-random on the
//! in-tree PCG — never wall clock — so cache-enabled runs replay
//! bit-identically per seed. The whole subsystem sits behind the
//! off-by-default `[cache]` config; with `enabled = false` no engine
//! constructs any of these types and runs stay bitwise identical to
//! the pre-cache engines (the same zero-cost discipline as
//! `obs::NullSink`).
//!
//! [`ModelCatalog`] models the placement half: a server holds at most
//! `model_slots` diffusion models resident; routing a request whose
//! model is absent charges `load_delay_s` of swap time (tightening the
//! request's residual deadline) and evicts the oldest-loaded model
//! round-robin.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::trace::PromptMark;
use crate::util::Pcg64;

/// Dedicated PCG stream for cache eviction draws.
const CACHE_STREAM: u64 = 0xCAC4E;

/// Deterministic eviction policy for a full [`GenCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionKind {
    /// CLOCK / second-chance: a hand sweeps the slot table, clearing
    /// referenced bits until it finds an unreferenced victim. No
    /// randomness at all.
    Clock,
    /// Seeded-random victim selection on the in-tree PCG.
    SeededRandom,
}

impl EvictionKind {
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "clock" | "second-chance" => Ok(Self::Clock),
            "random" | "seeded-random" => Ok(Self::SeededRandom),
            _ => bail!(
                "unknown eviction policy '{name}' (expected \"clock\" | \"second-chance\" | \
                 \"random\" | \"seeded-random\")"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Clock => "clock",
            Self::SeededRandom => "random",
        }
    }
}

/// Generation-cache settings. TOML section `[cache]`; disabled by
/// default so every existing recipe replays bit-identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheSettings {
    /// Master switch: `false` means no engine constructs a cache at
    /// all (the bit-identity position).
    pub enabled: bool,
    /// Entries per server; 0 disables caching but keeps the model
    /// catalog (placement-only mode).
    pub capacity: usize,
    pub eviction: EvictionKind,
    /// Diffusion models resident per server at once.
    pub model_slots: usize,
    /// Seconds charged to load/swap a model that is not resident.
    pub load_delay_s: f64,
    /// Seed for the seeded-random eviction draws; 0 = derive from the
    /// experiment seed at the CLI layer.
    pub seed: u64,
}

impl Default for CacheSettings {
    fn default() -> Self {
        Self {
            enabled: false,
            capacity: 64,
            eviction: EvictionKind::Clock,
            model_slots: 1,
            load_delay_s: 0.5,
            seed: 0,
        }
    }
}

/// Hit/miss/eviction counters for one cache (or a fleet merge).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Model catalog loads/swaps charged.
    pub swaps: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.insertions += other.insertions;
        self.evictions += other.evictions;
        self.swaps += other.swaps;
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Slot {
    key: PromptMark,
    /// Best step count generated for this key so far (more steps =
    /// better quality under the paper's monotone quality curve).
    steps: u32,
    /// CLOCK second-chance bit, set on every hit.
    referenced: bool,
}

/// Capacity-bounded content-addressed cache: `(model, prompt)` → best
/// generated step count. O(1) lookup via a position index; eviction by
/// the configured deterministic policy.
#[derive(Debug, Clone)]
pub struct GenCache {
    capacity: usize,
    slots: Vec<Slot>,
    index: HashMap<PromptMark, usize>,
    /// CLOCK hand.
    hand: usize,
    rng: Pcg64,
    eviction: EvictionKind,
    pub stats: CacheStats,
}

impl GenCache {
    pub fn new(capacity: usize, eviction: EvictionKind, seed: u64) -> Self {
        Self {
            capacity,
            slots: Vec::with_capacity(capacity.min(1024)),
            index: HashMap::new(),
            hand: 0,
            rng: Pcg64::new(seed, CACHE_STREAM),
            eviction,
            stats: CacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Admission-time probe: `Some(best_steps)` on a hit (refreshing
    /// the entry's second-chance bit), `None` on a miss. Both update
    /// the stats.
    pub fn lookup(&mut self, key: PromptMark) -> Option<u32> {
        match self.index.get(&key) {
            Some(&pos) => {
                self.slots[pos].referenced = true;
                self.stats.hits += 1;
                Some(self.slots[pos].steps)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Record a freshly generated result. An existing entry upgrades
    /// to the better (higher) step count; a new key evicts if the
    /// cache is at capacity. Returns the evicted key, if any, so
    /// mirrors (the cache-aware router's inverted owner index) can
    /// stay membership-exact without rescanning.
    pub fn insert(&mut self, key: PromptMark, steps: u32) -> Option<PromptMark> {
        if self.capacity == 0 {
            return None;
        }
        if let Some(&pos) = self.index.get(&key) {
            if steps > self.slots[pos].steps {
                self.slots[pos].steps = steps;
            }
            self.slots[pos].referenced = true;
            return None;
        }
        let evicted =
            if self.slots.len() >= self.capacity { Some(self.evict_one()) } else { None };
        let pos = self.slots.len();
        self.slots.push(Slot { key, steps, referenced: false });
        self.index.insert(key, pos);
        self.stats.insertions += 1;
        evicted
    }

    /// Drop one victim chosen by the configured policy, returning its
    /// key. The freed slot is filled by swap-remove, so the index
    /// entry of the moved slot is repaired in place.
    fn evict_one(&mut self) -> PromptMark {
        debug_assert!(!self.slots.is_empty());
        let victim = match self.eviction {
            EvictionKind::Clock => {
                // Second chance: clear referenced bits until an
                // unreferenced slot comes under the hand. Terminates
                // within two sweeps.
                loop {
                    let pos = self.hand % self.slots.len();
                    self.hand = (pos + 1) % self.slots.len();
                    if self.slots[pos].referenced {
                        self.slots[pos].referenced = false;
                    } else {
                        break pos;
                    }
                }
            }
            EvictionKind::SeededRandom => self.rng.below(self.slots.len() as u64) as usize,
        };
        let removed = self.slots.swap_remove(victim);
        self.index.remove(&removed.key);
        if victim < self.slots.len() {
            self.index.insert(self.slots[victim].key, victim);
        }
        self.stats.evictions += 1;
        removed.key
    }

    /// Does the cache currently hold `key`? Read-only (no stats, no
    /// second-chance refresh) — the router's shadow probe.
    pub fn contains(&self, key: PromptMark) -> bool {
        self.index.contains_key(&key)
    }

    /// Counter snapshot for this cache alone (no catalog swaps).
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// Which diffusion models a server holds resident. Model 0 is loaded
/// at boot; replacement is round-robin over the slots (deterministic,
/// no clocks).
#[derive(Debug, Clone)]
pub struct ModelCatalog {
    slot_count: usize,
    resident: Vec<u32>,
    /// Round-robin replacement cursor.
    next: usize,
}

impl ModelCatalog {
    pub fn new(slot_count: usize) -> Self {
        let slot_count = slot_count.max(1);
        Self { slot_count, resident: vec![0], next: 0 }
    }

    pub fn is_resident(&self, model: u32) -> bool {
        self.resident.contains(&model)
    }

    /// The models currently resident, in load order.
    pub fn resident_models(&self) -> &[u32] {
        &self.resident
    }

    /// Make `model` resident, returning `true` iff a load/swap was
    /// needed (the caller charges the load delay).
    pub fn ensure_resident(&mut self, model: u32) -> bool {
        self.ensure_resident_reporting(model).0
    }

    /// [`ensure_resident`](Self::ensure_resident) that also reports
    /// which model (if any) lost residency, so mirrors of the catalog
    /// can stay membership-exact without rescanning.
    pub fn ensure_resident_reporting(&mut self, model: u32) -> (bool, Option<u32>) {
        if self.is_resident(model) {
            return (false, None);
        }
        if self.resident.len() < self.slot_count {
            self.resident.push(model);
            return (true, None);
        }
        let out = self.resident[self.next];
        self.resident[self.next] = model;
        self.next = (self.next + 1) % self.slot_count;
        // Only report a model that truly left: a multi-slot catalog
        // could in principle still hold `out` elsewhere.
        let evicted =
            if out != model && !self.resident.contains(&out) { Some(out) } else { None };
        (true, evicted)
    }
}

/// One server's cache state: the generation cache plus the model
/// catalog, behind the admission-time API the engines call.
#[derive(Debug, Clone)]
pub struct ServerCache {
    pub cache: GenCache,
    pub catalog: ModelCatalog,
    load_delay_s: f64,
}

impl ServerCache {
    pub fn new(settings: &CacheSettings) -> Self {
        Self {
            cache: GenCache::new(settings.capacity, settings.eviction, settings.seed),
            catalog: ModelCatalog::new(settings.model_slots),
            load_delay_s: settings.load_delay_s,
        }
    }

    /// One per server; every instance seeds identically (the caches
    /// diverge by content, not by stream).
    pub fn fleet(settings: &CacheSettings, n: usize) -> Vec<ServerCache> {
        (0..n).map(|_| ServerCache::new(settings)).collect()
    }

    /// Admission-time probe: `Some(best_steps)` bypasses the epoch
    /// batch entirely (a hit needs no GPU and no resident model).
    pub fn lookup(&mut self, mark: PromptMark) -> Option<u32> {
        self.cache.lookup(mark)
    }

    /// Charge for the request's model on a miss: 0.0 when resident,
    /// `load_delay_s` when a load/swap had to happen.
    pub fn ensure_resident(&mut self, model: u32) -> f64 {
        self.ensure_resident_reporting(model).0
    }

    /// [`ensure_resident`](Self::ensure_resident) that also reports
    /// the model (if any) that lost residency in the swap.
    pub fn ensure_resident_reporting(&mut self, model: u32) -> (f64, Option<u32>) {
        let (loaded, evicted) = self.catalog.ensure_resident_reporting(model);
        if loaded {
            self.cache.stats.swaps += 1;
            (self.load_delay_s, evicted)
        } else {
            (0.0, None)
        }
    }

    /// Record a freshly served generation, reporting the evicted key
    /// (if any) so shadow mirrors can stay membership-exact.
    pub fn insert(&mut self, mark: PromptMark, steps: u32) -> Option<PromptMark> {
        self.cache.insert(mark, steps)
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mark(model: u32, prompt: u32) -> PromptMark {
        PromptMark { model, prompt }
    }

    fn settings(capacity: usize, eviction: EvictionKind) -> CacheSettings {
        CacheSettings {
            enabled: true,
            capacity,
            eviction,
            model_slots: 2,
            load_delay_s: 0.5,
            seed: 7,
        }
    }

    #[test]
    fn eviction_names_round_trip_and_bad_name_lists_valid() {
        for kind in [EvictionKind::Clock, EvictionKind::SeededRandom] {
            assert_eq!(EvictionKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(EvictionKind::from_name("second-chance").unwrap(), EvictionKind::Clock);
        assert_eq!(EvictionKind::from_name("seeded-random").unwrap(), EvictionKind::SeededRandom);
        let err = EvictionKind::from_name("lru").unwrap_err().to_string();
        assert!(err.contains("clock") && err.contains("random"), "{err}");
    }

    #[test]
    fn hit_after_insert_and_best_steps_monotone() {
        let mut c = GenCache::new(8, EvictionKind::Clock, 1);
        assert_eq!(c.lookup(mark(0, 1)), None);
        c.insert(mark(0, 1), 40);
        assert_eq!(c.lookup(mark(0, 1)), Some(40));
        // Upgrades keep the best step count; downgrades are ignored.
        c.insert(mark(0, 1), 25);
        assert_eq!(c.lookup(mark(0, 1)), Some(40));
        c.insert(mark(0, 1), 90);
        assert_eq!(c.lookup(mark(0, 1)), Some(90));
        assert_eq!(c.stats.insertions, 1, "upgrades are not new insertions");
        assert_eq!(c.stats.hits, 3);
        assert_eq!(c.stats.misses, 1);
        // Distinct models are distinct content even at equal prompts.
        assert_eq!(c.lookup(mark(1, 1)), None);
    }

    #[test]
    fn eviction_never_exceeds_capacity() {
        for eviction in [EvictionKind::Clock, EvictionKind::SeededRandom] {
            let mut c = GenCache::new(4, eviction, 9);
            for p in 0..100u32 {
                c.insert(mark(0, p), p + 1);
                assert!(c.len() <= 4, "{eviction:?}");
            }
            assert_eq!(c.len(), 4, "{eviction:?}");
            assert_eq!(c.stats.evictions, 96, "{eviction:?}");
            // The index stays consistent through swap-removes: every
            // resident key still resolves to its own steps.
            let resident: Vec<Slot> = c.slots.clone();
            for s in resident {
                assert_eq!(c.lookup(s.key), Some(s.steps), "{eviction:?}");
            }
        }
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = GenCache::new(0, EvictionKind::Clock, 3);
        c.insert(mark(0, 5), 10);
        assert!(c.is_empty());
        assert_eq!(c.lookup(mark(0, 5)), None);
        assert_eq!(c.stats.insertions, 0);
    }

    #[test]
    fn clock_second_chance_protects_referenced_entries() {
        let mut c = GenCache::new(2, EvictionKind::Clock, 1);
        c.insert(mark(0, 1), 10);
        c.insert(mark(0, 2), 10);
        // Touch prompt 1: its referenced bit shields it from the next
        // eviction, so inserting prompt 3 must evict prompt 2.
        assert_eq!(c.lookup(mark(0, 1)), Some(10));
        c.insert(mark(0, 3), 10);
        assert!(c.contains(mark(0, 1)), "referenced entry survives");
        assert!(!c.contains(mark(0, 2)), "unreferenced entry is the victim");
        assert!(c.contains(mark(0, 3)));
    }

    #[test]
    fn seeded_random_eviction_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut c = GenCache::new(8, EvictionKind::SeededRandom, seed);
            for p in 0..200u32 {
                c.insert(mark(p % 3, p), p);
            }
            let mut keys: Vec<(u32, u32)> =
                c.slots.iter().map(|s| (s.key.model, s.key.prompt)).collect();
            keys.sort_unstable();
            keys
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12), "different seeds pick different victims");
    }

    #[test]
    fn insert_reports_evicted_key_and_catalog_reports_swapped_model() {
        let mut c = GenCache::new(2, EvictionKind::Clock, 1);
        assert_eq!(c.insert(mark(0, 1), 10), None);
        assert_eq!(c.insert(mark(0, 2), 10), None);
        assert_eq!(c.insert(mark(0, 1), 50), None, "upgrade in place evicts nothing");
        assert_eq!(c.lookup(mark(0, 1)), Some(50));
        // Prompt 1 carries the referenced bit, so prompt 2 is evicted.
        assert_eq!(c.insert(mark(0, 3), 10), Some(mark(0, 2)));

        let mut cat = ModelCatalog::new(2);
        assert_eq!(cat.ensure_resident_reporting(1), (true, None), "free slot evicts nothing");
        assert_eq!(cat.ensure_resident_reporting(2), (true, Some(0)));
        assert_eq!(cat.ensure_resident_reporting(2), (false, None));
        assert_eq!(cat.resident_models(), &[2, 1][..]);
    }

    #[test]
    fn model_catalog_round_robin_swap() {
        let mut cat = ModelCatalog::new(2);
        assert!(cat.is_resident(0), "model 0 is loaded at boot");
        assert!(!cat.ensure_resident(0), "resident model costs nothing");
        assert!(cat.ensure_resident(1), "cold load");
        assert!(cat.is_resident(0) && cat.is_resident(1));
        // Slots full: loading 2 replaces round-robin (slot 0 first).
        assert!(cat.ensure_resident(2));
        assert!(!cat.is_resident(0));
        assert!(cat.is_resident(1) && cat.is_resident(2));
        assert!(cat.ensure_resident(3));
        assert!(!cat.is_resident(1));
        assert!(cat.is_resident(2) && cat.is_resident(3));
    }

    #[test]
    fn server_cache_charges_swap_delay_once_resident() {
        let mut sc = ServerCache::new(&settings(8, EvictionKind::Clock));
        assert_eq!(sc.ensure_resident(0), 0.0, "model 0 is resident at boot");
        assert_eq!(sc.ensure_resident(1), 0.5, "cold load charges the delay");
        assert_eq!(sc.ensure_resident(1), 0.0, "now resident");
        assert_eq!(sc.stats().swaps, 1);
        sc.insert(mark(1, 9), 33);
        assert_eq!(sc.lookup(mark(1, 9)), Some(33));
        assert_eq!(sc.stats().hits, 1);
        assert!(sc.stats().hit_rate() > 0.0);
    }

    #[test]
    fn stats_merge_sums_fields() {
        let a = CacheStats { hits: 1, misses: 2, insertions: 3, evictions: 4, swaps: 5 };
        let mut b =
            CacheStats { hits: 10, misses: 20, insertions: 30, evictions: 40, swaps: 50 };
        b.merge(&a);
        assert_eq!(
            b,
            CacheStats { hits: 11, misses: 22, insertions: 33, evictions: 44, swaps: 55 }
        );
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        assert!((b.hit_rate() - 11.0 / 33.0).abs() < 1e-12);
    }
}
