//! Wire protocol parsing/rendering for the TCP front-end.

/// A parsed client command.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// `GEN <deadline_s> <eta>` — request one content generation.
    Gen { deadline_s: f64, eta: f64 },
    /// `STATS` — metrics snapshot.
    Stats,
    /// `QUIT` — close the connection.
    Quit,
}

/// A server response line.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Done { steps: u32, gen_ms: f64, tx_ms: f64, quality: f64 },
    Outage,
    Error(String),
}

impl Command {
    /// Render the canonical wire form; `parse_request(cmd.render())`
    /// returns `cmd` for every valid command (f64 `Display` is
    /// shortest-round-trip, so the floats survive exactly — pinned by
    /// `tests/protocol_fuzz.rs`).
    pub fn render(&self) -> String {
        match self {
            Command::Gen { deadline_s, eta } => format!("GEN {deadline_s} {eta}"),
            Command::Stats => "STATS".to_string(),
            Command::Quit => "QUIT".to_string(),
        }
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Command, String> {
    let mut parts = line.split_whitespace();
    match parts.next() {
        Some("GEN") => {
            let deadline_s: f64 = parts
                .next()
                .ok_or("GEN needs <deadline_s> <eta>")?
                .parse()
                .map_err(|_| "bad deadline".to_string())?;
            let eta: f64 = parts
                .next()
                .ok_or("GEN needs <deadline_s> <eta>")?
                .parse()
                .map_err(|_| "bad eta".to_string())?;
            if parts.next().is_some() {
                return Err("trailing arguments".into());
            }
            if !(deadline_s > 0.0) || !(eta > 0.0) {
                return Err("deadline and eta must be positive".into());
            }
            Ok(Command::Gen { deadline_s, eta })
        }
        Some("STATS") => Ok(Command::Stats),
        Some("QUIT") => Ok(Command::Quit),
        Some(other) => Err(format!("unknown command '{other}'")),
        None => Err("empty line".into()),
    }
}

/// Frame a metrics snapshot as the wire-level STATS reply: the body's
/// lines followed by a lone `.` terminator line (the framing
/// [`Client::stats`](super::Client::stats) reads up to).
pub fn render_stats_reply(body: &str) -> String {
    debug_assert!(body.is_empty() || body.ends_with('\n'), "body is newline-terminated lines");
    format!("{body}.\n")
}

/// Inverse of [`render_stats_reply`]: strip the terminator and return
/// the snapshot body. Errors if the terminator is missing or appears
/// early (a body line of `.` would truncate the client's read).
pub fn parse_stats_reply(reply: &str) -> Result<String, String> {
    let body = reply.strip_suffix(".\n").ok_or("STATS reply must end with a '.' terminator")?;
    if body.lines().any(|l| l.trim_end() == ".") {
        return Err("terminator line inside STATS body".to_string());
    }
    Ok(body.to_string())
}

impl Response {
    pub fn render(&self) -> String {
        match self {
            Response::Done { steps, gen_ms, tx_ms, quality } => {
                format!("DONE {steps} {gen_ms:.3} {tx_ms:.3} {quality:.4}")
            }
            Response::Outage => "OUTAGE".to_string(),
            Response::Error(msg) => format!("ERR {msg}"),
        }
    }

    pub fn parse(line: &str) -> Result<Response, String> {
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("DONE") => {
                let nums: Vec<&str> = parts.collect();
                if nums.len() != 4 {
                    return Err(format!("DONE expects 4 fields, got {}", nums.len()));
                }
                Ok(Response::Done {
                    steps: nums[0].parse().map_err(|_| "bad steps")?,
                    gen_ms: nums[1].parse().map_err(|_| "bad gen_ms")?,
                    tx_ms: nums[2].parse().map_err(|_| "bad tx_ms")?,
                    quality: nums[3].parse().map_err(|_| "bad quality")?,
                })
            }
            Some("OUTAGE") => Ok(Response::Outage),
            Some("ERR") => Ok(Response::Error(line[3..].trim().to_string())),
            _ => Err(format!("unparseable response '{line}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_gen() {
        assert_eq!(
            parse_request("GEN 10.5 7.25").unwrap(),
            Command::Gen { deadline_s: 10.5, eta: 7.25 }
        );
    }

    #[test]
    fn rejects_malformed_gen() {
        assert!(parse_request("GEN").is_err());
        assert!(parse_request("GEN 5").is_err());
        assert!(parse_request("GEN five six").is_err());
        assert!(parse_request("GEN 5 6 7").is_err());
        assert!(parse_request("GEN -1 5").is_err());
        assert!(parse_request("GEN 5 0").is_err());
    }

    #[test]
    fn parses_control_commands() {
        assert_eq!(parse_request("STATS").unwrap(), Command::Stats);
        assert_eq!(parse_request("QUIT").unwrap(), Command::Quit);
        assert!(parse_request("NOPE").is_err());
        assert!(parse_request("").is_err());
    }

    #[test]
    fn command_render_roundtrip() {
        for cmd in
            [Command::Gen { deadline_s: 10.25, eta: 7.5 }, Command::Stats, Command::Quit]
        {
            assert_eq!(parse_request(&cmd.render()).unwrap(), cmd);
        }
    }

    #[test]
    fn stats_reply_roundtrips_a_rendered_registry() {
        // The STATS body is Metrics::render_body — deterministic, no
        // wall clock — so the wire reply round-trips byte-for-byte.
        let m = crate::metrics::Metrics::default();
        m.inc("requests");
        m.add("tasks", 7);
        m.set_gauge("last_bucket", 8.0);
        m.record_latency("plan", 0.004);
        let body = m.render_body();
        let reply = render_stats_reply(&body);
        assert!(reply.ends_with(".\n"));
        assert_eq!(parse_stats_reply(&reply).unwrap(), body);
        // Empty registry: the reply is just the terminator.
        let empty = render_stats_reply("");
        assert_eq!(empty, ".\n");
        assert_eq!(parse_stats_reply(&empty).unwrap(), "");
        // Malformed replies are rejected, not mis-framed.
        assert!(parse_stats_reply("counter a: 1\n").is_err(), "missing terminator");
        assert!(parse_stats_reply(".\ncounter a: 1\n.\n").is_err(), "early terminator");
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Done { steps: 12, gen_ms: 345.678, tx_ms: 12.5, quality: 31.4159 };
        let parsed = Response::parse(&r.render()).unwrap();
        match parsed {
            Response::Done { steps, gen_ms, tx_ms, quality } => {
                assert_eq!(steps, 12);
                assert!((gen_ms - 345.678).abs() < 1e-3);
                assert!((tx_ms - 12.5).abs() < 1e-3);
                assert!((quality - 31.4159).abs() < 1e-3);
            }
            _ => panic!(),
        }
        assert_eq!(Response::parse("OUTAGE").unwrap(), Response::Outage);
        assert!(matches!(Response::parse("ERR boom").unwrap(), Response::Error(_)));
        assert!(Response::parse("GARBAGE").is_err());
    }
}
