//! TCP front-end: a line-oriented protocol for submitting AIGC requests
//! to the serving engine (std::net — the vendored crate set has no
//! tokio; one OS thread per connection plus a single GPU-worker thread
//! matches the paper's single-shared-model topology anyway).
//!
//! Protocol (one request per line, UTF-8):
//!   `GEN <deadline_s> <eta_bits_per_s_per_hz>`  → queued for the next
//!        epoch; response `DONE <steps> <gen_ms> <tx_ms> <quality>` once
//!        the epoch executes (or `OUTAGE` if infeasible).
//!   `STATS` → multi-line metrics snapshot terminated by `.`.
//!   `QUIT`  → closes the connection.

pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

pub use protocol::{parse_request, parse_stats_reply, render_stats_reply, Command, Response};

use crate::bandwidth::PsoAllocator;
use crate::channel::Link;
use crate::config::ExperimentConfig;
use crate::coordinator::{Engine, EngineConfig, EpochPolicy};
use crate::metrics::window::ServiceWindows;
use crate::quality::{PowerLawQuality, QualityModel};
use crate::runtime::ArtifactStore;
use crate::scheduler::Stacking;
use crate::trace::{DeviceRequest, Workload};

/// One queued request with its reply channel.
struct Pending {
    deadline: f64,
    eta: f64,
    reply: Sender<Response>,
}

/// Server handle: spawned threads stop when dropped (best effort).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handle: Option<std::thread::JoinHandle<()>>,
}

/// Epoching policy: the worker drains the queue every `epoch_ms` (or as
/// soon as `max_batch` requests are waiting).
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub epoch_ms: u64,
    pub max_batch: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { epoch_ms: 200, max_batch: 32 }
    }
}

impl ServerConfig {
    /// The epoch-closing rule, shared verbatim with `sim::dynamic`.
    pub fn policy(&self) -> EpochPolicy {
        EpochPolicy::from_millis(self.epoch_ms, self.max_batch)
    }
}

/// Start the server on `addr` (use port 0 for an ephemeral port).
///
/// The PJRT client is not `Send` (`Rc` internals), so the
/// [`ArtifactStore`] is created *inside* the GPU-worker thread from
/// `artifacts_dir`; compilation happens once at worker startup.
pub fn serve(
    artifacts_dir: std::path::PathBuf,
    cfg: ExperimentConfig,
    server_cfg: ServerConfig,
    addr: &str,
) -> Result<Server> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));

    let (queue_tx, queue_rx) = channel::<Pending>();
    let metrics_text = Arc::new(Mutex::new(String::new()));

    // ---- GPU worker: owns the PJRT store, drains the queue into epochs ----
    let worker_stop = stop.clone();
    let worker_metrics = metrics_text.clone();
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let worker = std::thread::Builder::new()
        .name("gpu-worker".into())
        .spawn(move || {
            let store = match ArtifactStore::load(&artifacts_dir) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            gpu_worker(&store, cfg, server_cfg, queue_rx, worker_stop, worker_metrics)
        })
        .context("spawn worker")?;
    ready_rx
        .recv_timeout(Duration::from_secs(120))
        .context("worker startup timeout")?
        .context("loading artifacts")?;

    // ---- acceptor ----
    let accept_stop = stop.clone();
    let acceptor = std::thread::Builder::new()
        .name("acceptor".into())
        .spawn(move || {
            while !accept_stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = queue_tx.clone();
                        let metrics = metrics_text.clone();
                        let _ = std::thread::Builder::new()
                            .name("conn".into())
                            .spawn(move || handle_conn(stream, tx, metrics));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        })
        .context("spawn acceptor")?;

    Ok(Server { addr: local, stop, accept_handle: Some(acceptor), worker_handle: Some(worker) })
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.worker_handle.take() {
            let _ = h.join();
        }
    }
}

fn gpu_worker(
    store: &ArtifactStore,
    cfg: ExperimentConfig,
    server_cfg: ServerConfig,
    queue: Receiver<Pending>,
    stop: Arc<AtomicBool>,
    metrics_text: Arc<Mutex<String>>,
) {
    let mut engine = Engine::new(store, EngineConfig::default());
    let quality = PowerLawQuality::paper();
    let scheduler = Stacking::default();
    let allocator = PsoAllocator::default();
    let policy = server_cfg.policy();
    // Live telemetry over the trailing minute — the same window
    // definitions the simulators report, surfaced as gauges in STATS.
    let mut windows = ServiceWindows::new(60.0);
    let started = std::time::Instant::now();
    while !stop.load(Ordering::Relaxed) {
        // Collect an epoch under the shared closing rule. The epoch
        // opens at the FIRST request (same as sim::dynamic), not at
        // collection start — otherwise a request arriving after an
        // idle stretch would close its epoch immediately, unbatched.
        let mut epoch: Vec<Pending> = Vec::new();
        let mut opened = std::time::Instant::now();
        loop {
            let open_for = opened.elapsed().as_secs_f64();
            if policy.should_close(epoch.len(), open_for) {
                break;
            }
            let timeout = if epoch.is_empty() {
                // Nothing queued: poll so `stop` is observed promptly.
                Duration::from_millis(50)
            } else {
                Duration::from_secs_f64((policy.epoch_s - open_for).max(1e-4))
            };
            match queue.recv_timeout(timeout) {
                Ok(p) => {
                    if epoch.is_empty() {
                        opened = std::time::Instant::now();
                    }
                    windows.record_arrival(started.elapsed().as_secs_f64());
                    epoch.push(p);
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if !epoch.is_empty() {
                        break;
                    }
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        if epoch.is_empty() {
            continue;
        }
        // Build a workload from the epoch's requests.
        let devices: Vec<DeviceRequest> = epoch
            .iter()
            .enumerate()
            .map(|(i, p)| DeviceRequest {
                id: i,
                deadline: p.deadline,
                link: Link::new(p.eta),
            })
            .collect();
        let workload = Workload {
            devices,
            total_bandwidth_hz: cfg.scenario.total_bandwidth_hz,
            content_bits: cfg.scenario.content_bits,
        };
        match engine.serve_epoch(&workload, &scheduler, &allocator, &quality) {
            Ok(report) => {
                let now = started.elapsed().as_secs_f64();
                for (pending, req) in epoch.iter().zip(&report.requests) {
                    let resp = if req.steps == 0 {
                        windows.record_dropped(now, quality.outage());
                        Response::Outage
                    } else {
                        let e2e = req.planned_gen_s + req.tx_s;
                        let met = e2e <= pending.deadline;
                        windows.record_served(now, e2e, req.predicted_quality, met);
                        Response::Done {
                            steps: req.steps,
                            gen_ms: req.planned_gen_s * 1e3,
                            tx_ms: req.tx_s * 1e3,
                            quality: req.predicted_quality,
                        }
                    };
                    let _ = pending.reply.send(resp);
                }
                windows.prune(now);
                engine.metrics.set_gauge("epoch_batch", epoch.len() as f64);
                engine.metrics.set_gauge("window_arrival_hz", windows.arrivals.rate_hz());
                engine.metrics.set_gauge("window_outage_rate", windows.outage_rate());
                engine.metrics.set_gauge("window_quality_mean", windows.quality.mean());
                engine.metrics.set_gauge("window_e2e_p95_s", windows.e2e_s.percentile(95.0));
                *metrics_text.lock().unwrap() = engine.metrics.render();
            }
            Err(e) => {
                for pending in &epoch {
                    let _ = pending.reply.send(Response::Error(format!("{e:#}")));
                }
            }
        }
    }
}

fn handle_conn(stream: TcpStream, queue: Sender<Pending>, metrics_text: Arc<Mutex<String>>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        match parse_request(&line) {
            Ok(Command::Gen { deadline_s, eta }) => {
                let (tx, rx) = channel();
                if queue.send(Pending { deadline: deadline_s, eta, reply: tx }).is_err() {
                    let _ = writeln!(writer, "ERR server shutting down");
                    break;
                }
                match rx.recv_timeout(Duration::from_secs(120)) {
                    Ok(resp) => {
                        let _ = writeln!(writer, "{}", resp.render());
                    }
                    Err(_) => {
                        let _ = writeln!(writer, "ERR timeout");
                    }
                }
            }
            Ok(Command::Stats) => {
                let snapshot = metrics_text.lock().unwrap().clone();
                let _ = write!(writer, "{}", protocol::render_stats_reply(&snapshot));
            }
            Ok(Command::Quit) => break,
            Err(msg) => {
                let _ = writeln!(writer, "ERR {msg}");
            }
        }
    }
}

/// Blocking client for the line protocol (used by examples and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Submit a generation request and wait for the epoch to serve it.
    pub fn generate(&mut self, deadline_s: f64, eta: f64) -> Result<Response> {
        writeln!(self.writer, "GEN {deadline_s} {eta}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Response::parse(line.trim()).map_err(|e| anyhow::anyhow!(e))
    }

    pub fn stats(&mut self) -> Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 || line.trim() == "." {
                break;
            }
            out.push_str(&line);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full server round-trips live in rust/tests/server_e2e.rs (they
    // need the compiled artifacts); protocol-only tests are in
    // protocol.rs.

    #[test]
    fn server_config_defaults_sane() {
        let cfg = ServerConfig::default();
        assert!(cfg.epoch_ms >= 10);
        assert!(cfg.max_batch >= 1);
    }
}
