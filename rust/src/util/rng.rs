//! PCG-XSH-RR 64/32-based PRNG (O'Neill, 2014) with convenience
//! distributions. Deterministic, seedable, `no_std`-simple — every
//! stochastic component in the system (workloads, channels, PSO) draws
//! from this so that whole experiments replay bit-identically.

/// A 64-bit-state PCG generator producing 32-bit outputs, combined in
/// pairs for 64-bit values.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached second draw dropped for
    /// simplicity; callers needing bulk normals should loop).
    pub fn normal(&mut self) -> f64 {
        // Avoid u == 0 so ln is finite.
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Exponential with the given rate λ.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.uniform()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for per-component streams).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64(), self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 450.0, "counts={counts:?}");
        }
    }

    #[test]
    fn int_in_inclusive() {
        let mut rng = Pcg64::seeded(4);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            let v = rng.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seeded(6);
        let n = 50_000;
        let rate = 2.5;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(8);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seeded(9);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
