//! Descriptive statistics for benchmark reporting and fitting.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance; 0.0 for fewer than two points.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (hot paths keep data sorted).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Minimum; NaN-free input assumed.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford) — used by metrics counters
/// so the serving hot path never buffers samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// One Greenwald–Khanna summary tuple: value `v` covers `g` samples
/// ending at the running rank, with `delta` extra rank slack.
#[derive(Debug, Clone, Copy)]
struct GkTuple {
    v: f64,
    g: u64,
    delta: u64,
}

/// Deterministic Greenwald–Khanna streaming quantile sketch.
///
/// Answers any quantile query with rank error at most `⌈eps · n⌉`
/// while retaining O((1/eps) · log(eps · n)) values — independent of
/// the stream length, which is what makes 10⁷-request sweeps possible
/// without materializing per-request vectors. Inserts are buffered and
/// folded into the summary in sorted batches; every operation is a
/// pure function of the insert sequence (no randomness, no clocks), so
/// whole experiments replay bit-identically at any thread count.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    eps: f64,
    /// Samples folded into `entries` (excludes the pending buffer).
    n: u64,
    /// Summary tuples, sorted by value.
    entries: Vec<GkTuple>,
    /// Pending inserts, folded in sorted batches of `buffer_cap`.
    buffer: Vec<f64>,
    buffer_cap: usize,
}

impl QuantileSketch {
    /// `eps` is the rank-error fraction, in `(0, 0.5)`.
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 0.5, "sketch eps must be in (0, 0.5), got {eps}");
        let buffer_cap = ((0.5 / eps).ceil() as usize).max(16);
        Self { eps, n: 0, entries: Vec::new(), buffer: Vec::with_capacity(buffer_cap), buffer_cap }
    }

    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Total samples inserted so far.
    pub fn count(&self) -> u64 {
        self.n + self.buffer.len() as u64
    }

    /// Values currently retained (summary tuples + pending buffer) —
    /// the sketch's entire memory footprint, bounded by
    /// O((1/eps) · log(eps · n)).
    pub fn support_len(&self) -> usize {
        self.entries.len() + self.buffer.len()
    }

    pub fn insert(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "sketch insert of non-finite {x}");
        self.buffer.push(x);
        if self.buffer.len() >= self.buffer_cap {
            self.flush();
        }
    }

    /// Quantile estimate for `p` in `[0, 100]` (percentile convention,
    /// matching [`percentile`]). Returns an actual inserted value whose
    /// rank is within `⌈eps · n⌉` of the target rank; 0.0 when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let tuples = self.merged_view();
        let p = p.clamp(0.0, 100.0);
        let target = ((p / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let err = (self.eps * total as f64).floor() as u64;
        let mut min_rank = 0u64;
        // Track the last tuple admissible by the upper rank bound: if no
        // tuple satisfies *both* bounds (possible for low-p queries over
        // wide-delta summaries), it is the closest-from-below answer.
        // Falling through to `tuples.last()` — the stream maximum — was
        // the worst possible answer for exactly those queries.
        let mut admissible: Option<f64> = None;
        for t in &tuples {
            min_rank += t.g;
            let max_rank = min_rank + t.delta;
            if max_rank <= target + err {
                admissible = Some(t.v);
                if target <= min_rank + err {
                    return t.v;
                }
            }
        }
        admissible.unwrap_or(tuples[0].v)
    }

    /// Lower/upper bounds on the number of inserted samples `≤ x`.
    /// Used to combine per-server sketches into fleet quantiles.
    pub fn rank_bounds(&self, x: f64) -> (u64, u64) {
        let tuples = self.merged_view();
        let total = self.count();
        let mut min_rank = 0u64;
        for t in &tuples {
            if t.v <= x {
                min_rank += t.g;
            } else {
                let upper = (min_rank + t.g + t.delta).saturating_sub(1);
                return (min_rank, upper.max(min_rank));
            }
        }
        (min_rank, total)
    }

    /// Combined quantile across independent sketches (per-server fleet
    /// summaries) without a lossy merge: walks every retained value and
    /// picks the candidate whose combined rank interval sits closest to
    /// the target rank. Rank error is at most `Σᵢ eps·nᵢ = eps · N`.
    pub fn combined_quantile(sketches: &[&QuantileSketch], p: f64) -> f64 {
        let total: u64 = sketches.iter().map(|s| s.count()).sum();
        if total == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let target = ((p / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let views: Vec<Vec<GkTuple>> = sketches.iter().map(|s| s.merged_view()).collect();
        let mut candidates: Vec<f64> = views.iter().flat_map(|v| v.iter().map(|t| t.v)).collect();
        candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        candidates.dedup();
        let m = candidates.len();
        let mut lower = vec![0u64; m];
        let mut upper = vec![0u64; m];
        for (view, s) in views.iter().zip(sketches) {
            let per_sketch_total = s.count();
            let mut i = 0;
            let mut min_rank = 0u64;
            for (c, &x) in candidates.iter().enumerate() {
                while i < view.len() && view[i].v <= x {
                    min_rank += view[i].g;
                    i += 1;
                }
                lower[c] += min_rank;
                upper[c] += if i < view.len() {
                    (min_rank + view[i].g + view[i].delta).saturating_sub(1).max(min_rank)
                } else {
                    per_sketch_total
                };
            }
        }
        let mut best = candidates[0];
        let mut best_gap = u64::MAX;
        for c in 0..m {
            let mid = (lower[c] + upper[c]) / 2;
            let gap = mid.abs_diff(target);
            if gap < best_gap {
                best_gap = gap;
                best = candidates[c];
            }
        }
        best
    }

    /// Fold the pending buffer into the summary and re-compress.
    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        self.buffer.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (merged, n) = merge_sorted(&self.entries, &self.buffer, self.eps, self.n);
        self.entries = merged;
        self.n = n;
        self.buffer.clear();
        self.compress();
    }

    /// The summary as it would look with the pending buffer folded in —
    /// lets queries borrow `&self` between flushes.
    fn merged_view(&self) -> Vec<GkTuple> {
        if self.buffer.is_empty() {
            return self.entries.clone();
        }
        let mut batch = self.buffer.clone();
        batch.sort_by(|a, b| a.partial_cmp(b).unwrap());
        merge_sorted(&self.entries, &batch, self.eps, self.n).0
    }

    /// Greedily fold tuples into their right neighbour while the merged
    /// tuple still fits the `2·eps·n` error budget. The first and last
    /// tuples are always kept so min/max stay exact.
    fn compress(&mut self) {
        if self.entries.len() < 3 {
            return;
        }
        let threshold = (2.0 * self.eps * self.n as f64).floor() as u64;
        let mut kept: Vec<GkTuple> = Vec::with_capacity(self.entries.len());
        let mut acc = *self.entries.last().unwrap();
        for i in (1..self.entries.len() - 1).rev() {
            let e = self.entries[i];
            if e.g + acc.g + acc.delta <= threshold {
                acc.g += e.g;
            } else {
                kept.push(acc);
                acc = e;
            }
        }
        kept.push(acc);
        kept.push(self.entries[0]);
        kept.reverse();
        self.entries = kept;
    }
}

/// Merge a sorted batch of raw samples into a sorted tuple summary,
/// assigning each new sample the standard GK insertion slack
/// (`⌊2·eps·n⌋ − 1` in the interior, 0 at the extremes).
fn merge_sorted(entries: &[GkTuple], batch: &[f64], eps: f64, mut n: u64) -> (Vec<GkTuple>, u64) {
    let mut merged = Vec::with_capacity(entries.len() + batch.len());
    let mut i = 0;
    for &x in batch {
        while i < entries.len() && entries[i].v <= x {
            merged.push(entries[i]);
            i += 1;
        }
        n += 1;
        let delta = if merged.is_empty() || i == entries.len() {
            0
        } else {
            ((2.0 * eps * n as f64).floor() as u64).saturating_sub(1)
        };
        merged.push(GkTuple { v: x, g: 1, delta });
    }
    merged.extend_from_slice(&entries[i..]);
    (merged, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn mean_variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!(approx_eq(mean(&xs), 5.0, 1e-12));
        assert!(approx_eq(variance(&xs), 32.0 / 7.0, 1e-12));
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(approx_eq(percentile(&xs, 0.0), 1.0, 1e-12));
        assert!(approx_eq(percentile(&xs, 100.0), 4.0, 1e-12));
        assert!(approx_eq(percentile(&xs, 50.0), 2.5, 1e-12));
        // unsorted input must work too
        let ys = [4.0, 1.0, 3.0, 2.0];
        assert!(approx_eq(percentile(&ys, 50.0), 2.5, 1e-12));
    }

    #[test]
    fn percentile_clamps_out_of_range() {
        let xs = [1.0, 2.0];
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, 200.0), 2.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.5, 2.5, 3.5, 10.0, -4.0, 0.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!(approx_eq(w.mean(), mean(&xs), 1e-12));
        assert!(approx_eq(w.variance(), variance(&xs), 1e-12));
        assert_eq!(w.count(), xs.len() as u64);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.0);
    }

    /// Rank of `x` in `sorted` (number of samples ≤ x).
    fn rank_of(sorted: &[f64], x: f64) -> i64 {
        sorted.iter().filter(|&&v| v <= x).count() as i64
    }

    fn assert_within_rank_bound(sorted: &[f64], sketch: &QuantileSketch, p: f64, tag: &str) {
        let n = sorted.len() as f64;
        let target = (p / 100.0 * n).ceil().max(1.0) as i64;
        let err = (sketch.eps() * n).ceil() as i64 + 1;
        let got = sketch.quantile(p);
        let r = rank_of(sorted, got);
        assert!(
            (r - target).abs() <= err,
            "{tag}: p={p} rank {r} vs target {target} (err budget {err}, value {got})"
        );
    }

    #[test]
    fn sketch_is_exact_below_error_threshold() {
        let mut s = QuantileSketch::new(0.05);
        for x in 1..=10 {
            s.insert(x as f64);
        }
        assert_eq!(s.count(), 10);
        // target rank for p=50 over 10 items is ⌈5⌉ = 5 → value 5.0
        assert_eq!(s.quantile(50.0), 5.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(100.0), 10.0);
    }

    #[test]
    fn sketch_empty_returns_zero() {
        let s = QuantileSketch::new(0.01);
        assert_eq!(s.quantile(50.0), 0.0);
        assert_eq!(s.count(), 0);
        assert_eq!(QuantileSketch::combined_quantile(&[], 50.0), 0.0);
    }

    #[test]
    fn sketch_tracks_exact_within_eps() {
        let eps = 0.01;
        let n = 20_000;
        let mut rng = crate::util::rng::Pcg64::seeded(41);
        // uniform, heavy-tailed, and bimodal streams
        let uniform: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.0, 10.0)).collect();
        let exponential: Vec<f64> = (0..n).map(|_| rng.exponential(0.8)).collect();
        let bimodal: Vec<f64> = (0..n)
            .map(|_| {
                if rng.uniform() < 0.3 {
                    rng.uniform_in(0.0, 1.0)
                } else {
                    rng.uniform_in(50.0, 60.0)
                }
            })
            .collect();
        let streams = [("uniform", uniform), ("exponential", exponential), ("bimodal", bimodal)];
        for (name, xs) in &streams {
            let mut sketch = QuantileSketch::new(eps);
            for &x in xs {
                sketch.insert(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for p in [1.0, 5.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9] {
                assert_within_rank_bound(&sorted, &sketch, p, name);
            }
        }
    }

    #[test]
    fn sketch_support_stays_logarithmic() {
        let eps = 0.01;
        let n = 200_000u64;
        let mut rng = crate::util::rng::Pcg64::seeded(17);
        let mut sketch = QuantileSketch::new(eps);
        for _ in 0..n {
            sketch.insert(rng.exponential(1.0));
        }
        let bound = (12.0 / eps * (2.0 * eps * n as f64 + 4.0).log2()).ceil() as usize + 64;
        assert!(
            sketch.support_len() <= bound,
            "support {} exceeds O((1/eps)·log(eps·n)) bound {bound}",
            sketch.support_len()
        );
    }

    #[test]
    fn sketch_replays_bit_identically() {
        let mut rng = crate::util::rng::Pcg64::seeded(23);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let run = || {
            let mut s = QuantileSketch::new(0.02);
            for &x in &xs {
                s.insert(x);
            }
            [50.0, 95.0, 99.0].map(|p| s.quantile(p).to_bits())
        };
        assert_eq!(run(), run());
    }

    /// Regression: when no summary tuple satisfied both rank bounds the
    /// query fell through to the stream MAXIMUM — for a p→0 query the
    /// worst possible answer. A degenerate all-wide-delta summary (no
    /// admissible tuple at all) must return the minimum, never the max.
    #[test]
    fn sketch_low_p_fallthrough_returns_minimum_not_maximum() {
        let entries: Vec<GkTuple> =
            (1..=10).map(|i| GkTuple { v: i as f64, g: 10, delta: 15 }).collect();
        let sketch =
            QuantileSketch { eps: 0.1, n: 100, entries, buffer: Vec::new(), buffer_cap: 16 };
        // err = 10; every tuple has max_rank >= 25 > target + err for
        // p = 1 (target 1), so nothing is admissible by the upper bound.
        for p in [0.0, 1.0, 5.0] {
            let got = sketch.quantile(p);
            assert_eq!(got, 1.0, "p={p} must answer from the low end, got {got}");
        }
    }

    /// Property: over randomized adversarial-but-valid GK summaries
    /// (first/last tuples exact, every tuple within the `2·eps·n`
    /// invariant), the distance from the target rank to the returned
    /// tuple's rank interval never exceeds ⌈eps·n⌉ (+1 floor slack) —
    /// including the low-p queries that used to fall through.
    #[test]
    fn sketch_rank_error_bounded_on_adversarial_summaries() {
        let mut rng = crate::util::rng::Pcg64::seeded(97);
        for case in 0..300usize {
            let eps = [0.02, 0.05, 0.1][case % 3];
            let m = 3 + rng.below(40) as usize;
            let gs: Vec<u64> =
                (0..m).map(|i| if i == 0 { 1 } else { 1 + rng.below(12) }).collect();
            let n: u64 = gs.iter().sum();
            let slack = (2.0 * eps * n as f64).floor() as u64;
            let mut v = 0.0;
            let mut entries = Vec::with_capacity(m);
            for (i, &g) in gs.iter().enumerate() {
                v += 1.0 + 3.0 * rng.uniform();
                let delta = if i == 0 || i + 1 == m {
                    0 // extremes are exact, as in every organic summary
                } else {
                    rng.below(slack.saturating_sub(g) + 1)
                };
                entries.push(GkTuple { v, g, delta });
            }
            let sketch = QuantileSketch {
                eps,
                n,
                entries: entries.clone(),
                buffer: Vec::new(),
                buffer_cap: 16,
            };
            let budget = (eps * n as f64).ceil() as u64 + 1;
            for p in [0.1, 1.0, 5.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
                let got = sketch.quantile(p);
                let target = ((p / 100.0 * n as f64).ceil() as u64).clamp(1, n);
                let mut min_rank = 0u64;
                let mut interval = None;
                for t in &entries {
                    min_rank += t.g;
                    if t.v == got {
                        interval = Some((min_rank, min_rank + t.delta));
                        break;
                    }
                }
                let (lo, hi) = interval.expect("query must return a retained value");
                let dist = if target < lo {
                    lo - target
                } else {
                    target.saturating_sub(hi)
                };
                assert!(
                    dist <= budget,
                    "case {case} p={p}: rank interval [{lo}, {hi}] vs target {target} \
                     (budget {budget}, n={n}, eps={eps})"
                );
            }
        }
    }

    #[test]
    fn combined_quantile_matches_pooled_exact() {
        let eps = 0.01;
        let mut rng = crate::util::rng::Pcg64::seeded(31);
        let mut a = QuantileSketch::new(eps);
        let mut b = QuantileSketch::new(eps);
        let mut pooled = Vec::new();
        for i in 0..30_000 {
            let x = rng.exponential(0.5);
            if i % 3 == 0 {
                a.insert(x);
            } else {
                b.insert(x);
            }
            pooled.push(x);
        }
        pooled.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let n = pooled.len() as f64;
        for p in [10.0, 50.0, 95.0, 99.0] {
            let got = QuantileSketch::combined_quantile(&[&a, &b], p);
            let target = (p / 100.0 * n).ceil().max(1.0) as i64;
            // combined rank error ≤ eps·N, plus interval-midpoint slack
            let err = (2.0 * eps * n).ceil() as i64 + 2;
            let r = rank_of(&pooled, got);
            assert!((r - target).abs() <= err, "p={p} rank {r} target {target} err {err}");
        }
    }
}
