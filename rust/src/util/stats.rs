//! Descriptive statistics for benchmark reporting and fitting.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance; 0.0 for fewer than two points.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (hot paths keep data sorted).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Minimum; NaN-free input assumed.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford) — used by metrics counters
/// so the serving hot path never buffers samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn mean_variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!(approx_eq(mean(&xs), 5.0, 1e-12));
        assert!(approx_eq(variance(&xs), 32.0 / 7.0, 1e-12));
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(approx_eq(percentile(&xs, 0.0), 1.0, 1e-12));
        assert!(approx_eq(percentile(&xs, 100.0), 4.0, 1e-12));
        assert!(approx_eq(percentile(&xs, 50.0), 2.5, 1e-12));
        // unsorted input must work too
        let ys = [4.0, 1.0, 3.0, 2.0];
        assert!(approx_eq(percentile(&ys, 50.0), 2.5, 1e-12));
    }

    #[test]
    fn percentile_clamps_out_of_range() {
        let xs = [1.0, 2.0];
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, 200.0), 2.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.5, 2.5, 3.5, 10.0, -4.0, 0.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!(approx_eq(w.mean(), mean(&xs), 1e-12));
        assert!(approx_eq(w.variance(), variance(&xs), 1e-12));
        assert_eq!(w.count(), xs.len() as u64);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.0);
    }
}
