//! Foundation utilities shared across the stack.
//!
//! The offline vendored crate set has no `rand`, `serde`, `proptest` or
//! `nalgebra`, so this module provides the small, well-tested pieces the
//! rest of the system needs: a PCG PRNG, descriptive statistics,
//! least-squares fitting (linear and power-law — the two fits in the
//! paper's Fig. 1), a minimal JSON parser for the artifact manifests, a
//! symmetric eigensolver for Fréchet-distance checks, a tiny
//! property-testing harness, and the deterministic parallel-map fabric
//! (`exec`) the hot loops fan out through.

pub mod exec;
pub mod fit;
pub mod json;
pub mod linalg;
pub mod prop;
pub mod rng;
pub mod stats;

pub use exec::{par_map, resolve_threads};
pub use fit::{fit_linear, fit_power_law, LinearFit, PowerLawFit};
pub use rng::Pcg64;

/// Relative/absolute float comparison used across tests.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    let diff = (a - b).abs();
    diff <= tol || diff <= tol * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_absolute_and_relative() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9));
        assert!(approx_eq(1e12, 1e12 * (1.0 + 1e-10), 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3));
        assert!(approx_eq(0.0, 0.0, 1e-12));
    }
}
