//! Least-squares fitting — the two model fits in the paper's Fig. 1.
//!
//! * [`fit_linear`]: `y = a·x + b`, the batch-delay model of Eq. (4)
//!   (Fig. 1a: a = 0.0240, b = 0.3543 on the authors' RTX 3050).
//! * [`fit_power_law`]: `y = c·x^(−d) + e`, the quality-vs-steps model
//!   (Fig. 1b). Linear in (c, e) for fixed d, so d is grid-searched —
//!   the same procedure `python/compile/calibrate.py` uses, kept in both
//!   languages so either side can re-fit measured curves.

/// Result of a linear fit `y = a·x + b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub a: f64,
    pub b: f64,
    /// Coefficient of determination on the training points.
    pub r2: f64,
}

/// Result of a power-law fit `y = c·x^(−d) + e`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLawFit {
    pub c: f64,
    pub d: f64,
    pub e: f64,
    pub r2: f64,
}

fn r_squared(ys: &[f64], preds: &[f64]) -> f64 {
    let n = ys.len() as f64;
    let mean = ys.iter().sum::<f64>() / n;
    let ss_tot: f64 = ys.iter().map(|y| (y - mean) * (y - mean)).sum();
    let ss_res: f64 = ys.iter().zip(preds).map(|(y, p)| (y - p) * (y - p)).sum();
    if ss_tot <= 0.0 {
        if ss_res <= 1e-24 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Ordinary least squares for `y = a·x + b`.
///
/// # Panics
/// Panics if fewer than two points or all x identical.
pub fn fit_linear(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear fit needs >= 2 points");
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    assert!(denom.abs() > 1e-12, "degenerate x values");
    let a = (n * sxy - sx * sy) / denom;
    let b = (sy - a * sx) / n;
    let preds: Vec<f64> = xs.iter().map(|x| a * x + b).collect();
    LinearFit { a, b, r2: r_squared(ys, &preds) }
}

/// Solve the 2×2 normal equations for `y ≈ c·basis + e`.
fn solve_c_e(basis: &[f64], ys: &[f64]) -> Option<(f64, f64)> {
    let n = basis.len() as f64;
    let sb: f64 = basis.iter().sum();
    let sbb: f64 = basis.iter().map(|b| b * b).sum();
    let sy: f64 = ys.iter().sum();
    let sby: f64 = basis.iter().zip(ys).map(|(b, y)| b * y).sum();
    let det = n * sbb - sb * sb;
    if det.abs() < 1e-12 {
        return None;
    }
    let c = (n * sby - sb * sy) / det;
    let e = (sy - c * sb) / n;
    Some((c, e))
}

/// Fit `y = c·x^(−d) + e` by grid-searching d and solving (c, e) exactly.
///
/// `xs` must be strictly positive.
pub fn fit_power_law(xs: &[f64], ys: &[f64]) -> PowerLawFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 3, "power-law fit needs >= 3 points");
    assert!(xs.iter().all(|&x| x > 0.0), "power-law fit needs x > 0");

    let mut best = PowerLawFit { c: 0.0, d: 1.0, e: 0.0, r2: f64::NEG_INFINITY };
    let mut best_sse = f64::INFINITY;
    let mut basis = vec![0.0; xs.len()];
    // Same grid as the python fitter: d ∈ [0.05, 4.0] step 0.01.
    let mut d = 0.05;
    while d <= 4.0 + 1e-9 {
        for (slot, &x) in basis.iter_mut().zip(xs) {
            *slot = x.powf(-d);
        }
        if let Some((c, e)) = solve_c_e(&basis, ys) {
            let sse: f64 = basis
                .iter()
                .zip(ys)
                .map(|(b, y)| {
                    let r = c * b + e - y;
                    r * r
                })
                .sum();
            if sse < best_sse {
                best_sse = sse;
                let preds: Vec<f64> = basis.iter().map(|b| c * b + e).collect();
                best = PowerLawFit { c, d, e, r2: r_squared(ys, &preds) };
            }
        }
        d += 0.01;
    }
    best
}

impl PowerLawFit {
    /// Evaluate the fitted curve at `x` (> 0).
    pub fn eval(&self, x: f64) -> f64 {
        self.c * x.powf(-self.d) + self.e
    }
}

impl LinearFit {
    /// Evaluate the fitted line at `x`.
    pub fn eval(&self, x: f64) -> f64 {
        self.a * x + self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn linear_exact_recovery() {
        let xs: Vec<f64> = (1..=32).map(|x| x as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.0240 * x + 0.3543).collect();
        let fit = fit_linear(&xs, &ys);
        assert!(approx_eq(fit.a, 0.0240, 1e-9));
        assert!(approx_eq(fit.b, 0.3543, 1e-9));
        assert!(fit.r2 > 0.999_999);
    }

    #[test]
    fn linear_noisy_r2_reasonable() {
        let mut rng = crate::util::Pcg64::seeded(1);
        let xs: Vec<f64> = (1..=64).map(|x| x as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0 + 0.1 * rng.normal()).collect();
        let fit = fit_linear(&xs, &ys);
        assert!(approx_eq(fit.a, 2.0, 1e-2));
        assert!(approx_eq(fit.b, 1.0, 0.1));
        assert!(fit.r2 > 0.999);
    }

    #[test]
    #[should_panic]
    fn linear_rejects_single_point() {
        fit_linear(&[1.0], &[2.0]);
    }

    #[test]
    #[should_panic]
    fn linear_rejects_degenerate_x() {
        fit_linear(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn power_law_exact_recovery() {
        // The paper-like curve: FID(T) = 300·T^-1.2 + 20.
        let xs: Vec<f64> = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48]
            .iter()
            .map(|&x| x as f64)
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 300.0 * x.powf(-1.2) + 20.0).collect();
        let fit = fit_power_law(&xs, &ys);
        assert!(approx_eq(fit.c, 300.0, 0.03), "{fit:?}");
        assert!(approx_eq(fit.d, 1.2, 0.02), "{fit:?}");
        assert!(approx_eq(fit.e, 20.0, 0.05), "{fit:?}");
        assert!(fit.r2 > 0.9999);
    }

    #[test]
    fn power_law_matches_python_fit() {
        // Cross-check against python/compile/calibrate.py on the measured
        // curve (values from artifacts/quality.json of the reference run).
        let ts: [f64; 15] =
            [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0, 16.0, 20.0, 24.0, 32.0, 40.0, 50.0];
        let c0 = 100.054;
        let d0 = 1.03;
        let e0 = 6.16;
        let qs: Vec<f64> = ts.iter().map(|t| c0 * t.powf(-d0) + e0).collect();
        let fit = fit_power_law(&ts, &qs);
        assert!(approx_eq(fit.c, c0, 0.02), "{fit:?}");
        assert!(approx_eq(fit.d, d0, 0.02), "{fit:?}");
        assert!(approx_eq(fit.e, e0, 0.05), "{fit:?}");
    }

    #[test]
    fn power_law_flat_curve_has_zero_c() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let fit = fit_power_law(&xs, &[5.0; 5]);
        assert!(fit.c.abs() < 1e-9, "{fit:?}");
        assert!(approx_eq(fit.e, 5.0, 1e-9));
    }

    #[test]
    fn eval_roundtrip() {
        let f = PowerLawFit { c: 10.0, d: 0.5, e: 1.0, r2: 1.0 };
        assert!(approx_eq(f.eval(4.0), 10.0 / 2.0 + 1.0, 1e-12));
        let l = LinearFit { a: 2.0, b: 3.0, r2: 1.0 };
        assert!(approx_eq(l.eval(5.0), 13.0, 1e-12));
    }
}
