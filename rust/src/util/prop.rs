//! A minimal property-testing harness (the vendored crate set has no
//! `proptest`; DESIGN.md §5).
//!
//! Usage (`no_run`: rustdoc test binaries don't inherit the cargo-config
//! rpath for libxla_extension; the same behaviour is exercised by the
//! unit tests below):
//! ```no_run
//! use aigc_edge::prop_assert;
//! use aigc_edge::util::prop::{forall, Gen};
//! forall("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     prop_assert!(g, a + b == b + a, "a={a} b={b}");
//!     true
//! });
//! ```
//!
//! On failure the harness reports the iteration index and the seed so a
//! failing case replays deterministically with `Gen::replay(seed)`.

use super::rng::Pcg64;

/// Random-input generator handed to each property iteration.
pub struct Gen {
    rng: Pcg64,
    /// Seed that reproduces this iteration exactly.
    pub seed: u64,
    failure: Option<String>,
}

impl Gen {
    pub fn replay(seed: u64) -> Self {
        Self { rng: Pcg64::seeded(seed), seed, failure: None }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.int_in(lo as i64, hi as i64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// A vector of `len` draws from `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Record a failure message (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `iters` iterations of `property`, each with a fresh deterministic
/// seed derived from the property name. Panics (test failure) on the
/// first falsified iteration, printing the replay seed.
pub fn forall(name: &str, iters: u32, mut property: impl FnMut(&mut Gen) -> bool) {
    // Derive a base seed from the name so distinct properties explore
    // distinct streams but remain stable across runs.
    let mut base: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        base ^= b as u64;
        base = base.wrapping_mul(0x100000001b3);
    }
    for i in 0..iters {
        let seed = base.wrapping_add((i as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen::replay(seed);
        let ok = property(&mut g);
        if !ok || g.failure.is_some() {
            let detail = g.failure.unwrap_or_else(|| "property returned false".into());
            panic!(
                "property '{name}' falsified at iteration {i} (replay seed {seed:#x}):\n  {detail}"
            );
        }
    }
}

/// Assert inside a property; records the message and fails the iteration.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)+) => {
        if !$cond {
            $g.fail(format!($($fmt)+));
            return false;
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("x*0 == 0", 100, |g| {
            let x = g.f64_in(-1e9, 1e9);
            x * 0.0 == 0.0
        });
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn fails_false_property() {
        forall("all u64 are even", 100, |g| g.u64() % 2 == 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall("collect", 10, |g| {
            first.push(g.u64());
            true
        });
        let mut second = Vec::new();
        forall("collect", 10, |g| {
            second.push(g.u64());
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn prop_assert_macro_records_message() {
        let result = std::panic::catch_unwind(|| {
            forall("macro check", 5, |g| {
                let v = g.usize_in(0, 10);
                prop_assert!(g, v <= 10, "v out of range: {v}");
                prop_assert!(g, v < 100, "unreachable");
                true
            });
        });
        assert!(result.is_ok());
    }
}
