//! Minimal JSON parser for the build-time artifact metadata
//! (`artifacts/manifest.json`, `artifacts/quality.json`).
//!
//! The vendored crate set has no `serde`, so this is a small
//! recursive-descent parser covering the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null). It is only
//! used at startup — never on the request path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, message: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: message.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected literal '{lit}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or '}'");
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or ']'");
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        // Surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(code)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or(JsonError {
                offset: self.pos,
                message: "truncated \\u escape".into(),
            })?;
            let d = (c as char).to_digit(16).ok_or(JsonError {
                offset: self.pos,
                message: "invalid hex digit".into(),
            })?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n = text
            .parse::<f64>()
            .map_err(|_| JsonError { offset: start, message: format!("bad number '{text}'") })?;
        // Overflowing literals like `1e999` parse to ±inf; JSON has no
        // representation for non-finite numbers, so reject them here
        // instead of letting inf/NaN leak into downstream arithmetic.
        if !n.is_finite() {
            return Err(JsonError {
                offset: start,
                message: format!("number '{text}' out of f64 range"),
            });
        }
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        // Bound by 2^53: the largest range where every integer is
        // exactly representable in f64. Beyond that (`1e20`, inf) the
        // value cannot faithfully round-trip and the `as usize` cast
        // would silently saturate.
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0;
        match self {
            Json::Num(n) if *n >= 0.0 && *n <= MAX_EXACT && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Fetch a required field, with a readable error.
    pub fn required<'a>(&'a self, key: &str) -> anyhow::Result<&'a Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    /// Serialize back to JSON text. Deterministic: object keys emit in
    /// `BTreeMap` order, numbers use Rust's shortest-roundtrip float
    /// formatting, and there is no insignificant whitespace — so
    /// `parse(render(v)) == v` and equal values render byte-identically
    /// (the bench-artifact merge in `benches/fig_serialization.rs`
    /// relies on both).
    ///
    /// # Panics
    /// On non-finite numbers: the parser refuses them, so a value
    /// holding inf/NaN was built by broken code and must not
    /// round-trip silently as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                assert!(n.is_finite(), "JSON cannot represent non-finite number {n}");
                out.push_str(&format!("{n}"));
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": true}, "e": "x"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"日本語\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "日本語");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("  [ ]  ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn real_manifest_roundtrip() {
        // Shape of the actual artifacts/manifest.json.
        let doc = r#"{
          "data_dim": 64, "buckets": [1, 2, 4],
          "hlo": {"1": {"file": "denoise_b1.hlo.txt", "bytes": 37372}},
          "io": {"tuple_output": true}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("data_dim").unwrap().as_usize(), Some(64));
        let buckets: Vec<usize> = v
            .get("buckets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.as_usize().unwrap())
            .collect();
        assert_eq!(buckets, vec![1, 2, 4]);
        assert_eq!(
            v.get("hlo").unwrap().get("1").unwrap().get("file").unwrap().as_str(),
            Some("denoise_b1.hlo.txt")
        );
        assert_eq!(v.get("io").unwrap().get("tuple_output").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn required_field_error_message() {
        let v = parse("{}").unwrap();
        let err = v.required("nope").unwrap_err();
        assert!(err.to_string().contains("nope"));
    }

    #[test]
    fn rejects_overflowing_number_literals() {
        // `1e999` parses to inf under `str::parse::<f64>`; the parser
        // must refuse it with a readable error instead of letting a
        // non-finite number leak into the document.
        for text in ["1e999", "-1e999", "[1, 1e999]", "{\"a\": -1e999}"] {
            let err = parse(text).unwrap_err();
            assert!(err.to_string().contains("out of f64 range"), "{text}: {err}");
        }
        // Large but finite literals still parse.
        assert_eq!(parse("1e20").unwrap(), Json::Num(1e20));
    }

    #[test]
    fn render_roundtrips_and_is_deterministic() {
        let doc = r#"{
          "z": [1, 2.5, -3e2, true, null, "a\nb\t\"c\""],
          "a": {"nested": {"k": 0.1}}, "empty_arr": [], "empty_obj": {}
        }"#;
        let v = parse(doc).unwrap();
        let text = v.render();
        // Round-trip preserves the value exactly...
        assert_eq!(parse(&text).unwrap(), v);
        // ...and rendering is a fixpoint (sorted keys, no whitespace).
        assert_eq!(parse(&text).unwrap().render(), text);
        assert!(text.starts_with("{\"a\":"), "keys sort: {text}");
        assert!(text.contains("\"empty_arr\":[]"), "{text}");
        assert!(text.contains("\"empty_obj\":{}"), "{text}");
        assert!(text.contains("-300"), "{text}");
    }

    #[test]
    fn render_escapes_and_float_bits_survive() {
        let v = Json::Str("quote \" slash \\ nl \n ctl \u{1}".into());
        assert_eq!(parse(&v.render()).unwrap(), v);
        // Shortest-roundtrip float formatting must reparse bit-exactly.
        for x in [0.1, 1.0 / 3.0, 6.02e23, -4.9e-14, 9_007_199_254_740_992.0] {
            let n = Json::Num(x);
            let back = parse(&n.render()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn render_refuses_non_finite_numbers() {
        Json::Num(f64::INFINITY).render();
    }

    #[test]
    fn as_usize_bounded_to_exact_integers() {
        // 1e20 is finite, non-negative and has fract() == 0, but is far
        // beyond 2^53 — `as usize` would not round-trip, so refuse it.
        assert_eq!(Json::Num(1e20).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(2.5).as_usize(), None);
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_usize(), Some(1 << 53));
        assert_eq!(Json::Num(64.0).as_usize(), Some(64));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
    }
}
