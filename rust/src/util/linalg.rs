//! Dense symmetric linear algebra for the Rust-side quality metric.
//!
//! The end-to-end example computes the Fréchet distance between the
//! moments of *actually served* generations and the target distribution
//! (the same metric `python/compile/calibrate.py` uses). That needs
//! `tr sqrt(Σ₁Σ₂)`, computed here via a cyclic Jacobi eigensolver — no
//! LAPACK in the vendored crate set.

/// A dense, row-major, square symmetric matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct SymMat {
    pub n: usize,
    pub data: Vec<f64>, // n * n, row-major
}

impl SymMat {
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n = rows.len();
        let mut data = Vec::with_capacity(n * n);
        for r in rows {
            assert_eq!(r.len(), n, "not square");
            data.extend_from_slice(r);
        }
        Self { n, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Matrix product (general, O(n³)).
    pub fn matmul(&self, other: &SymMat) -> SymMat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = SymMat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.data[i * n + j] += aik * other.get(k, j);
                }
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.get(i, i)).sum()
    }

    pub fn transpose(&self) -> SymMat {
        let n = self.n;
        let mut out = SymMat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Maximum absolute asymmetry |A - Aᵀ|∞ — sanity checks.
    pub fn asymmetry(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                worst = worst.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        worst
    }
}

/// Eigendecomposition of a symmetric matrix by the cyclic Jacobi method.
/// Returns (eigenvalues, eigenvectors-as-columns). O(n³) per sweep,
/// converges quadratically; fine for the d=64 moment matrices used here.
pub fn jacobi_eigh(a: &SymMat, max_sweeps: usize) -> (Vec<f64>, SymMat) {
    let n = a.n;
    let mut m = a.clone();
    let mut v = SymMat::identity(n);

    for _ in 0..max_sweeps {
        let mut off: f64 = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let eigvals: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    (eigvals, v)
}

/// Symmetric PSD matrix square root via eigendecomposition
/// (negative eigenvalues — numerical noise — are clamped to zero).
pub fn sym_sqrt(a: &SymMat) -> SymMat {
    let n = a.n;
    let (vals, vecs) = jacobi_eigh(a, 30);
    // sqrt = V diag(sqrt(λ)) Vᵀ
    let mut out = SymMat::zeros(n);
    for k in 0..n {
        let s = vals[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = vecs.get(i, k) * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.data[i * n + j] += vik * vecs.get(j, k);
            }
        }
    }
    out
}

/// Fréchet distance between two Gaussians (μ₁,Σ₁), (μ₂,Σ₂):
/// `FD² = ‖μ₁−μ₂‖² + tr(Σ₁+Σ₂−2·(Σ₁Σ₂)^{1/2})`.
/// Uses the symmetric factorization `tr sqrt(Σ₁Σ₂) = tr sqrt(S Σ₂ S)`
/// with `S = Σ₁^{1/2}`, so the Jacobi solver only ever sees symmetric
/// matrices.
pub fn frechet_distance(mu1: &[f64], cov1: &SymMat, mu2: &[f64], cov2: &SymMat) -> f64 {
    assert_eq!(mu1.len(), mu2.len());
    assert_eq!(cov1.n, mu1.len());
    assert_eq!(cov2.n, mu2.len());
    let diff2: f64 = mu1.iter().zip(mu2).map(|(a, b)| (a - b) * (a - b)).sum();
    let s = sym_sqrt(cov1);
    let inner = s.matmul(cov2).matmul(&s);
    let (vals, _) = jacobi_eigh(&inner, 30);
    let tr_sqrt: f64 = vals.iter().map(|&l| l.max(0.0).sqrt()).sum();
    let fd2 = diff2 + cov1.trace() + cov2.trace() - 2.0 * tr_sqrt;
    fd2.max(0.0).sqrt()
}

/// Sample mean and covariance (unbiased) of row-major samples.
pub fn sample_moments(samples: &[f64], dim: usize) -> (Vec<f64>, SymMat) {
    assert!(dim > 0 && samples.len() % dim == 0);
    let n = samples.len() / dim;
    assert!(n > 0);
    let mut mu = vec![0.0; dim];
    for row in samples.chunks_exact(dim) {
        for (m, x) in mu.iter_mut().zip(row) {
            *m += x;
        }
    }
    for m in &mut mu {
        *m /= n as f64;
    }
    let mut cov = SymMat::zeros(dim);
    let denom = if n > 1 { (n - 1) as f64 } else { 1.0 };
    for row in samples.chunks_exact(dim) {
        for i in 0..dim {
            let di = row[i] - mu[i];
            for j in i..dim {
                let dj = row[j] - mu[j];
                cov.data[i * dim + j] += di * dj / denom;
            }
        }
    }
    for i in 0..dim {
        for j in 0..i {
            cov.data[i * dim + j] = cov.data[j * dim + i];
        }
    }
    (mu, cov)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    fn diag(vals: &[f64]) -> SymMat {
        let mut m = SymMat::zeros(vals.len());
        for (i, &v) in vals.iter().enumerate() {
            m.set(i, i, v);
        }
        m
    }

    #[test]
    fn jacobi_diagonal_passthrough() {
        let m = diag(&[3.0, 1.0, 2.0]);
        let (mut vals, _) = jacobi_eigh(&m, 20);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(approx_eq(vals[0], 1.0, 1e-10));
        assert!(approx_eq(vals[1], 2.0, 1e-10));
        assert!(approx_eq(vals[2], 3.0, 1e-10));
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let m = SymMat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (mut vals, vecs) = jacobi_eigh(&m, 20);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(approx_eq(vals[0], 1.0, 1e-10));
        assert!(approx_eq(vals[1], 3.0, 1e-10));
        // eigenvectors orthonormal
        let vtv = vecs.transpose().matmul(&vecs);
        assert!(approx_eq(vtv.get(0, 0), 1.0, 1e-10));
        assert!(approx_eq(vtv.get(0, 1), 0.0, 1e-10));
    }

    #[test]
    fn jacobi_reconstructs_random_symmetric() {
        let mut rng = crate::util::Pcg64::seeded(11);
        let n = 12;
        let mut a = SymMat::zeros(n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        let (vals, vecs) = jacobi_eigh(&a, 30);
        // A ≈ V diag(vals) Vᵀ
        let mut recon = SymMat::zeros(n);
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    recon.data[i * n + j] += vals[k] * vecs.get(i, k) * vecs.get(j, k);
                }
            }
        }
        for i in 0..n * n {
            assert!(approx_eq(recon.data[i], a.data[i], 1e-8), "entry {i}");
        }
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = crate::util::Pcg64::seeded(12);
        let n = 8;
        // PSD: B Bᵀ + I
        let mut b = SymMat::zeros(n);
        for i in 0..n * n {
            b.data[i] = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.data[i * n + i] += 1.0;
        }
        let s = sym_sqrt(&a);
        let s2 = s.matmul(&s);
        for i in 0..n * n {
            assert!(approx_eq(s2.data[i], a.data[i], 1e-7), "entry {i}");
        }
    }

    #[test]
    fn frechet_identity_zero() {
        let mu = vec![1.0, -2.0, 0.5];
        let cov = diag(&[2.0, 1.0, 0.5]);
        assert!(frechet_distance(&mu, &cov, &mu, &cov) < 1e-7);
    }

    #[test]
    fn frechet_mean_shift_only() {
        let cov = SymMat::identity(4);
        let a = vec![0.0; 4];
        let b = vec![3.0, 0.0, 0.0, 0.0];
        assert!(approx_eq(frechet_distance(&a, &cov, &b, &cov), 3.0, 1e-9));
    }

    #[test]
    fn frechet_isotropic_closed_form() {
        // FD between N(0, s²I) and N(0, t²I) in dim d is √d·|s−t|.
        let d = 6;
        let (s, t) = (2.0, 0.5);
        let mut c1 = SymMat::identity(d);
        let mut c2 = SymMat::identity(d);
        for i in 0..d {
            c1.data[i * d + i] = s * s;
            c2.data[i * d + i] = t * t;
        }
        let z = vec![0.0; d];
        let fd = frechet_distance(&z, &c1, &z, &c2);
        assert!(approx_eq(fd, (d as f64).sqrt() * (s - t), 1e-9), "fd={fd}");
    }

    #[test]
    fn frechet_matches_python_on_crosscheck() {
        // Cross-language pin: computed by python/compile/calibrate.py's
        // frechet_distance for the same inputs.
        let mu1 = vec![0.0, 0.0];
        let mu2 = vec![1.0, 1.0];
        let c1 = SymMat::from_rows(&[vec![1.0, 0.3], vec![0.3, 2.0]]);
        let c2 = SymMat::from_rows(&[vec![0.5, -0.1], vec![-0.1, 1.5]]);
        let fd = frechet_distance(&mu1, &c1, &mu2, &c2);
        // value computed with python/compile/calibrate.py frechet_distance
        assert!(approx_eq(fd, 1.475_129_079_168, 1e-6), "fd={fd}");
    }

    #[test]
    fn moments_of_constant_rows() {
        let dim = 3;
        let samples = [1.0, 2.0, 3.0, 1.0, 2.0, 3.0];
        let (mu, cov) = sample_moments(&samples, dim);
        assert_eq!(mu, vec![1.0, 2.0, 3.0]);
        assert!(cov.data.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn moments_match_known_distribution() {
        let mut rng = crate::util::Pcg64::seeded(13);
        let dim = 4;
        let n = 40_000;
        let mut samples = Vec::with_capacity(n * dim);
        for _ in 0..n {
            for j in 0..dim {
                samples.push(3.0 + (j as f64 + 1.0) * rng.normal());
            }
        }
        let (mu, cov) = sample_moments(&samples, dim);
        for j in 0..dim {
            assert!(approx_eq(mu[j], 3.0, 0.06), "mu[{j}]={}", mu[j]);
            let var = (j as f64 + 1.0) * (j as f64 + 1.0);
            assert!((cov.get(j, j) - var).abs() < 0.25 * var, "cov[{j}][{j}]");
        }
    }
}
