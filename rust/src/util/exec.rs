//! Deterministic parallel execution — the vendored-dependency-free
//! fan-out fabric behind the hot loops (PSO particle fitness,
//! per-server epoch solves, bench sweep cells).
//!
//! The whole system is built on bit-identical replay, so the fabric's
//! contract is strict: [`par_map`] is an *order-preserving* chunked map
//! over [`std::thread::scope`] whose output is bit-identical to the
//! serial `items.iter().map(f)` at **any** thread count — each item is
//! mapped exactly once from an immutable reference and written back by
//! index, so scheduling can reorder the *work* but never the *result*.
//! Callers therefore treat `threads` as a pure performance knob
//! (`tests/exec_determinism.rs` pins this across every engine).
//!
//! `threads == 0` means "auto": use [`std::thread::available_parallelism`].
//! `threads == 1` (or ≤ 1 item) degenerates to a plain serial map with
//! no thread spawned at all.

use std::num::NonZeroUsize;

/// Resolve a `threads` knob: `0` = auto-detect from
/// [`std::thread::available_parallelism`] (1 if detection fails),
/// anything else is taken literally.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
    } else {
        threads
    }
}

/// Order-preserving parallel map: `par_map(t, items, f)[i] == f(i, &items[i])`
/// for every `i`, at every thread count `t` (0 = auto).
///
/// Work is split into contiguous chunks, one scoped worker thread per
/// chunk; a panicking `f` propagates out of the scope join, exactly as
/// it would from the serial loop. `f` must be pure with respect to the
/// item it is given (it runs once per item, but on an unspecified
/// thread and in an unspecified order across chunks).
pub fn par_map<T, U, F>(threads: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let workers = resolve_threads(threads).min(items.len()).max(1);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let mut out: Vec<Option<U>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let chunk = items.len().div_ceil(workers);
    std::thread::scope(|scope| {
        for (w, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let base = w * chunk;
            let f = &f;
            scope.spawn(move || {
                for (j, slot) in out_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + j, &items[base + j]));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order_at_every_thread_count() {
        let items: Vec<u64> = (0..257).collect();
        let serial: Vec<u64> = items.iter().enumerate().map(|(i, x)| x * 3 + i as u64).collect();
        for threads in [0, 1, 2, 3, 8, 64] {
            let got = par_map(threads, &items, |i, x| x * 3 + i as u64);
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let items: Vec<u32> = Vec::new();
        let out: Vec<u32> = par_map(8, &items, |_, x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn threads_one_degenerates_to_a_plain_map_on_the_calling_thread() {
        let caller = std::thread::current().id();
        let items = vec![1u32, 2, 3, 4];
        let out = par_map(1, &items, |_, x| {
            assert_eq!(std::thread::current().id(), caller, "threads=1 must not spawn");
            x + 1
        });
        assert_eq!(out, vec![2, 3, 4, 5]);
    }

    #[test]
    fn single_item_never_spawns() {
        let caller = std::thread::current().id();
        let items = vec![7u32];
        let out = par_map(0, &items, |_, x| {
            assert_eq!(std::thread::current().id(), caller);
            *x
        });
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn every_item_mapped_exactly_once() {
        let items: Vec<usize> = (0..100).collect();
        let calls = AtomicUsize::new(0);
        let out = par_map(4, &items, |i, &x| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(i, x);
            x
        });
        assert_eq!(out.len(), 100);
        assert_eq!(calls.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn worker_panic_propagates_to_the_caller() {
        let items: Vec<u32> = (0..64).collect();
        let result = std::panic::catch_unwind(|| {
            par_map(4, &items, |_, &x| {
                if x == 37 {
                    panic!("worker panic for item {x}");
                }
                x
            })
        });
        assert!(result.is_err(), "a panicking worker must fail the whole map");
    }

    #[test]
    fn serial_panic_also_propagates() {
        let items = vec![0u32, 1];
        let result = std::panic::catch_unwind(|| {
            par_map(1, &items, |_, &x| {
                if x == 1 {
                    panic!("boom");
                }
                x
            })
        });
        assert!(result.is_err());
    }

    #[test]
    fn auto_resolves_to_at_least_one() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(5), 5);
    }
}
