//! `aigc-edge` — leader entrypoint.
//!
//! See `cli::USAGE` for subcommands. The binary is self-contained once
//! `make artifacts` has produced the AOT executables: Python never runs
//! on any path below.

use anyhow::{bail, Context, Result};

use aigc_edge::bandwidth::{Allocator, EqualAllocator, ProportionalAllocator, PsoAllocator};
use aigc_edge::bench;
use aigc_edge::cli::{Args, USAGE};
use aigc_edge::config::ExperimentConfig;
use aigc_edge::coordinator::{profile_batch_delay, ProfileConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::{PowerLawQuality, QualityModel, TableQuality};
use aigc_edge::runtime::ArtifactStore;
use aigc_edge::scheduler::{
    BatchScheduler, FixedSizeBatching, GreedyBatching, SingleInstance, Stacking, StackingConfig,
};

/// Build the STACKING scheduler from config (0 = derive T* bound).
fn stacking_from(cfg: &ExperimentConfig) -> Stacking {
    Stacking::new(StackingConfig {
        t_star_max: (cfg.stacking.t_star_max > 0).then_some(cfg.stacking.t_star_max),
        max_steps: cfg.stacking.max_steps,
        ..Default::default()
    })
}
use aigc_edge::server::{serve, ServerConfig};
use aigc_edge::sim::solve_joint;
use aigc_edge::trace::generate;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "profile" => cmd_profile(&args),
        "figures" => cmd_figures(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path)),
        None => Ok(ExperimentConfig::paper()),
    }
}

fn quality_model(cfg: &ExperimentConfig) -> Result<Box<dyn QualityModel>> {
    use aigc_edge::config::QualityModelKind::*;
    Ok(match cfg.quality {
        PaperPowerLaw => Box::new(PowerLawQuality::paper()),
        CalibratedPowerLaw => {
            Box::new(PowerLawQuality::from_quality_json(&cfg.quality_json_path())?)
        }
        CalibratedTable => Box::new(TableQuality::from_quality_json(&cfg.quality_json_path())?),
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only(&["addr", "config", "epoch-ms", "max-batch"])?;
    let cfg = load_config(args)?;
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let server_cfg = ServerConfig {
        epoch_ms: args.get_u64("epoch-ms", 200)?,
        max_batch: args.get_usize("max-batch", 32)?,
    };
    let artifacts_dir = cfg.artifacts_dir.clone();
    let server = serve(artifacts_dir, cfg, server_cfg, &addr)?;
    println!("listening on {} — protocol: GEN <deadline_s> <eta> | STATS | QUIT", server.addr);
    // Run until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.expect_only(&["config", "scheduler", "allocator", "seed"])?;
    let mut cfg = load_config(args)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let scheduler: Box<dyn BatchScheduler> = match args.get_or("scheduler", "stacking").as_str() {
        "stacking" => Box::new(stacking_from(&cfg)),
        "single" => Box::new(SingleInstance::default()),
        "greedy" => Box::new(GreedyBatching),
        "fixed" => Box::new(FixedSizeBatching::default()),
        other => bail!("unknown scheduler '{other}'"),
    };
    let allocator: Box<dyn Allocator> = match args.get_or("allocator", "pso").as_str() {
        "pso" => Box::new(PsoAllocator::default()),
        "equal" => Box::new(EqualAllocator),
        "proportional" => Box::new(ProportionalAllocator),
        other => bail!("unknown allocator '{other}'"),
    };
    let quality = quality_model(&cfg)?;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let workload = generate(&cfg.scenario, cfg.seed);
    let sol = solve_joint(&workload, scheduler.as_ref(), allocator.as_ref(), &delay, quality.as_ref());

    println!(
        "scenario: K={} deadlines U[{}, {}]s B={} Hz",
        cfg.scenario.num_services,
        cfg.scenario.deadline_lo,
        cfg.scenario.deadline_hi,
        cfg.scenario.total_bandwidth_hz
    );
    println!("scheduler={} allocator={}", scheduler.name(), allocator.name());
    println!(
        "mean FID {:.3} | outages {} | mean steps {:.1} | makespan {:.2}s | inner evals {}",
        sol.outcome.mean_quality(),
        sol.outcome.outages(),
        sol.outcome.mean_steps(),
        sol.outcome.schedule.makespan(),
        sol.inner_evals
    );
    for s in &sol.outcome.services {
        println!(
            "  svc {:>2}: deadline {:>5.2}s steps {:>3} gen {:>5.2}s tx {:>4.2}s e2e {:>5.2}s {}",
            s.id,
            s.deadline,
            s.steps,
            s.gen_delay,
            s.tx_delay,
            s.e2e_delay,
            if s.met { "ok" } else { "OUTAGE" }
        );
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    args.expect_only(&["reps", "config"])?;
    let cfg = load_config(args)?;
    let reps = args.get_usize("reps", 20)?;
    let store = ArtifactStore::load(&cfg.artifacts_dir).context("loading artifacts")?;
    println!("platform: {}", store.platform());
    let fit = profile_batch_delay(&store, ProfileConfig { reps, ..Default::default() })?;
    let model = fit.model();
    println!("g(X) = aX + b fit over buckets {:?}", store.buckets());
    for (x, s) in &fit.samples {
        println!("  X={x:>3}: {:.5}s (fit {:.5}s)", s, model.g(*x));
    }
    println!("a = {:.6} s/task, b = {:.6} s/batch, R² = {:.4}", model.a, model.b, fit.fit.r2);
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    args.expect_only(&["which", "reps", "config"])?;
    let cfg = load_config(args)?;
    let which = args.get_or("which", "all");
    let reps = args.get_usize("reps", 3)?;
    let want = |name: &str| which == "all" || which == name;
    if want("1a") {
        let store = ArtifactStore::load(&cfg.artifacts_dir).context("loading artifacts")?;
        bench::fig1a(&store, reps.max(5));
    }
    if want("1b") {
        bench::fig1b(&cfg);
    }
    if want("2a") {
        bench::fig2a(&cfg);
    }
    if want("2b") {
        bench::fig2b(&cfg, &[5, 10, 15, 20, 25, 30, 35, 40], reps);
    }
    if want("2c") {
        bench::fig2c(&cfg, &[3.0, 5.0, 7.0, 9.0, 11.0, 13.0, 15.0, 17.0, 19.0], reps);
    }
    Ok(())
}
